"""Unified model assembly for all assigned architectures.

One parameter/init/apply stack covers:
  dense decoders (llama-style; olmo non-parametric LN; qwen3 qk-norm;
  phi3; deepseek-coder), MoE decoders (mixtral SWA; deepseek-v3 MLA+MoE+MTP),
  SSM (mamba2), hybrid (recurrentgemma RG-LRU 2:1 local attention),
  encoder-decoder (whisper, stub audio frontend), VLM (pixtral, stub patch
  frontend).

Layers are stacked and driven by `lax.scan` (compact HLO — essential for the
512-device dry-run compiles), with `jax.checkpoint` rematerialisation per
block.  Decode uses per-layer caches scanned alongside the parameters.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as ly
from .moe import moe_layer
from .ssm import mamba2_layer
from .rglru import rglru_layer

Params = Any

# Optional sequence-parallel activation sharding, set by the launcher
# (repro.launch.dryrun / train): a PartitionSpec applied to the residual
# stream at every block boundary.  None = let GSPMD propagate freely.
_ACT_SPEC = {"spec": None}


def set_activation_spec(spec):
    _ACT_SPEC["spec"] = spec


def _constrain_act(x):
    spec = _ACT_SPEC["spec"]
    if spec is not None and x.ndim == 3 and x.shape[1] >= 16 and x.shape[1] % 16 == 0:
        return jax.lax.with_sharding_constraint(x, spec)
    return x


# ---------------------------------------------------------------------- init
def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _mat(key, shape, dtype, scale=None):
    std = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _attn_init(cfg: ModelConfig, key, dtype):
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _mat(ks[0], (D, H * hd), dtype),
        "wk": _mat(ks[1], (D, KV * hd), dtype),
        "wv": _mat(ks[2], (D, KV * hd), dtype),
        "wo": _mat(ks[3], (H * hd, D), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _mla_init(cfg: ModelConfig, key, dtype):
    m, D, H = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "q_down": _mat(ks[0], (D, m.q_lora_rank), dtype),
        "q_down_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "q_up": _mat(ks[1], (m.q_lora_rank, H * qk), dtype),
        "kv_down": _mat(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_down_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "k_up": _mat(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "v_up": _mat(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": _mat(ks[5], (H * m.v_head_dim, D), dtype),
    }


def _mlp_init(cfg: ModelConfig, key, dtype, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _mat(ks[0], (D, F), dtype),
        "w_up": _mat(ks[1], (D, F), dtype),
        "w_down": _mat(ks[2], (F, D), dtype),
    }


def _moe_init(cfg: ModelConfig, key, dtype):
    m, D = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "router": _mat(ks[0], (D, m.num_experts), jnp.float32),
        "experts_gate": _mat(ks[1], (m.num_experts, D, m.d_ff_expert), dtype),
        "experts_up": _mat(ks[2], (m.num_experts, D, m.d_ff_expert), dtype),
        "experts_down": _mat(ks[3], (m.num_experts, m.d_ff_expert, D), dtype,
                             scale=1.0 / math.sqrt(m.d_ff_expert)),
    }
    if m.num_shared:
        p["shared_gate"] = _mat(ks[4], (m.num_shared, D, m.d_ff_expert), dtype)
        p["shared_up"] = _mat(ks[5], (m.num_shared, D, m.d_ff_expert), dtype)
        p["shared_down"] = _mat(ks[6], (m.num_shared, m.d_ff_expert, D), dtype,
                                scale=1.0 / math.sqrt(m.d_ff_expert))
    return p


def _ssm_init(cfg: ModelConfig, key, dtype):
    s, D = cfg.ssm, cfg.d_model
    din = s.expand * D
    H = din // s.head_dim
    N = s.d_state
    ks = jax.random.split(key, 3)
    return {
        "in_proj": _mat(ks[0], (D, 2 * din + 2 * N + H), dtype),
        "conv_w": _mat(ks[1], (s.d_conv, din + 2 * N), jnp.float32, scale=0.5),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_proj": _mat(ks[2], (din, D), dtype),
    }


def _rec_init(cfg: ModelConfig, key, dtype):
    r, D = cfg.rglru, cfg.d_model
    W = r.lru_width or D
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _mat(ks[0], (D, W), dtype),
        "gate_proj": _mat(ks[1], (D, W), dtype),
        "conv_w": _mat(ks[2], (r.conv_width, W), jnp.float32, scale=0.5),
        "w_r": _mat(ks[3], (W, W), dtype),
        "w_i": _mat(ks[4], (W, W), dtype),
        "lam": jnp.full((W,), 0.5, jnp.float32),
        "out_proj": _mat(ks[5], (W, D), dtype),
    }


def _norm_init(cfg):
    return None if cfg.nonparametric_norm else jnp.zeros((cfg.d_model,), jnp.float32)


def _block_init(cfg: ModelConfig, key, kind: str):
    """kind: attn | mla | ssm | rec | enc | dec"""
    dtype = _dt(cfg)
    ks = jax.random.split(key, 4)
    p = {}
    if kind == "ssm":
        p["norm"] = _norm_init(cfg)
        p["ssm"] = _ssm_init(cfg, ks[0], dtype)
        return p
    if kind == "rec":
        p["attn_norm"] = _norm_init(cfg)
        p["rec"] = _rec_init(cfg, ks[0], dtype)
        p["mlp_norm"] = _norm_init(cfg)
        p["mlp"] = _mlp_init(cfg, ks[1], dtype)
        return p
    p["attn_norm"] = _norm_init(cfg)
    p["attn"] = _mla_init(cfg, ks[0], dtype) if kind == "mla" else _attn_init(cfg, ks[0], dtype)
    if kind == "dec":
        p["cross_norm"] = _norm_init(cfg)
        p["cross"] = _attn_init(cfg, ks[2], dtype)
    p["mlp_norm"] = _norm_init(cfg)
    if cfg.moe is not None and kind in ("attn", "mla"):
        p["moe"] = _moe_init(cfg, ks[1], dtype)
    else:
        p["mlp"] = _mlp_init(cfg, ks[1], dtype)
    return p


def _stacked(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def decoder_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.mla is not None:
        return "mla"
    return "attn"


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = _dt(cfg)
    ks = jax.random.split(key, 8)
    p = {"tok_embed": _mat(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["out_head"] = _mat(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    p["final_norm"] = _norm_init(cfg)

    if cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        nb = cfg.num_layers // len(pat)
        rem = cfg.num_layers - nb * len(pat)
        def super_init(k):
            kk = jax.random.split(k, len(pat))
            return {f"{kind}{i}": _block_init(cfg, kk[i], "rec" if kind == "rec" else "attn")
                    for i, kind in enumerate(pat)}
        p["super"] = _stacked(super_init, ks[2], nb)
        if rem:
            p["tail"] = _stacked(lambda k: _block_init(cfg, k, "rec"), ks[3], rem)
    elif cfg.family == "encdec":
        p["enc"] = _stacked(lambda k: _block_init(cfg, k, "attn"), ks[2], cfg.encoder_layers)
        p["enc_norm"] = _norm_init(cfg)
        p["dec"] = _stacked(lambda k: _block_init(cfg, k, "dec"), ks[3], cfg.num_layers)
    else:
        kind = decoder_kind(cfg)
        p["layers"] = _stacked(lambda k: _block_init(cfg, k, kind), ks[2], cfg.num_layers)
    if cfg.mtp_depth:
        p["mtp_proj"] = _mat(ks[4], (2 * cfg.d_model, cfg.d_model), dtype)
        p["mtp_block"] = _block_init(cfg, ks[5], decoder_kind(cfg))
        p["mtp_norm"] = _norm_init(cfg)
    return p


# --------------------------------------------------------------------- cache
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> Any:
    """Decode caches, stacked per layer (leading layer axis for scan)."""
    dtype = dtype or _dt(cfg)
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model

    def attn_cache(window):
        C = min(cache_len, window) if window else cache_len
        c = {"k": jnp.zeros((batch, C, KV, hd), dtype),
             "v": jnp.zeros((batch, C, KV, hd), dtype)}
        if window and cache_len > window:
            c["pos"] = jnp.full((batch, C), -1, jnp.int32)
        return c

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    if cfg.family == "ssm":
        s = cfg.ssm
        din = s.expand * D
        nh = din // s.head_dim
        one = {"conv": jnp.zeros((batch, s.d_conv - 1, din + 2 * s.d_state), dtype),
               "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32)}
        return {"layers": stack(one, cfg.num_layers)}
    if cfg.family == "hybrid":
        r = cfg.rglru
        W = r.lru_width or D
        pat = r.pattern
        nb = cfg.num_layers // len(pat)
        rem = cfg.num_layers - nb * len(pat)
        rec = {"conv": jnp.zeros((batch, r.conv_width - 1, W), dtype),
               "h": jnp.zeros((batch, W), jnp.float32)}
        sup = {}
        for i, kind in enumerate(pat):
            sup[f"{kind}{i}"] = rec if kind == "rec" else attn_cache(r.window)
        out = {"super": stack(sup, nb)}
        if rem:
            out["tail"] = stack(rec, rem)
        return out
    if cfg.family == "encdec":
        one = {"self": attn_cache(None),
               "cross_k": jnp.zeros((batch, cfg.encoder_seq, KV, hd), dtype),
               "cross_v": jnp.zeros((batch, cfg.encoder_seq, KV, hd), dtype)}
        return {"dec": stack(one, cfg.num_layers)}
    one = (
        {"lat": jnp.zeros((batch, cache_len,
                           cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim), dtype)}
        if cfg.mla is not None else attn_cache(cfg.window)
    )
    return {"layers": stack(one, cfg.num_layers)}


# ------------------------------------------------------------------- blocks
def _apply_block(cfg: ModelConfig, p, x, positions, cache, cache_pos, kind,
                 enc_out=None):
    """One transformer block.  Returns (x, aux_loss, new_cache)."""
    aux = jnp.float32(0.0)
    if kind == "ssm":
        h, nc = mamba2_layer(cfg, p["ssm"], ly.norm(cfg, p.get("norm"), x), cache=cache)
        return x + h, aux, nc
    if kind == "rec":
        h, nc = rglru_layer(cfg, p["rec"], ly.norm(cfg, p.get("attn_norm"), x), cache=cache)
        x = x + h
        x = x + ly.swiglu(p["mlp"], ly.norm(cfg, p.get("mlp_norm"), x))
        return x, aux, nc
    # attention blocks
    window = cfg.window
    causal = kind != "enc"
    if kind == "attn_local":
        window = cfg.rglru.window
    h_in = ly.norm(cfg, p.get("attn_norm"), x)
    if kind == "mla":
        h, nc = ly.mla_attention(cfg, p["attn"], h_in, positions=positions,
                                 cache=cache, cache_pos=cache_pos)
    else:
        h, nc = ly.gqa_attention(cfg, p["attn"], h_in, positions=positions,
                                 cache=cache if kind != "dec" else
                                 (cache["self"] if cache is not None else None),
                                 cache_pos=cache_pos, causal=causal, window=window)
    x = x + h
    if kind == "dec":
        if enc_out is not None:
            # train or prefill: compute cross K/V from the encoder output
            ck = ly.dense(enc_out, p["cross"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
            cv = ly.dense(enc_out, p["cross"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
            kv = (ck, cv)
        else:
            kv = (cache["cross_k"], cache["cross_v"])
        h, _ = ly.gqa_attention(cfg, p["cross"], ly.norm(cfg, p.get("cross_norm"), x),
                                positions=None, causal=False, kv_override=kv)
        x = x + h
        nc = {"self": nc, "cross_k": kv[0], "cross_v": kv[1]} if cache is not None else None
    h_in = ly.norm(cfg, p.get("mlp_norm"), x)
    if "moe" in p:
        from .moe_a2a import a2a_available, moe_layer_a2a
        if a2a_available(cfg, h_in.shape[1]):
            h, aux = moe_layer_a2a(cfg, p["moe"], h_in)
        else:
            h, aux = moe_layer(cfg, p["moe"], h_in)
    else:
        h = ly.swiglu(p["mlp"], h_in)
    return x + h, aux, nc


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_stack(cfg: ModelConfig, stack, x, positions, kind, cache=None,
               cache_pos=0, enc_out=None):
    """Scan a homogeneous layer stack. Returns (x, aux, new_cache)."""

    if cache is None:
        def body(carry, lp):
            xx, aux = carry
            xx, a2, _ = _apply_block(cfg, lp, xx, positions, None, 0, kind, enc_out)
            return (_constrain_act(xx), aux + a2), None
        body = _maybe_remat(cfg, body)
        (x, aux), _ = jax.lax.scan(body, (_constrain_act(x), jnp.float32(0.0)), stack)
        return x, aux, None

    def body(carry, xs):
        xx, aux = carry
        lp, lc = xs
        xx, a2, nc = _apply_block(cfg, lp, xx, positions, lc, cache_pos, kind, enc_out)
        return (_constrain_act(xx), aux + a2), nc
    body = _maybe_remat(cfg, body)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), (stack, cache))
    return x, aux, new_cache


def _run_hybrid(cfg: ModelConfig, params, x, positions, cache=None, cache_pos=0):
    pat = cfg.rglru.pattern
    kinds = {f"{k}{i}": ("rec" if k == "rec" else "attn_local") for i, k in enumerate(pat)}

    def body(carry, xs):
        xx, aux = carry
        if cache is None:
            lp = xs
            lc = {k: None for k in kinds}
        else:
            lp, lc = xs
        ncs = {}
        for name in [f"{k}{i}" for i, k in enumerate(pat)]:
            xx, a2, nc = _apply_block(cfg, lp[name], xx, positions, lc[name],
                                      cache_pos, kinds[name])
            aux = aux + a2
            ncs[name] = nc
        return (xx, aux), (ncs if cache is not None else None)

    body = _maybe_remat(cfg, body)
    xs = params["super"] if cache is None else (params["super"], cache["super"])
    (x, aux), new_sup = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    new_cache = {"super": new_sup} if cache is not None else None
    if "tail" in params:
        tc = cache["tail"] if cache is not None else None
        x, a2, new_tail = _run_stack(cfg, params["tail"], x, positions, "rec", tc, cache_pos)
        aux += a2
        if cache is not None:
            new_cache["tail"] = new_tail
    return x, aux, new_cache


# ------------------------------------------------------------------ forward
def embed(cfg: ModelConfig, params, tokens):
    return params["tok_embed"][tokens].astype(_dt(cfg)) * math.sqrt(cfg.d_model)


def unembed(cfg: ModelConfig, params, x):
    w = params["tok_embed"].T if cfg.tie_embeddings else params["out_head"]
    return jnp.einsum("...d,dv->...v", x, w)


def forward(cfg: ModelConfig, params, batch, cache=None, cache_pos=0):
    """Full-sequence forward (train / prefill).  batch keys:
    tokens (B,S); frames (B,Se,D) for encdec; patches (B,P,D) for vlm.
    Returns (hidden (B,S,D), aux_loss, new_cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)) + cache_pos

    if cfg.family == "encdec":
        enc_x = batch["frames"].astype(_dt(cfg))
        pe = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1], dtype=jnp.int32)[None], enc_x.shape[:2])
        enc_out, _, _ = _run_stack(cfg, params["enc"], enc_x, pe, "enc")
        enc_out = ly.norm(cfg, params.get("enc_norm"), enc_out)
        x, aux, nc = _run_stack(cfg, params["dec"], x, positions, "dec",
                                cache["dec"] if cache is not None else None,
                                cache_pos, enc_out=enc_out)
        new_cache = {"dec": nc} if cache is not None else None
    elif cfg.family == "hybrid":
        x, aux, new_cache = _run_hybrid(cfg, params, x, positions, cache, cache_pos)
    else:
        if cfg.family == "vlm" and "patches" in batch:
            patches = batch["patches"].astype(_dt(cfg))
            x = jnp.concatenate([patches, x], axis=1)
            S = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        kind = decoder_kind(cfg)
        lc = cache["layers"] if cache is not None else None
        x, aux, nc = _run_stack(cfg, params["layers"], x, positions, kind, lc, cache_pos)
        new_cache = {"layers": nc} if cache is not None else None
    x = ly.norm(cfg, params.get("final_norm"), x)
    return x, aux, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens (B, 1); pos: scalar int32 absolute position.
    Returns (logits (B, vocab), new_cache)."""
    B = tokens.shape[0]
    x = embed(cfg, params, tokens)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.family == "encdec":
        x, _, nc = _run_stack(cfg, params["dec"], x, positions, "dec",
                              cache["dec"], pos)
        new_cache = {"dec": nc}
    elif cfg.family == "hybrid":
        x, _, new_cache = _run_hybrid(cfg, params, x, positions, cache, pos)
    else:
        kind = decoder_kind(cfg)
        x, _, nc = _run_stack(cfg, params["layers"], x, positions, kind,
                              cache["layers"], pos)
        new_cache = {"layers": nc}
    x = ly.norm(cfg, params.get("final_norm"), x)
    logits = unembed(cfg, params, x[:, 0]).astype(jnp.float32)
    return logits, new_cache


# --------------------------------------------------------------------- loss
def chunked_ce(cfg: ModelConfig, params, hidden, targets, mask, chunk=512):
    """Cross-entropy without materialising (B, S, V) logits: lax.map over
    sequence chunks (vocab up to 256k stays in-bounds)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nch = S // chunk
    h = hidden.reshape(B, nch, chunk, D).swapaxes(0, 1)
    t = targets.reshape(B, nch, chunk).swapaxes(0, 1)
    m = mask.reshape(B, nch, chunk).swapaxes(0, 1)

    def one(args):
        hh, tt, mm = args
        logits = unembed(cfg, params, hh).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return nll.sum(), mm.sum()

    nll, cnt = jax.lax.map(one, (h, t, m))
    return nll.sum() / jnp.maximum(cnt.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token CE (+ MoE aux + optional MTP head). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    hidden, aux, _ = forward(cfg, params, batch)
    if cfg.family == "vlm" and "patches" in batch:
        hidden = hidden[:, batch["patches"].shape[1]:]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if "mask" in batch:
        mask = mask * batch["mask"]
    ce = chunked_ce(cfg, params, hidden, targets, mask)
    loss = ce + 0.01 * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        # DeepSeek-style MTP: combine h_t with emb(t+1), one extra block,
        # shared head predicts t+2.
        e_next = embed(cfg, params, targets)
        h = jnp.concatenate([hidden, e_next], axis=-1)
        h = jnp.einsum("bsd,df->bsf", h, params["mtp_proj"])
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        h, _, _ = _apply_block(cfg, params["mtp_block"], h, positions, None, 0,
                               decoder_kind(cfg))
        h = ly.norm(cfg, params.get("mtp_norm"), h)
        t2 = jnp.concatenate([tokens[:, 2:], tokens[:, :2]], axis=1)
        m2 = mask.at[:, -2:].set(0.0)
        mtp = chunked_ce(cfg, params, h, t2, m2)
        loss = loss + 0.3 * mtp
        metrics["mtp"] = mtp
    return loss, metrics
