from .config import (MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SHAPES,
                     ShapeConfig, SSMConfig, reduced)
from .lm import decode_step, forward, init_cache, init_params, loss_fn

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "ShapeConfig", "SHAPES", "reduced",
    "init_params", "init_cache", "forward", "decode_step", "loss_fn",
]
