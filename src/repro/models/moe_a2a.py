"""Expert-parallel MoE dispatch via shard_map + all-to-all.

The GSPMD formulation in `moe.py` scatters tokens into a logically-global
(E, C, D) buffer; under pjit the combine becomes buffer-sized partial-sum
all-reduces (EXPERIMENTS.md §Perf, Cell A/C).  This module re-expresses the
dispatch the way expert-parallel systems do it on the wire:

  1. tokens are sequence-sharded across the 'model' axis (every device owns
     a distinct token slice);
  2. each device packs its routed tokens into per-destination-shard,
     per-expert capacity slots: buf (tp, E_local, C_e, D);
  3. ONE all-to-all over 'model' moves token payloads only;
  4. each shard runs its local experts on the received (E_local, tp*C_e, D)
     batch; the reverse all-to-all returns outputs to the token owners.

Requires num_experts % tp == 0 (deepseek-v3: 256 % 16; mixtral's E=8 < 16
keeps the tensor-parallel-inside-expert fallback).  Enabled per-run via
`set_moe_impl` (the dry-run/launcher sets it; default stays GSPMD).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

_IMPL = {"mesh": None, "dp_axes": (), "model_axis": "model"}


def set_moe_impl(mesh=None, dp_axes=(), model_axis="model"):
    """Install (or clear, with mesh=None) the a2a dispatch for moe layers."""
    _IMPL.update(mesh=mesh, dp_axes=tuple(dp_axes), model_axis=model_axis)


def a2a_available(cfg: ModelConfig, seq_len: int) -> bool:
    mesh = _IMPL["mesh"]
    if mesh is None or cfg.moe is None:
        return False
    tp = mesh.shape.get(_IMPL["model_axis"], 1)
    return (cfg.moe.num_experts % tp == 0 and tp > 1
            and seq_len % tp == 0 and seq_len >= tp)


def moe_layer_a2a(cfg: ModelConfig, p, x):
    """Drop-in replacement for moe.moe_layer when a2a_available()."""
    mesh = _IMPL["mesh"]
    ax = _IMPL["model_axis"]
    dp = _IMPL["dp_axes"]
    m = cfg.moe
    tp = mesh.shape[ax]
    B, S, D = x.shape
    E = m.num_experts
    E_l = E // tp
    # per-source-shard, per-expert capacity
    T_l = (B * S) // tp // max(_dp_size(mesh, dp), 1)
    C_e = max(8, -(-int(T_l * m.top_k / E * m.capacity_factor) // 8) * 8)

    fsdp_ax = dp if cfg.fsdp else ()

    def body(x_l, router, eg, eu, ed, *shared):
        if fsdp_ax:
            # ZeRO: gather the local experts' weights over the FSDP axes for
            # this layer only; AD reduce-scatters dW back (same wire bytes as
            # the GSPMD formulation, but token payloads now go via all-to-all)
            for a in fsdp_ax:
                eg = jax.lax.all_gather(eg, a, axis=1, tiled=True)
                eu = jax.lax.all_gather(eu, a, axis=1, tiled=True)
                ed = jax.lax.all_gather(ed, a, axis=2, tiled=True)
        Bl, Sl, _ = x_l.shape
        Tl = Bl * Sl
        xt = x_l.reshape(Tl, D)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, m.top_k)            # (Tl, K)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        flat_e = ids.reshape(-1)                             # (Tl*K,) global expert
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        pos_sorted = jnp.arange(Tl * m.top_k) - seg[sorted_e]
        pos = jnp.zeros(Tl * m.top_k, jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < C_e
        dest = flat_e // E_l                                 # target shard
        e_loc = flat_e % E_l
        tok = jnp.arange(Tl * m.top_k) // m.top_k

        # pack: (tp, E_l, C_e, D)
        buf = jnp.zeros((tp, E_l, C_e, D), x_l.dtype)
        buf = buf.at[
            jnp.where(keep, dest, 0), jnp.where(keep, e_loc, 0),
            jnp.where(keep, pos, C_e - 1)
        ].add(jnp.where(keep[:, None], xt[tok], 0).astype(x_l.dtype))

        recv = jax.lax.all_to_all(buf, ax, split_axis=0, concat_axis=0,
                                  tiled=False)               # (tp, E_l, C_e, D)
        work = recv.transpose(1, 0, 2, 3).reshape(E_l, tp * C_e, D)
        g = jnp.einsum("ecd,edf->ecf", work, eg)
        u = jnp.einsum("ecd,edf->ecf", work, eu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, ed)
        y = y.reshape(E_l, tp, C_e, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, ax, split_axis=0, concat_axis=0,
                                  tiled=False)               # (tp, E_l, C_e, D)

        rows = back[jnp.where(keep, dest, 0), jnp.where(keep, e_loc, 0),
                    jnp.where(keep, pos, 0)]
        rows = jnp.where(keep[:, None], rows, 0)
        contrib = rows * gate.reshape(-1)[:, None].astype(rows.dtype)
        out = jax.ops.segment_sum(contrib, tok, num_segments=Tl)

        if shared:
            sg, su, sd = shared
            hg = jnp.einsum("td,sdf->tsf", xt, sg)
            hu = jnp.einsum("td,sdf->tsf", xt, su)
            out = out + jnp.einsum("tsf,sfd->td", jax.nn.silu(hg) * hu, sd)

        # switch aux loss from local stats, averaged over all shards
        me = probs.mean(0)
        ce = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32).mean(0)
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, (*dp, ax)) if dp else jax.lax.pmean(aux, ax)
        return out.reshape(Bl, Sl, D).astype(x_l.dtype), aux

    shared_specs, shared_args = (), ()
    if m.num_shared:
        shared_specs = (P(), P(), P())
        shared_args = (p["shared_gate"], p["shared_up"], p["shared_down"])
    wspec = (P(ax, dp if (cfg.fsdp and dp) else None, None),
             P(ax, dp if (cfg.fsdp and dp) else None, None),
             P(ax, None, dp if (cfg.fsdp and dp) else None))
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp if dp else None, ax, None), P(), *wspec, *shared_specs),
        out_specs=(P(dp if dp else None, ax, None), P()),
        check_rep=False,
    )
    return fn(x, p["router"], p["experts_gate"], p["experts_up"],
              p["experts_down"], *shared_args)


def _dp_size(mesh, dp_axes):
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n
