"""Mamba-2 (State-Space Duality) block — chunked training scan + O(1) decode.

Follows the SSD formulation (Dao & Gu 2024): within chunks the recurrence is
computed as masked attention-like einsums (MXU-friendly), across chunks a
small state (H, P, N) is carried by an associative scan.  Decode keeps the
(conv, state) pair and costs O(1) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _causal_conv(x, w, state=None):
    """Depthwise causal conv over time: x (B, S, C), w (K, C).
    state: (B, K-1, C) trailing context for decode. Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y.astype(x.dtype), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0=None):
    """SSD scan.  xh (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).  Math (per head):
      h_t = exp(A dt_t) h_{t-1} + dt_t * B_t x_t
      y_t = C_t . h_t
    h0: optional initial state (prefill continuation).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0
    xc = xh.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    dA = dtc * A[None, None, None, :]              # (B,nc,l,H) log-decay, <= 0
    cums = jnp.cumsum(dA, axis=2)                  # inclusive cumsum within chunk

    # ---- intra-chunk (lower-triangular "attention") ----
    # L[i,j] = exp(cums_i - cums_j) for i >= j   (decay from j+1..i), * dt_j
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]      # (B,nc,l,l,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjh,bcjhp->bcihp",
        CB, L, dtc.astype(jnp.float32), xc.astype(jnp.float32),
    )

    # ---- chunk states and inter-chunk scan ----
    seg_end = cums[:, :, -1:, :]                   # (B,nc,1,H) total chunk decay
    decay_to_end = jnp.exp(seg_end - cums)         # (B,nc,l,H)
    states = jnp.einsum(
        "bcjn,bcjh,bcjh,bcjhp->bchpn",
        Bc.astype(jnp.float32), (dtc * 1.0).astype(jnp.float32),
        decay_to_end.astype(jnp.float32), xc.astype(jnp.float32),
    )                                              # (B,nc,H,P,N)
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])     # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp
        h = h * dec[..., None, None] + st
        return h, h

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, hs = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hs = hs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N) state at chunk END
    h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)

    # ---- inter-chunk contribution: y_j += C_j . (decay_to_j * h_prev) ----
    decay_from_start = jnp.exp(cums)               # (B,nc,l,H)
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp",
        Cc.astype(jnp.float32), decay_from_start.astype(jnp.float32), h_prev,
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(xh.dtype), hs[:, -1]


def mamba2_layer(cfg: ModelConfig, p, x, *, cache=None):
    """x (B,S,D) -> (B,S,D).  cache: dict(conv=(B,K-1,C), state=(B,H,P,N))."""
    s = cfg.ssm
    B, S, D = x.shape
    din = s.expand * D
    H = din // s.head_dim
    P, N = s.head_dim, s.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xb, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xb, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"], None if cache is None else cache["conv"]
    )
    conv_out = jax.nn.silu(conv_out)
    xb = conv_out[..., :din].reshape(B, S, H, P)
    Bm = conv_out[..., din : din + N]
    Cm = conv_out[..., din + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))    # (H,) negative

    if cache is None:
        y, _ = ssd_chunked(xb, dt, A, Bm, Cm, min(s.chunk, S))
        new_state = None
    elif S > 1:
        # prefill continuation: chunked scan carrying the cached state
        y, new_state = ssd_chunked(xb, dt, A, Bm, Cm, min(s.chunk, S),
                                   h0=cache["state"])
    else:
        # O(1) decode: h = h * exp(A dt) + dt * B x ; y = C . h
        h = cache["state"]
        dec = jnp.exp(A[None] * dt[:, 0])           # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                         dt[:, 0], xb[:, 0].astype(jnp.float32))
        h = h * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)[:, None]
        new_state = h
    y = y + xb.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, din).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = None if cache is None else dict(conv=new_conv, state=new_state)
    return out.astype(x.dtype), new_cache
