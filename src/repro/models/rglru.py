"""RecurrentGemma building blocks: RG-LRU recurrence + local-attention mix.

The RG-LRU (Real-Gated Linear Recurrent Unit, De et al. 2024):
    r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Training uses an associative scan over the sequence (log-depth on TPU);
decode keeps h as O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

_C = 8.0


def _lru_scan(a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan. a, bx: (B, S, W)."""

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return hh


def rglru_layer(cfg: ModelConfig, p, x, *, cache=None):
    """Recurrent block: conv1d -> RG-LRU -> out proj. x (B,S,D).

    cache: dict(conv=(B,K-1,W), h=(B,W)) for decode."""
    r = cfg.rglru
    B, S, D = x.shape
    W = r.lru_width or D
    xw = jnp.einsum("bsd,dw->bsw", x, p["in_proj"])
    gate_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["gate_proj"]))

    # causal depthwise conv
    K = r.conv_width
    if cache is None:
        pad = jnp.zeros((B, K - 1, W), xw.dtype)
        xp = jnp.concatenate([pad, xw], axis=1)
        new_conv = None
    else:
        xp = jnp.concatenate([cache["conv"].astype(xw.dtype), xw], axis=1)
        new_conv = xp[:, -(K - 1):]
    xc = sum(xp[:, i : i + S] * p["conv_w"][i][None, None] for i in range(K))

    rg = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_r"]).astype(jnp.float32))
    ig = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)
    gated = ig * xc.astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    if cache is None:
        h = _lru_scan(a, bx)
        new_h = None
    else:
        # scan with initial state h0: h_t = scan(a, bx)_t + (prod a_{1..t}) h0
        h = _lru_scan(a, bx)
        cum_a = jax.lax.associative_scan(jnp.multiply, a, axis=1)
        h = h + cum_a * cache["h"][:, None].astype(h.dtype)
        new_h = h[:, -1]
    y = (h.astype(x.dtype) * gate_branch)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"])
    new_cache = None if cache is None else dict(conv=new_conv, h=new_h)
    return out.astype(x.dtype), new_cache
