"""Shared neural layers: norms, RoPE, GQA/MLA attention, SwiGLU.

Pure-jnp, sharding-agnostic (GSPMD propagates shardings through einsums).
Long sequences use a block-triangular online-softmax attention (`blocked
attention`): exact flash-style causal attention with only the lower-triangle
blocks materialised, so prefill FLOPs stay at the useful S^2/2 and the
working set stays O(chunk^2) — the pure-XLA analogue of a fused TPU kernel.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# ------------------------------------------------------------------- norms
def rms_norm(x, scale=None, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def layer_norm_np(x, eps=1e-5):
    """Non-parametric LayerNorm (OLMo)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(cfg: ModelConfig, params, x):
    if cfg.nonparametric_norm:
        return layer_norm_np(x)
    return rms_norm(x, params)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions, dim, theta):
    """positions (...,) -> cos/sin (..., dim/2)."""
    freqs = jnp.asarray(
        1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim)), jnp.float32
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); cos/sin: (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ dense matmul
def dense(x, w):
    return jnp.einsum("...d,df->...f", x, w).astype(x.dtype)


def swiglu(params, x):
    g = dense(x, params["w_gate"])
    u = dense(x, params["w_up"])
    return dense(jax.nn.silu(g) * u, params["w_down"])


# -------------------------------------------------------------- attention
NEG_INF = -1e30


def _plain_attention(q, k, v, *, causal, window, q_offset, scale):
    """Reference einsum attention (short sequences / decode).

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0.
    q_offset: absolute position of q[0] relative to k[0] (for decode Sq=1)."""
    with jax.named_scope("flash_attention"):
        return _plain_attention_impl(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset, scale=scale)


def _plain_attention_impl(q, k, v, *, causal, window, q_offset, scale):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def _blocked_causal_attention(q, k, v, *, window, scale, chunk):
    """Exact causal attention with lower-triangular block iteration and online
    softmax.  Only blocks intersecting the causal (and window) band are
    computed: FLOPs ~ S^2/2 (resp. S*window)."""
    with jax.named_scope("flash_attention"):
        return _blocked_causal_attention_impl(q, k, v, window=window,
                                              scale=scale, chunk=chunk)


def _blocked_causal_attention_impl(q, k, v, *, window, scale, chunk):
    B, S, H, hd = q.shape
    KV, vd = k.shape[2], v.shape[-1]
    G = H // KV
    nb = S // chunk
    assert S % chunk == 0
    qg = q.reshape(B, nb, chunk, KV, G, hd)
    kb = k.reshape(B, nb, chunk, KV, hd)
    vb = v.reshape(B, nb, chunk, KV, vd)
    win_blocks = None if window is None else max(1, -(-window // chunk))

    pos = jnp.arange(chunk)
    outs = []
    for i in range(nb):
        m = jnp.full((B, chunk, KV, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, chunk, KV, G), jnp.float32)
        acc = jnp.zeros((B, chunk, KV, G, vd), jnp.float32)
        j_lo = 0 if win_blocks is None else max(0, i - win_blocks)
        for j in range(j_lo, i + 1):
            s = jnp.einsum(
                "bqkgh,bskh->bqkgs",
                qg[:, i].astype(jnp.float32),
                kb[:, j].astype(jnp.float32),
            ) * scale
            qpos = pos[:, None] + i * chunk
            kpos = pos[None, :] + j * chunk
            mask = kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskh->bqkgh", p, vb[:, j].astype(jnp.float32)
            )
            m = m_new
        outs.append((acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, chunk, H, vd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _fori_flash_attention(q, k, v, *, window, scale, chunk):
    """Exact causal flash attention for INFERENCE (prefill): outer lax.map
    over q blocks, inner fori_loop with a *dynamic* upper bound — linear HLO
    size, no masked-block overcompute.  Not reverse-mode differentiable
    (dynamic trip count), hence inference-only."""
    with jax.named_scope("flash_attention"):
        return _fori_flash_attention_impl(q, k, v, window=window, scale=scale,
                                          chunk=chunk)


def _fori_flash_attention_impl(q, k, v, *, window, scale, chunk):
    B, S, H, hd = q.shape
    KV, vd = k.shape[2], v.shape[-1]
    G = H // KV
    nb = S // chunk
    qb = q.reshape(B, nb, chunk, KV, G, hd)
    kb = k.reshape(B, nb, chunk, KV, hd)
    vb = v.reshape(B, nb, chunk, KV, vd)
    pos = jnp.arange(chunk)
    win_blocks = None if window is None else max(1, -(-window // chunk))

    def qblock(i):  # noqa: within flash_attention scope via caller
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False).astype(jnp.float32)

        def body(j, carry):
            m, l, acc = carry
            kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False).astype(jnp.float32)
            vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False).astype(jnp.float32)
            s = jnp.einsum("bqkgh,bskh->bqkgs", qi, kj) * scale
            qpos = pos[:, None] + i * chunk
            kpos = pos[None, :] + j * chunk
            mask = kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l2 = l * corr + p.sum(axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum("bqkgs,bskh->bqkgh", p, vj)
            return m_new, l2, acc2

        init = (jnp.full((B, chunk, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, chunk, KV, G), jnp.float32),
                jnp.zeros((B, chunk, KV, G, vd), jnp.float32))
        lo = jnp.int32(0) if win_blocks is None else jnp.maximum(i - win_blocks, 0)
        m, l, acc = jax.lax.fori_loop(lo, i + 1, body, init)
        return (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, chunk, H, vd)

    out = jax.lax.map(qblock, jnp.arange(nb))          # (nb, B, chunk, H, vd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, vd)
    return out.astype(q.dtype)


def attention_core(q, k, v, *, causal=True, window=None, q_offset=0,
                   blocked_threshold=4096, chunk=1024, inference=False,
                   scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    Sq, Sk = q.shape[1], k.shape[1]
    if causal and Sq == Sk and Sk >= blocked_threshold and Sk % chunk == 0:
        if inference:
            big_chunk = max(chunk, Sk // 16)
            if Sk % big_chunk == 0:
                return _fori_flash_attention(q, k, v, window=window, scale=scale,
                                             chunk=big_chunk)
        return _blocked_causal_attention(q, k, v, window=window, scale=scale, chunk=chunk)
    return _plain_attention(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, scale=scale)


# --------------------------------------------------------------- GQA layer
def gqa_attention(cfg: ModelConfig, p, x, *, positions, cache=None, cache_pos=None,
                  causal=True, window=None, kv_override=None):
    """Grouped-query attention with RoPE, optional qk-norm / sliding window.

    cache: dict(k=(B, C, KV, hd), v=...) ring/linear buffer, written at
    cache_pos.  Returns (out, new_cache).
    kv_override: (k, v) for cross-attention (whisper decoder)."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = dense(x, p["wq"]).reshape(B, S, H, hd)
    if kv_override is None:
        k = dense(x, p["wk"]).reshape(B, S, KV, hd)
        v = dense(x, p["wv"]).reshape(B, S, KV, hd)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"]) if kv_override is None else k
    if kv_override is None and positions is not None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q_offset = 0
    new_cache = cache
    if cache is not None and kv_override is None:
        C = cache["k"].shape[1]
        if "pos" in cache and S >= C:
            # long prefill into a window ring: attention runs over the fresh
            # k/v (blocked SWA); only the last C tokens enter the ring, laid
            # out so slot(p) == p % C.
            shift = jnp.mod(cache_pos + S - C, C)
            ck = jnp.roll(k[:, -C:], shift, axis=1)
            cv = jnp.roll(v[:, -C:], shift, axis=1)
            kpos = jnp.roll(positions[:, -C:], shift, axis=1)
            new_cache = dict(k=ck, v=cv, pos=kpos)
            out = attention_core(q, k, v, causal=causal, window=window,
                                 q_offset=cache_pos, inference=True)
            return dense(out.reshape(B, S, H * hd), p["wo"]), new_cache
        if "pos" in cache:
            # ring buffer (sliding-window cache shorter than the sequence)
            idx = cache_pos % C
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
            # unroll the ring into causal order is unnecessary: use positions
            kpos = cache["pos"]
            kpos = jax.lax.dynamic_update_slice(kpos, positions.reshape(B, -1), (0, idx))
            new_cache = dict(k=ck, v=cv, pos=kpos)
            # attend with explicit position mask
            return _ring_decode_attend(cfg, p, q, new_cache, positions), new_cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_pos, 0, 0))
        new_cache = dict(k=ck, v=cv)
        k, v = ck, cv
        q_offset = cache_pos
        # mask out not-yet-written slots via causal mask (positions beyond
        # cache_pos + S are > qpos, already excluded)
    out = attention_core(q, k, v, causal=causal, window=window, q_offset=q_offset,
                         inference=cache is not None or kv_override is not None)
    return dense(out.reshape(B, S, H * hd), p["wo"]), new_cache


def _ring_decode_attend(cfg: ModelConfig, p, q, cache, positions):
    """Decode attention over a ring buffer with explicit per-slot positions."""
    B, S, H, hd = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    k, v, kpos = cache["k"], cache["v"], cache["pos"]
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = positions.reshape(B, -1)
    valid = (
        (kpos[:, None, :] >= 0)
        & (kpos[:, None, :] <= qpos[..., None])
        & (kpos[:, None, :] > qpos[..., None] - (cfg.window or 1 << 30))
    )
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pr, v.astype(jnp.float32))
    out = out.reshape(B, S, H * hd).astype(q.dtype)
    return dense(out, p["wo"])


# --------------------------------------------------------------- MLA layer
def mla_attention(cfg: ModelConfig, p, x, *, positions, cache=None, cache_pos=None):
    """Multi-head Latent Attention (DeepSeek-V3).

    Training/prefill: expanded form (materialise per-head K/V from the
    latent).  Decode: absorbed form — queries are projected into the latent
    space and attention runs against the (kv_lora + rope) cache directly,
    MQA-style; W_uk / W_uv are absorbed into the query/output projections.
    """
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rms_norm(dense(x, p["q_down"]), p["q_down_norm"])
    q = dense(cq, p["q_up"]).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv = dense(x, p["kv_down"])
    c_kv = rms_norm(kv[..., : m.kv_lora_rank], p["kv_down_norm"])
    k_rope = kv[..., m.kv_lora_rank:].reshape(B, S, 1, m.qk_rope_head_dim)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    scale = 1.0 / math.sqrt(qk)
    if cache is not None:
        # ---- absorbed decode/prefill path: attention runs in the latent
        # space, MQA-style (KV = 1): q_lat = q_nope @ W_uk, keys/values are
        # the (kv_lora + rope) cache itself; W_uv is applied to the output.
        lat = jnp.concatenate([c_kv, k_rope[:, :, 0]], axis=-1)  # (B,S,r+rope)
        clat = jax.lax.dynamic_update_slice(cache["lat"], lat, (0, cache_pos, 0))
        new_cache = dict(lat=clat)
        w_uk = p["k_up"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        q_all = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], axis=-1)
        k_all = clat[:, :, None, :]                         # (B,Sc,1,r+rope)
        v_all = clat[:, :, None, : m.kv_lora_rank]          # (B,Sc,1,r)
        o_lat = attention_core(q_all.astype(x.dtype), k_all, v_all,
                               causal=True, q_offset=cache_pos,
                               inference=True, scale=scale)
        w_uv = p["v_up"].reshape(m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(jnp.float32),
                         w_uv.astype(jnp.float32))
        out = out.reshape(B, S, H * m.v_head_dim).astype(x.dtype)
        return dense(out, p["wo"]), new_cache

    # ---- expanded train/prefill path ----
    k_nope = dense(c_kv, p["k_up"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = dense(c_kv, p["v_up"]).reshape(B, S, H, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    out = attention_core(qq, k, v, causal=True)
    out = out.reshape(B, S, H * m.v_head_dim)
    return dense(out, p["wo"]), None
