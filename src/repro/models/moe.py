"""Mixture-of-Experts layer with sort-based dispatch and SFC expert placement.

Dispatch is capacity-bounded and sort-based (argsort by expert id + scatter
into (E, C, D) buffers), so compute scales with *active* tokens only —
no (T, E, C) one-hot dispatch tensors.  Expert buffers are sharded over the
'model' mesh axis (expert parallelism); the token scatter/gather lowers to
an all-to-all under GSPMD.

The expert->device order follows the SFC placement module: experts are kept
contiguous per device, which keeps the all-to-all block-structured, and
`repro.core.placement.expert_placement` re-partitions experts by measured
load between training phases (see examples/sfc_expert_placement.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 lanes


def moe_layer(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (B, S, D).  Router in float32, experts in model dtype."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    C = moe_capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = ids.reshape(-1)                                # (T*K,)
    # position of each routed token within its expert (sort-based ranking)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    pos = jnp.zeros(T * K, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C                                          # capacity drop

    tok_idx = jnp.arange(T * K) // K
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[jnp.where(keep, flat_e, E - 1),
                 jnp.where(keep, pos, C - 1)].add(
        jnp.where(keep[:, None], xt[tok_idx], 0).astype(x.dtype)
    )

    # expert FFN (SwiGLU): (E, C, D) x (E, D, F)
    g = jnp.einsum("ecd,edf->ecf", buf, p["experts_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["experts_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["experts_down"])

    # gather back + weighted combine
    out_rows = y[jnp.where(keep, flat_e, 0), jnp.where(keep, pos, 0)]
    out_rows = jnp.where(keep[:, None], out_rows, 0)
    contrib = out_rows * gate.reshape(-1)[:, None].astype(out_rows.dtype)
    out = jax.ops.segment_sum(contrib, tok_idx, num_segments=T)

    if m.num_shared:
        sg = jnp.einsum("td,sdf->tsf", xt, p["shared_gate"])
        su = jnp.einsum("td,sdf->tsf", xt, p["shared_up"])
        out = out + jnp.einsum("tsf,sfd->td", jax.nn.silu(sg) * su, p["shared_down"])
    return out.reshape(B, S, D).astype(x.dtype), _aux_loss(probs, ids, E)


def _aux_loss(probs, ids, E):
    """Switch-style load-balance auxiliary loss."""
    T, K = ids.shape
    me = probs.mean(0)                                       # (E,)
    one = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    ce = one.mean(0)
    return E * jnp.sum(me * ce)
