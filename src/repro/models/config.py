"""Model / shape / run configuration dataclasses.

Every assigned architecture is expressed as a `ModelConfig`; the four
benchmark shapes (train_4k / prefill_32k / decode_32k / long_500k) are
`ShapeConfig`s.  `reduced()` produces the family-preserving small config used
by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma: RG-LRU + local attention, pattern (rec, rec, attn)."""
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4
    window: int = 2048
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    qk_norm: bool = False                   # qwen3
    nonparametric_norm: bool = False        # olmo
    window: Optional[int] = None            # sliding-window attention (mixtral)
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper): encoder consumes precomputed frame embeddings
    encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm (pixtral): prefix of precomputed patch embeddings
    num_patches: int = 0
    mtp_depth: int = 0                      # deepseek multi-token prediction
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # training-memory knobs (per-arch defaults; the perf loop tunes these)
    remat: str = "block"                    # none | block | full
    optimizer: str = "adamw"                # adamw | adafactor
    opt_state_dtype: str = "float32"        # float32 | bfloat16
    grad_acc_dtype: str = "float32"         # microbatch gradient accumulator
    fsdp: bool = False                      # shard params over the data axis too
    num_micro_override: Optional[int] = None  # grad-accum count (None=auto)
    # "tp": megatron-style tensor parallel over 'model' (default)
    # "fsdp_sp": pure FSDP over ALL axes + sequence-parallel activations —
    #            for archs whose head counts don't divide the TP axis
    parallelism: str = "tp"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k shape? (paper-pool rule: only
        SSM / hybrid / sliding-window archs)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.window is not None
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        def attn_params():
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * H * qk          # q down/up
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)          # kv down
                p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                p += H * m.v_head_dim * d                               # out
                return p
            return d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        def mlp_params(dff):
            return 3 * d * dff  # SwiGLU
        def moe_params():
            m = self.moe
            p = d * m.num_experts                                      # router
            p += m.num_experts * mlp_params(m.d_ff_expert)
            p += m.num_shared * mlp_params(m.d_ff_expert)
            return p
        if self.family == "ssm":
            s = self.ssm
            din = s.expand * d
            nh = din // s.head_dim
            per = d * (2 * din + 2 * s.d_state + nh) + din * s.d_conv + din * d + din
            n += L * per
        elif self.family == "hybrid":
            r = self.rglru
            w = r.lru_width or d
            rec = (2 * d * w + w * r.conv_width + 2 * w * w + w + w * d
                   + mlp_params(self.d_ff))
            att = attn_params() + mlp_params(self.d_ff)
            n_rec = L - L // len(r.pattern)  # 2 of 3 (+ tail)
            n_att = L // len(r.pattern)
            n += n_rec * rec + n_att * att
        else:
            per = attn_params() + (moe_params() if self.moe else mlp_params(self.d_ff))
            n += L * per
            if self.encoder_layers:
                n += self.encoder_layers * (attn_params() + mlp_params(self.d_ff))
                n += L * attn_params()  # cross attention in decoder
            if self.mtp_depth:
                n += self.mtp_depth * (2 * d * d + per)
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert
        inactive = self.num_layers * (m.num_experts - m.top_k) * per_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"
    # decode shapes: one new token against a KV cache of seq_len
    microbatch: Optional[int] = None   # per-DP-rank microbatch for grad accum


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2 * len(cfg.rglru.pattern) if cfg.rglru else 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.moe:
        # capacity_factor E/k => capacity == num tokens: no drops, so smoke
        # tests can check exact prefill/decode consistency
        kw["moe"] = replace(cfg.moe, num_experts=4, top_k=2, d_ff_expert=128,
                            capacity_factor=2.0)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.rglru:
        kw["rglru"] = replace(cfg.rglru, lru_width=128, window=64)
        kw["num_layers"] = 2 * len(cfg.rglru.pattern)
    if cfg.window:
        kw["window"] = 64
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 32
    if cfg.num_patches:
        kw["num_patches"] = 16
    return replace(cfg, **kw)
