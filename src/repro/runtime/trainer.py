"""Fault-tolerant training runtime.

Responsibilities (the 1000-node checklist, realised single-process here and
structured so each piece maps 1:1 onto a multi-host deployment):

  * checkpoint/restart — async checkpoints every k steps; restart resumes
    from the latest complete step with an IDENTICAL data stream (the
    pipeline is a pure function of step, see repro.data.pipeline);
  * preemption — SIGTERM/SIGINT install a "save at next step boundary" flag
    (TPU preemption notice pattern);
  * elastic re-scaling — gathered checkpoints restore onto any mesh;
    `DataPipeline.reshard` re-derives each rank's slice;
  * straggler mitigation — a step-time watchdog flags slow steps; the
    mitigation hook re-balances load via the paper's weighted SFC partition
    (`repro.core.placement.target_ranks` over per-rank step-time weights),
    the same algorithm the mesh layer uses for elements;
  * determinism — losses depend only on (seed, step), asserted in tests.
"""

from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data import DataPipeline
from repro.models import init_params
from repro.optim import init_opt_state


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    lr: float = 3e-4
    straggler_factor: float = 2.0   # step slower than factor*median => flagged
    log_path: Optional[str] = None


class StepWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, dt: float):
        self.times.append(dt)
        med = float(np.median(self.times[-50:]))
        if len(self.times) > 5 and dt > self.factor * med:
            self.flagged.append(step)
            return True
        return False

    def rebalance_weights(self, per_rank_times: np.ndarray) -> np.ndarray:
        """SFC-partition weights for straggler-aware re-balancing: ranks that
        run slow get proportionally less work on the next partition pass."""
        from repro.core.placement import target_ranks
        import jax.numpy as jnp
        inv = 1.0 / np.maximum(per_rank_times, 1e-9)
        return np.asarray(target_ranks(jnp.asarray(np.repeat(inv, 8)), len(per_rank_times)))


class Trainer:
    def __init__(self, cfg_model, shape, tcfg: TrainerConfig, *, step_fn,
                 seed: int = 0, dp_size: int = 1):
        self.cfg = cfg_model
        self.shape = shape
        self.tcfg = tcfg
        self.step_fn = step_fn
        self.pipeline = DataPipeline(cfg_model, shape, seed=seed, dp_size=dp_size)
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.watchdog = StepWatchdog(tcfg.straggler_factor)
        self._preempted = False
        self.metrics_log: list[dict] = []

    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def init_or_restore(self, key):
        params = init_params(self.cfg, key)
        opt = init_opt_state(params, self.cfg.optimizer, self.cfg.opt_state_dtype)
        start = 0
        last = latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            (params, opt), manifest = restore_checkpoint(
                self.tcfg.ckpt_dir, (params, opt))
            start = manifest["step"] + 1
        return params, opt, start

    def run(self, key=None):
        self._install_signals()
        key = key if key is not None else jax.random.PRNGKey(0)
        params, opt, start = self.init_or_restore(key)
        log_f = open(self.tcfg.log_path, "a") if self.tcfg.log_path else None
        for step in range(start, self.tcfg.max_steps):
            t0 = time.time()
            batch = self.pipeline.batch(step)
            params, opt, metrics = self.step_fn(
                params, opt, batch, jax.numpy.int32(step))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = self.watchdog.record(step, dt)
            rec = {"step": step, "loss": loss, "dt": dt, "straggler": slow}
            self.metrics_log.append(rec)
            if log_f:
                log_f.write(json.dumps(rec) + "\n")
                log_f.flush()
            if (step + 1) % self.tcfg.ckpt_every == 0 or self._preempted \
                    or step + 1 == self.tcfg.max_steps:
                self.ckpt.save((params, opt), step=step)
            if self._preempted:
                break
        self.ckpt.wait()
        if log_f:
            log_f.close()
        return params, opt, self.metrics_log
