"""deepseek-coder-33b [dense] — llama-architecture GQA decoder.
[arXiv:2401.14196; hf]

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from repro.models.config import ModelConfig

ARCH = "deepseek-coder-33b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=62,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        head_dim=128,
        remat="block",
        fsdp=True,
        # 56 heads / 8 kv-heads don't divide the 16-way model axis: TP makes
        # GSPMD shard head_dim and all-reduce f32 attention scores (see
        # EXPERIMENTS.md Perf iteration 3). Pure-FSDP + sequence parallelism
        # sidesteps head divisibility entirely.
        parallelism="fsdp_sp",
        # 8 microbatches instead of 16: FSDP weight all-gathers scale with
        # the micro count (Perf iteration 4); micro=4 gave the best
        # collective term but peaked at 18.2 GB/dev > 16 GB HBM, micro=8
        # keeps both in budget.
        num_micro_override=8,
    )
