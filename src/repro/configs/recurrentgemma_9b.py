"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, pattern 1 attn : 2
recurrent.  [arXiv:2402.19427; unverified]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
38 = 12 x (rec, rec, attn) + 2 trailing recurrent blocks.
"""

from repro.models.config import ModelConfig, RGLRUConfig

ARCH = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        head_dim=256,
        rglru=RGLRUConfig(lru_width=4096, conv_width=4, window=2048,
                          pattern=("rec", "rec", "attn")),
        remat="block",
        fsdp=True,
    )
