"""pixtral-12b [vlm] — mistral-nemo backbone; the pixtral ViT frontend is a
STUB (input_specs supplies precomputed patch embeddings (B, P, d_model)).
[hf:mistralai/Pixtral-12B-2409; unverified]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
Shapes: seq_len counts patches + text; we use 1024 patch positions.
"""

from repro.models.config import ModelConfig

ARCH = "pixtral-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=131072,
        head_dim=128,
        num_patches=1024,
        rope_theta=1e6,
        remat="block",
        fsdp=True,
    )
