"""whisper-medium [audio] — encoder-decoder; conv/mel frontend is a STUB
(input_specs supplies precomputed frame embeddings (B, 1500, d_model)).
[arXiv:2212.04356; unverified]

24L enc + 24L dec, d_model=1024 16H d_ff=4096 vocab=51865.
Deviation (DESIGN.md): RoPE replaces whisper's learned/sinusoidal positional
embeddings; decode_32k is a stress shape far beyond whisper's 448 positions.
"""

from repro.models.config import ModelConfig

ARCH = "whisper-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="encdec",
        num_layers=24,
        encoder_layers=24,
        encoder_seq=1500,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        remat="block",
    )
