"""Architecture registry + benchmark input specs.

`get_config(arch)` returns the exact assigned ModelConfig; `input_specs`
returns jax.ShapeDtypeStruct stand-ins for every model input of a
(config, shape) cell — weak-type-correct, shardable, no device allocation —
used by the multi-pod dry-run and the roofline harness.

Cell applicability follows the paper-pool rules:
  * long_500k only for sub-quadratic archs (SSM / hybrid / sliding-window);
  * decode shapes use `decode_step` (one token against a seq_len KV cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, reduced

from . import (
    deepseek_v3_671b,
    mixtral_8x7b,
    whisper_medium,
    recurrentgemma_9b,
    mamba2_130m,
    deepseek_coder_33b,
    olmo_1b,
    qwen3_1_7b,
    phi3_mini_3_8b,
    pixtral_12b,
)

_MODULES = [
    deepseek_v3_671b,
    mixtral_8x7b,
    whisper_medium,
    recurrentgemma_9b,
    mamba2_130m,
    deepseek_coder_33b,
    olmo_1b,
    qwen3_1_7b,
    phi3_mini_3_8b,
    pixtral_12b,
]

ARCHS = {m.ARCH: m.config for m in _MODULES}
ARCH_NAMES = list(ARCHS.keys())


def get_config(arch: str) -> ModelConfig:
    return ARCHS[arch]()


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) a runnable cell?  Returns (ok, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (skip: full attention)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for the model inputs of this cell.

    train/prefill: token batch (+ frame/patch embeddings for audio/vlm).
    decode: one token per sequence + scalar position (the KV cache spec is
    produced separately via jax.eval_shape(init_cache, ...)).
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.mode in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), tok),
                "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dtype),
            }
        if cfg.family == "vlm":
            P = min(cfg.num_patches, S // 2)
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), tok),
                "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), dtype),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
    # decode: one new token with a KV cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}


def all_cells():
    """Every (arch, shape) pair with its support status — 40 cells."""
    out = []
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_supported(cfg, s)
            out.append((a, s.name, ok, why))
    return out


__all__ = [
    "ARCHS", "ARCH_NAMES", "get_config", "get_shape", "cell_supported",
    "input_specs", "all_cells", "SHAPES", "reduced",
]
