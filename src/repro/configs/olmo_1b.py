"""olmo-1b [dense] — non-parametric LayerNorm, tied embeddings.
[arXiv:2402.00838; hf]

16L d_model=2048 16H d_ff=8192 vocab=50304.
"""

from repro.models.config import ModelConfig

ARCH = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        nonparametric_norm=True,
        tie_embeddings=True,
        remat="block",
    )
