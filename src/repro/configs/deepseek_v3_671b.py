"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.
[arXiv:2412.19437; hf]

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
Note (DESIGN.md): the real model keeps the first 3 layers dense; we model all
layers as MoE (uniform scan stack).  Training memory uses adafactor +
bf16 states + FSDP — Adam-f32 on 671B params does not fit 256 x 16 GB.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

ARCH = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=2048,
        vocab_size=129280,
        moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1,
                      capacity_factor=1.25),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        mtp_depth=1,
        remat="block",
        optimizer="adafactor",
        opt_state_dtype="bfloat16",
        grad_acc_dtype="bfloat16",
        fsdp=True,
        # ZeRO weight gathers scale with the microbatch count (Perf it. 7):
        # 4 micros instead of 16 quarters the all-gather bytes; the a2a MoE
        # dispatch + seq-parallel residuals keep activations bounded.
        num_micro_override=4,
    )
