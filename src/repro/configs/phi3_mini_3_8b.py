"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + GQA (kv == heads).
[arXiv:2404.14219; unverified]

32L d_model=3072 32H d_ff=8192 vocab=32064.
"""

from repro.models.config import ModelConfig

ARCH = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        remat="block",
    )
