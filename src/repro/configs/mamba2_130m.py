"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

24L d_model=768 vocab=50280, d_state=128, expand=2, head_dim=64.
"""

from repro.models.config import ModelConfig, SSMConfig

ARCH = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=24,          # d_inner / head_dim (bookkeeping only)
        num_kv_heads=24,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        remat="block",
    )
