"""qwen3-1.7b [dense] — qk-norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
"""

from repro.models.config import ModelConfig

ARCH = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=6144,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=True,
        remat="block",
    )
