"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) d_ff(expert)=14336 vocab=32000, window 4096.
"""

from repro.models.config import ModelConfig, MoEConfig

ARCH = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        window=4096,
        rope_theta=1e6,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
        remat="block",
        fsdp=True,
    )
