"""Gradient compression for cross-pod reduction: int8 quantization with
error feedback.

At multi-pod scale the data-parallel all-reduce over the slow pod axis
dominates (see EXPERIMENTS.md roofline): quantizing the pod-axis reduction
payload to int8 (per-block scales) cuts those bytes 4x vs bf16.  Error
feedback carries the quantization residual into the next step, preserving
convergence (Seide et al.; Karimireddy et al.).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array, block: int = BLOCK):
    """x (f32/bf16) -> (int8 payload, f32 per-block scales, pad)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale, pad


def decompress_int8(q, scale, pad, shape, dtype=jnp.float32):
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, *, residual: jax.Array | None = None,
                    block: int = BLOCK):
    """Quantized mean-psum over `axis_name` with error feedback.

    Two-phase, wire-honest scheme: (1) pmax of per-block absmax fixes a
    *shared* scale per block, (2) the int8 payload is psum-ed (as int32
    accumulators; 127 * axis_size stays far below 2^31).  The residual
    x - deq(q) is returned and must be fed back on the next step.
    Returns (mean-reduced value, new residual).
    """
    if residual is not None:
        x = x + residual.astype(x.dtype)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jax.lax.pmax(jnp.max(jnp.abs(flat), axis=1, keepdims=True), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    new_residual = (flat - q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        new_residual = new_residual[:-pad]
    new_residual = new_residual.reshape(x.shape).astype(x.dtype)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) * scale
    out = summed.reshape(-1)
    if pad:
        out = out[:-pad]
    return (out.reshape(x.shape) / n).astype(x.dtype), new_residual
