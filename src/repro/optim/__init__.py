from .optimizers import OptState, adafactor_update, adamw_update, init_opt_state, apply_updates
from .schedule import cosine_schedule
from .compression import compress_int8, decompress_int8, compressed_psum

__all__ = [
    "OptState", "init_opt_state", "adamw_update", "adafactor_update",
    "apply_updates", "cosine_schedule",
    "compress_int8", "decompress_int8", "compressed_psum",
]
