"""Optimizers: AdamW and Adafactor (factored second moment), pytree-native.

Written from scratch (no optax dependency).  State dtype is configurable —
bf16 moments with stochastic-rounding-style scaling keep 671B-class training
inside 16 GB/chip HBM budgets (see DESIGN.md memory table).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment (adamw) or None-like zeros (adafactor w/o momentum)
    nu: Any        # second moment (adamw) | (row, col) factored (adafactor)


def _state_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.float32


def init_opt_state(params, optimizer: str = "adamw", dtype: str = "float32") -> OptState:
    dt = _state_dtype(dtype)
    if optimizer == "adamw":
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dt), params)
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dt), params)
    elif optimizer == "adafactor":
        mu = jax.tree.map(lambda p: jnp.zeros((), dt), params)  # momentum-free

        def factored(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], dt), jnp.zeros(p.shape[:-2] + p.shape[-1:], dt))
            return (jnp.zeros_like(p, dt), jnp.zeros((), dt))
        nu = jax.tree.map(factored, params, is_leaf=lambda x: isinstance(x, jax.Array))
    else:
        raise ValueError(optimizer)
    return OptState(jnp.zeros((), jnp.int32), mu, nu)


def adamw_update(grads, state: OptState, params, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return -lr * u, m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return updates, OptState(step, mu, nu)


def adafactor_update(grads, state: OptState, params, lr, *, decay=0.8,
                     eps=1e-30, clip_threshold=1.0, weight_decay=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        vr, vc = v
        if p.ndim >= 2:
            vr2 = beta * vr.astype(jnp.float32) + (1 - beta) * g2.mean(axis=-1)
            vc2 = beta * vc.astype(jnp.float32) + (1 - beta) * g2.mean(axis=-2)
            r = vr2 / jnp.maximum(vr2.mean(axis=-1, keepdims=True), eps)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc2)[..., None, :])
            new_v = (vr2.astype(vr.dtype), vc2.astype(vc.dtype))
        else:
            vr2 = beta * vr.astype(jnp.float32) + (1 - beta) * g2
            u = g32 / jnp.sqrt(jnp.maximum(vr2, eps))
            new_v = (vr2.astype(vr.dtype), vc)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return -lr * u, new_v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    updates = tdef.unflatten([o[0] for o in outs])
    nu = tdef.unflatten([o[1] for o in outs])
    return updates, OptState(step, state.mu, nu)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), n
