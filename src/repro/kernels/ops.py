"""Jit-ready wrappers around the Pallas SFC kernels: padding, Simplex I/O,
and CPU/TPU dispatch (interpret mode on CPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import u64 as u64m
from repro.core.types import ECLASS_SIMPLEX, Simplex
from . import sfc


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad(n: int, block: int) -> int:
    return (n + block - 1) // block * block


def _fields(s: Simplex):
    d = s.anchor.shape[-1]
    return [s.anchor[..., k] for k in range(d)]


def _padded(arrays, n_pad):
    return [jnp.pad(a, (0, n_pad - a.shape[0])) for a in arrays]


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def morton_key(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
               eclass: int = ECLASS_SIMPLEX) -> u64m.U64:
    """Batch morton keys via the Pallas encode kernel."""
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.stype], np_)
    hi, lo = sfc.morton_key_kernel(d, *arrays, block=block, interpret=_interpret(),
                                   eclass=eclass)
    return u64m.U64(hi[:n], lo[:n])


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def decode(d: int, key: u64m.U64, level, block: int = sfc.DEFAULT_BLOCK,
           eclass: int = ECLASS_SIMPLEX) -> Simplex:
    n = key.hi.shape[0]
    np_ = _pad(n, block)
    hi, lo, lvl = _padded([key.hi, key.lo, jnp.asarray(level, jnp.int32)], np_)
    outs = sfc.decode_kernel(d, hi, lo, lvl, block=block, interpret=_interpret(),
                             eclass=eclass)
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)
    return Simplex(anchor, jnp.asarray(level, jnp.int32), outs[d][:n])


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def face_neighbor(d: int, s: Simplex, face, block: int = sfc.DEFAULT_BLOCK,
                  eclass: int = ECLASS_SIMPLEX):
    n = s.level.shape[0]
    np_ = _pad(n, block)
    face = jnp.broadcast_to(jnp.asarray(face, jnp.int32), (n,))
    arrays = _padded(_fields(s) + [s.level, s.stype, face], np_)
    outs = sfc.face_neighbor_kernel(d, *arrays, block=block, interpret=_interpret(),
                                    eclass=eclass)
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)
    return Simplex(anchor, s.level, outs[d][:n]), outs[d + 1][:n]


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def face_sweep(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
               eclass: int = ECLASS_SIMPLEX):
    """One fused kernel dispatch over ALL nf faces (d+1 simplex, 2d hex):
    returns (neighbor Simplex, dual, inside, key U64), each with a leading
    face axis of length nf (anchor is (nf, n, d))."""
    n = s.level.shape[0]
    nf = sfc.faces_per_element(d, eclass)
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.face_sweep_kernel(d, *arrays, block=block, interpret=_interpret(),
                                 eclass=eclass)
    cut = [o[:n].T for o in outs]  # (nf, n) per field
    anchor = jnp.stack(cut[:d], axis=-1)  # (nf, n, d)
    level = jnp.broadcast_to(s.level, (nf, n))
    nb = Simplex(anchor, level, cut[d])
    return nb, cut[d + 1], cut[d + 2].astype(bool), u64m.U64(cut[d + 3], cut[d + 4])


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def successor(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
              eclass: int = ECLASS_SIMPLEX) -> Simplex:
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.successor_kernel(d, *arrays, block=block, interpret=_interpret(),
                                eclass=eclass)
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)
    return Simplex(anchor, s.level, outs[d][:n])


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def parent_and_local_index(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
                           eclass: int = ECLASS_SIMPLEX):
    """One pass of the fused parent/local-index kernel: (parent, iloc)."""
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.parent_kernel(d, *arrays, block=block, interpret=_interpret(),
                             eclass=eclass)
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)
    return Simplex(anchor, s.level - 1, outs[d][:n]), outs[d + 1][:n]


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def parent(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
           eclass: int = ECLASS_SIMPLEX) -> Simplex:
    return parent_and_local_index(d, s, block, eclass)[0]


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def local_index(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
                eclass: int = ECLASS_SIMPLEX):
    """SFC child index within the parent (second output of the parent kernel)."""
    return parent_and_local_index(d, s, block, eclass)[1]


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def children(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
             eclass: int = ECLASS_SIMPLEX) -> Simplex:
    """All 2^d SFC-ordered children: batch shape (n, 2^d)."""
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.children_kernel(d, *arrays, block=block, interpret=_interpret(),
                               eclass=eclass)
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)  # (n, nc, d)
    nc = 2 ** d
    level = jnp.broadcast_to((s.level + 1)[:, None], (n, nc))
    return Simplex(anchor, level, outs[d][:n])


@functools.partial(jax.jit, static_argnums=(0, 2, 3, 4, 5, 6))
def tree_transform(d: int, s: Simplex, M, c, tmap, block: int = sfc.DEFAULT_BLOCK,
                   eclass: int = ECLASS_SIMPLEX) -> Simplex:
    """Cross-tree coordinate change; M/c/tmap are static per-connection
    tuples (few distinct values per coarse mesh, so jit caching is cheap).
    The body is class-generic (a hex typemap is the single entry (0,), which
    the type LUT maps to 0), so `eclass` only keys the jit cache."""
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.tree_transform_kernel(d, M, c, tmap, *arrays, block=block,
                                     interpret=_interpret())
    anchor = jnp.stack([o[:n] for o in outs[:d]], axis=-1)
    return Simplex(anchor, s.level, outs[d][:n])


@functools.partial(jax.jit, static_argnums=(3,))
def owner_rank(key_u64: u64m.U64, tree, markers, block: int = sfc.DEFAULT_BLOCK):
    """Owner rank per (tree, key) against the padded partition-marker table
    `markers = (marker_tree, marker_key_u64)` via the Pallas searchsorted
    kernel.  Marker arrays must already carry the power-of-two sentinel
    padding (tree = int32 max) — see `repro.core.batch`."""
    mt, mkey = markers
    n = tree.shape[0]
    np_ = _pad(n, block)
    t, hi, lo = _padded(
        [jnp.asarray(tree, jnp.int32), key_u64.hi, key_u64.lo], np_)
    out = sfc.owner_rank_kernel(
        t, hi, lo, jnp.asarray(mt, jnp.int32), mkey.hi, mkey.lo,
        block=block, interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnums=(0, 8))
def eval_route(d: int, tgt, khi, klo, lev, mt, mhi, mlo,
               block: int = sfc.DEFAULT_BLOCK):
    """Fused routing eval via the Pallas kernel: inputs are face-major
    (d+1, n) tiles (n a multiple of `block`) plus the sentinel-padded marker
    arrays; returns (khi64_hi, khi64_lo, first, last) in the same (d+1, n)
    layout.  The kernel runs element-major, so transpose in and out."""
    outs = sfc.eval_route_kernel(
        d, tgt.T, khi.T, klo.T, lev.T, mt, mhi, mlo,
        block=block, interpret=_interpret())
    return tuple(o.T for o in outs)


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def is_inside_root(d: int, s: Simplex, block: int = sfc.DEFAULT_BLOCK,
                   eclass: int = ECLASS_SIMPLEX):
    n = s.level.shape[0]
    np_ = _pad(n, block)
    arrays = _padded(_fields(s) + [s.level, s.stype], np_)
    outs = sfc.inside_root_kernel(d, *arrays, block=block, interpret=_interpret(),
                                  eclass=eclass)
    return outs[0][:n].astype(bool)
