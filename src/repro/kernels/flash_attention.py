"""Pallas TPU flash attention: fused causal attention with online softmax.

This is the kernel the roofline's "fused attention" variant models
(EXPERIMENTS.md §Perf iteration 5): scores/probabilities never leave VMEM —
HBM traffic is exactly q/k/v in + o out.

Layout/grid:
  grid = (B, H, S // block_q); each program owns one q block of one head.
  q block  : (block_q, hd) VMEM tile
  k/v      : the full (S, hd) stripe of the matching KV head in VMEM —
             fine for S*hd*4 bytes <= a few MB (S <= 8k at hd 128); longer
             sequences add a k-block grid dimension with VMEM accumulators.
  online softmax state (m, l, acc) lives in registers/VMEM.

GQA: the BlockSpec index map sends query head h to KV head h // (H // KV),
so grouped heads share the same k/v stripe without materialised repeats.

Validated against `repro.kernels.ref.flash_attention_ref` in interpret mode
(CPU) across shapes/dtypes; `repro.models.layers` uses the same math in its
pure-XLA blocked implementation (exactness cross-checked in tests).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_body(s_len: int, block_q: int, block_k: int, causal: bool,
                window, scale: float, *refs):
    q_ref, k_ref, v_ref, o_ref = refs
    i = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
    bq, hd = q.shape
    nkb = s_len // block_k

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, hd), jnp.float32)
    qpos = i * block_q + jax.lax.iota(jnp.int32, bq)

    for j in range(nkb):                                # static unroll
        k = k_ref[0, j * block_k:(j + 1) * block_k, 0, :].astype(jnp.float32)
        v = v_ref[0, j * block_k:(j + 1) * block_k, 0, :].astype(jnp.float32)
        s = q @ k.T * scale                             # (bq, bk) on the MXU
        kpos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + p @ v
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q (B,S,H,hd); k,v (B,S,KV,hd), H % KV == 0. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % block_q == 0 and S % block_k == 0
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, S // block_q)
    return pl.pallas_call(
        functools.partial(_flash_body, S, block_q, block_k, causal, window, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // G, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
