"""Pallas TPU kernels for the SFC hot loops: encode / decode / neighbor / successor.

These are the compute hot-spots of the paper's AMR pipeline (New and Adapt
spend essentially all their time computing consecutive indices, decoding
them, and finding face neighbors — paper Sections 4.5-4.6).

TPU adaptation (vs. the paper's scalar C):
  * Elements are processed in VMEM tiles of BLOCK lanes; each field (x, y, z,
    level, type) is its own int32 vector — SoA keeps loads contiguous and
    VPU-friendly (8x128 lanes).
  * The (cube-id, type) transition tables are *fused into the instruction
    stream* as masked-sum lookups over <= 48 packed constants per level —
    TPUs have no per-lane gather, so table lookups become compare/select
    chains on vregs, which the VPU executes at full width.
  * The 64-bit consecutive index is carried as two uint32 words (TPU vector
    units have no 64-bit integer type); see `repro.core.u64`.
  * Level loops are fully unrolled (MAXLEVEL is a compile-time constant), so
    the kernel body is straight-line vector code with static shifts.

Each kernel has a pure-jnp oracle in `repro.kernels.ref` (delegating to
`repro.core.ops`), and `repro.kernels.ops` wraps them with padding + jit.
On CPU (this container) the kernels run under `interpret=True`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import u64 as u64m
from repro.core.tables import MAXLEVEL, get_tables
from repro.core.types import ECLASS_HEX, ECLASS_SIMPLEX

DEFAULT_BLOCK = 1024


def faces_per_element(d: int, eclass: int = ECLASS_SIMPLEX) -> int:
    """The per-class face count that sizes face-sweep / eval-route tiles."""
    return 2 * d if eclass == ECLASS_HEX else d + 1


# ----------------------------------------------------------- packed tables
@functools.lru_cache(maxsize=None)
def _packed_tables(d: int):
    t = get_tables(d)
    nc, nt = t.num_children, t.num_types
    enc = [0] * (nt * nc)   # idx = b * nc + cid -> iloc | parent_type << 3
    dec = [0] * (nt * nc)   # idx = b * nc + iloc -> cid | child_type << 3
    nei = [0] * (nt * (d + 1))  # idx = b*(d+1)+f -> type | dual<<3 | (off+1) 2b each
    for b in range(nt):
        for cid in range(nc):
            iloc = int(t.local_index[cid, b])
            pb = int(t.parent_type[cid, b])
            enc[b * nc + cid] = iloc | (pb << 3)
        for iloc in range(nc):
            cid = int(t.cube_id_of_local[b, iloc])
            ct = int(t.type_of_local[b, iloc])
            dec[b * nc + iloc] = cid | (ct << 3)
        for f in range(d + 1):
            v = int(t.neighbor_type[b, f]) | (int(t.neighbor_face[b, f]) << 3)
            for k in range(d):
                v |= (int(t.neighbor_offset[b, f, k]) + 1) << (6 + 2 * k)
            nei[b * (d + 1) + f] = v
    return tuple(enc), tuple(dec), tuple(nei)


def _lut(consts, idx):
    """Masked-sum lookup: TPU-idiomatic replacement for per-lane gather."""
    acc = jnp.zeros(idx.shape, jnp.int32)
    for k, v in enumerate(consts):
        if v:
            acc = acc + jnp.where(idx == k, jnp.int32(v), 0)
    return acc


@functools.lru_cache(maxsize=None)
def _packed_hex_nei(d: int):
    """Hex face-neighbor constants: idx = f -> dual<<3 | (off+1) 2b per axis
    (type bits stay 0 — hexes have no types)."""
    nei = [0] * (2 * d)
    for f in range(2 * d):
        v = (f ^ 1) << 3
        for k in range(d):
            off = (2 * (f % 2) - 1) if k == f // 2 else 0
            v |= (off + 1) << (6 + 2 * k)
        nei[f] = v
    return tuple(nei)


# ---------------------------------------------------- shared body expressions
# The per-op kernel bodies below and the fused face-sweep body compose these
# pure vreg->vreg expressions; keeping them shared means the fused kernel can
# never drift from the single-op kernels it replaces.  Each simplex
# expression has a hex twin (plain Morton: no type chain, axis-aligned
# neighbors, box containment) selected statically by the bodies' `eclass`.
def _encode_expr(d: int, coords, b):
    """morton key (level-padded consecutive index) from Tet-id -> (hi, lo)."""
    L = MAXLEVEL[d]
    enc, _, _ = _packed_tables(d)
    nc = 2 ** d
    hi = jnp.zeros(b.shape, jnp.uint32)
    lo = jnp.zeros(b.shape, jnp.uint32)
    for i in range(L, 0, -1):  # fine -> coarse; positions are independent
        cid = jnp.zeros(b.shape, jnp.int32)
        for k, c in enumerate(coords):
            cid = cid | (((c >> (L - i)) & 1) << k)
        packed = _lut(enc, b * nc + cid)
        iloc = (packed & 7).astype(jnp.uint32)
        b = packed >> 3
        pos = d * (L - i)
        if pos < 32:
            lo = lo | (iloc << pos)
            if pos + d > 32:  # digit straddles the word boundary
                hi = hi | (iloc >> (32 - pos))
        else:
            hi = hi | (iloc << (pos - 32))
    return hi, lo


def _neighbor_expr(d: int, coords, lvl, b, f):
    """Same-level face neighbor (Algorithm 4.6) -> (coords', type', dual).
    `f` is a face-index vreg or a static Python int (the fused sweep unrolls
    it statically)."""
    L = MAXLEVEL[d]
    _, _, nei = _packed_tables(d)
    h = (jnp.int32(1) << (L - lvl)).astype(jnp.int32)
    packed = _lut(nei, b * (d + 1) + f)
    out = []
    for k in range(d):
        off = ((packed >> (6 + 2 * k)) & 3) - 1
        out.append(coords[k] + off * h)
    return out, packed & 7, (packed >> 3) & 7


def _inside_expr(d: int, coords, lvl, b):
    """Constant-time inside-root test (Proposition 23 with T = root, type 0)
    -> int32 0/1 mask.  The axis permutation and boundary type sets collapse
    to per-type constants baked into the instruction stream."""
    L = MAXLEVEL[d]
    t = get_tables(d)
    p = tuple(int(v) for v in t.outside_perm[0])
    KJ = tuple(int(v) for v in t.outside_types_kj[0])
    IK = tuple(int(v) for v in t.outside_types_ik[0])
    DIAG = tuple(int(v) for v in t.outside_types_diag[0])
    ht = jnp.int32(1 << L)
    ai = coords[p[0]]
    aj = coords[p[1]]
    at_root = (lvl == 0) & (b == 0)
    for c in coords:
        at_root = at_root & (c == 0)
    if d == 2:
        inside = (aj >= 0) & (ai < ht) & (aj <= ai)
        ok_diag = _lut(KJ, b) == 0
        inside = inside & ((aj != ai) | ok_diag)
    else:
        ak = coords[p[2]]
        inside = (aj >= 0) & (ai < ht) & (ak <= ai) & (aj <= ak)
        eq_ik = ak == ai
        eq_kj = aj == ak
        ok_ik = _lut(IK, b) == 0
        ok_kj = _lut(KJ, b) == 0
        ok_diag = _lut(DIAG, b) == 0
        ok = jnp.where(
            eq_ik & eq_kj, ok_diag, jnp.where(eq_ik, ok_ik, jnp.where(eq_kj, ok_kj, True))
        )
        inside = inside & ok
    return (at_root | ((lvl > 0) & inside)).astype(jnp.int32)


def _hex_encode_expr(d: int, coords):
    """Hex twin of `_encode_expr`: the plain Morton interleave — no type
    chain, each level's digit is the raw cube id -> (hi, lo)."""
    L = MAXLEVEL[d]
    hi = jnp.zeros(coords[0].shape, jnp.uint32)
    lo = jnp.zeros(coords[0].shape, jnp.uint32)
    for i in range(L, 0, -1):
        cid = jnp.zeros(coords[0].shape, jnp.int32)
        for k, c in enumerate(coords):
            cid = cid | (((c >> (L - i)) & 1) << k)
        digit = cid.astype(jnp.uint32)
        pos = d * (L - i)
        if pos < 32:
            lo = lo | (digit << pos)
            if pos + d > 32:  # digit straddles the word boundary
                hi = hi | (digit >> (32 - pos))
        else:
            hi = hi | (digit << (pos - 32))
    return hi, lo


def _hex_neighbor_expr(d: int, coords, lvl, f):
    """Hex twin of `_neighbor_expr`: neighbor across face f = 2*axis + dir
    is one cube side away along `axis`; dual = f ^ 1.  `f` is a face vreg or
    a static int (the fused sweep unrolls it)."""
    L = MAXLEVEL[d]
    nei = _packed_hex_nei(d)
    h = (jnp.int32(1) << (L - lvl)).astype(jnp.int32)
    packed = _lut(nei, f) if not isinstance(f, int) else jnp.full(lvl.shape, nei[f], jnp.int32)
    out = []
    for k in range(d):
        off = ((packed >> (6 + 2 * k)) & 3) - 1
        out.append(coords[k] + off * h)
    return out, (packed >> 3) & 7


def _hex_inside_expr(d: int, coords, lvl):
    """Hex twin of `_inside_expr`: box containment in the root cube —
    anchor in [0, 2^L - h] per axis (the upper bound is h-shifted so the
    compare never overflows int32 at level 0)."""
    L = MAXLEVEL[d]
    h = (jnp.int32(1) << (L - lvl)).astype(jnp.int32)
    lim = jnp.int32(1 << L) - h
    inside = lvl >= 0
    for c in coords:
        inside = inside & (c >= 0) & (c <= lim)
    return inside.astype(jnp.int32)


# ------------------------------------------------------------ kernel bodies
def _encode_body(d: int, eclass: int, refs):
    """morton key (level-padded consecutive index) from the element id."""
    if d == 3:
        x_ref, y_ref, z_ref, b_ref, hi_ref, lo_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
    else:
        x_ref, y_ref, b_ref, hi_ref, lo_ref = refs
        coords = (x_ref[...], y_ref[...])
    if eclass == ECLASS_HEX:
        hi_ref[...], lo_ref[...] = _hex_encode_expr(d, coords)
    else:
        hi_ref[...], lo_ref[...] = _encode_expr(d, coords, b_ref[...])


def _decode_body(d: int, eclass: int, refs):
    """Element id from morton key (level implied by trailing zero digits is
    NOT recovered here; the caller supplies it and we mask fine digits)."""
    L = MAXLEVEL[d]
    _, dec, _ = _packed_tables(d)
    nc = 2 ** d
    if d == 3:
        hi_ref, lo_ref, lvl_ref, x_ref, y_ref, z_ref, b_ref = refs
        nout = 3
    else:
        hi_ref, lo_ref, lvl_ref, x_ref, y_ref, b_ref = refs
        nout = 2
    hi = hi_ref[...]
    lo = lo_ref[...]
    lvl = lvl_ref[...]
    b = jnp.zeros(hi.shape, jnp.int32)
    xyz = [jnp.zeros(hi.shape, jnp.int32) for _ in range(nout)]
    for i in range(1, L + 1):
        pos = d * (L - i)
        if pos >= 32:
            digit = (hi >> (pos - 32)) & np.uint32(nc - 1)
        elif pos + d > 32:
            digit = ((lo >> pos) | (hi << (32 - pos))) & np.uint32(nc - 1)
        else:
            digit = (lo >> pos) & np.uint32(nc - 1)
        iloc = jnp.where(i <= lvl, digit.astype(jnp.int32), 0)
        if eclass == ECLASS_HEX:
            cid = iloc  # plain Morton: the digit IS the cube id
        else:
            packed = _lut(dec, b * nc + iloc)
            cid = packed & 7
            b = jnp.where(i <= lvl, packed >> 3, b)
        for k in range(nout):
            xyz[k] = xyz[k] | (((cid >> k) & 1) << (L - i))
    x_ref[...] = xyz[0]
    y_ref[...] = xyz[1]
    if d == 3:
        z_ref[...] = xyz[2]
    b_ref[...] = b


def _neighbor_body(d: int, eclass: int, refs):
    """Same-level face neighbor (Algorithm 4.6): single pass, no level loop."""
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, f_ref, ox_ref, oy_ref, oz_ref, ob_ref, of_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
        outs = (ox_ref, oy_ref, oz_ref)
    else:
        x_ref, y_ref, lvl_ref, b_ref, f_ref, ox_ref, oy_ref, ob_ref, of_ref = refs
        coords = (x_ref[...], y_ref[...])
        outs = (ox_ref, oy_ref)
    if eclass == ECLASS_HEX:
        ncoords, dual = _hex_neighbor_expr(d, coords, lvl_ref[...], f_ref[...])
        ntype = jnp.zeros(dual.shape, jnp.int32)
    else:
        ncoords, ntype, dual = _neighbor_expr(d, coords, lvl_ref[...], b_ref[...], f_ref[...])
    for k in range(d):
        outs[k][...] = ncoords[k]
    ob_ref[...] = ntype
    of_ref[...] = dual


def _face_sweep_body(d: int, eclass: int, refs):
    """Fused per-element face sweep: for ALL nf faces at once (d+1 simplex,
    2d hex), the same-level neighbor (coords/type/dual), its inside-root
    mask, and its morton key — the three ops Balance/Ghost evaluation
    composes per face, with the element's (anchor, level, type) read from
    memory exactly once.  The face loop is a static unroll, so the body
    stays straight-line vector code; each output is a (block, nf) tile (one
    column per face, like the children kernel)."""
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref = refs[:5]
        coords = (x_ref[...], y_ref[...], z_ref[...])
    else:
        x_ref, y_ref, lvl_ref, b_ref = refs[:4]
        coords = (x_ref[...], y_ref[...])
    out_refs = refs[d + 2:]  # d coord outs, type, dual, inside, hi, lo
    lvl = lvl_ref[...]
    b = b_ref[...]
    cols = [[] for _ in range(len(out_refs))]
    for f in range(faces_per_element(d, eclass)):
        if eclass == ECLASS_HEX:
            ncoords, dual = _hex_neighbor_expr(d, coords, lvl, f)
            ntype = jnp.zeros(lvl.shape, jnp.int32)
            inside = _hex_inside_expr(d, ncoords, lvl)
            hi, lo = _hex_encode_expr(d, ncoords)
        else:
            ncoords, ntype, dual = _neighbor_expr(d, coords, lvl, b, f)
            inside = _inside_expr(d, ncoords, lvl, ntype)
            hi, lo = _encode_expr(d, ncoords, ntype)
        for k in range(d):
            cols[k].append(ncoords[k])
        cols[d].append(ntype)
        cols[d + 1].append(dual)
        cols[d + 2].append(inside)
        cols[d + 3].append(hi)
        cols[d + 4].append(lo)
    for ref, col in zip(out_refs, cols):
        ref[...] = jnp.stack(col, axis=-1)


def _successor_body(d: int, eclass: int, refs):
    """Fused successor: encode -> +1 at own level -> decode (Algorithm 4.10).
    The hex path skips the type-chain lookups on both sides (digit = cube
    id) but shares the carry chain."""
    L = MAXLEVEL[d]
    enc, dec, _ = _packed_tables(d)
    nc = 2 ** d
    is_hex = eclass == ECLASS_HEX
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, ox_ref, oy_ref, oz_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
        nout = 3
        outs = (ox_ref, oy_ref, oz_ref)
    else:
        x_ref, y_ref, lvl_ref, b_ref, ox_ref, oy_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...])
        nout = 2
        outs = (ox_ref, oy_ref)
    lvl = lvl_ref[...]
    b = b_ref[...]
    # --- encode iloc digits per level (store unrolled) ---
    ilocs = [None] * (L + 1)
    bb = b
    for i in range(L, 0, -1):
        cid = jnp.zeros(b.shape, jnp.int32)
        for k, c in enumerate(coords):
            cid = cid | (((c >> (L - i)) & 1) << k)
        if is_hex:
            ilocs[i] = cid
        else:
            packed = _lut(enc, bb * nc + cid)
            ilocs[i] = packed & 7
            bb = packed >> 3
    # --- +1 with carry starting at own level (digits below lvl are zero) ---
    carry = jnp.ones(b.shape, jnp.int32)
    new_ilocs = [None] * (L + 1)
    for i in range(L, 0, -1):
        active = (i <= lvl)
        s = ilocs[i] + jnp.where(active, carry, 0)
        new_ilocs[i] = jnp.where(active, s % nc, ilocs[i])
        carry = jnp.where(active, s // nc, carry)
    # --- decode from new digits (coarse -> fine) ---
    bo = jnp.zeros(b.shape, jnp.int32)
    xyz = [jnp.zeros(b.shape, jnp.int32) for _ in range(nout)]
    for i in range(1, L + 1):
        iloc = jnp.where(i <= lvl, new_ilocs[i], 0)
        if is_hex:
            cid = iloc
        else:
            packed = _lut(dec, bo * nc + iloc)
            cid = packed & 7
            bo = jnp.where(i <= lvl, packed >> 3, bo)
        for k in range(nout):
            xyz[k] = xyz[k] | (((cid >> k) & 1) << (L - i))
    for k in range(nout):
        outs[k][...] = xyz[k]
    ob_ref[...] = bo


def _parent_body(d: int, eclass: int, refs):
    """Parent id (Algorithm 4.3) + local index (paper Table 6), fused:
    one cube-id extraction feeds both lookups via the packed `enc` table.
    For hexes the cube id IS the local index and the parent type is 0."""
    L = MAXLEVEL[d]
    enc, _, _ = _packed_tables(d)
    nc = 2 ** d
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, ox_ref, oy_ref, oz_ref, ob_ref, oi_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
        outs = (ox_ref, oy_ref, oz_ref)
    else:
        x_ref, y_ref, lvl_ref, b_ref, ox_ref, oy_ref, ob_ref, oi_ref = refs
        coords = (x_ref[...], y_ref[...])
        outs = (ox_ref, oy_ref)
    lvl = lvl_ref[...]
    b = b_ref[...]
    h = (jnp.int32(1) << (L - lvl)).astype(jnp.int32)
    cid = jnp.zeros(b.shape, jnp.int32)
    for k, c in enumerate(coords):
        cid = cid | jnp.where((c & h) != 0, jnp.int32(1 << k), 0)
    for k, c in enumerate(coords):
        outs[k][...] = c & ~h
    if eclass == ECLASS_HEX:
        ob_ref[...] = jnp.zeros(b.shape, jnp.int32)
        oi_ref[...] = cid
    else:
        packed = _lut(enc, b * nc + cid)
        ob_ref[...] = packed >> 3
        oi_ref[...] = packed & 7


def _children_body(d: int, eclass: int, refs):
    """All 2^d children in SFC order (Algorithm 4.5; plain Morton order for
    hexes), one (block, 2^d) tile per output field."""
    L = MAXLEVEL[d]
    _, dec, _ = _packed_tables(d)
    nc = 2 ** d
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, ox_ref, oy_ref, oz_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
        outs = (ox_ref, oy_ref, oz_ref)
    else:
        x_ref, y_ref, lvl_ref, b_ref, ox_ref, oy_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...])
        outs = (ox_ref, oy_ref)
    lvl = lvl_ref[...]
    b = b_ref[...]
    h2 = ((jnp.int32(1) << (L - lvl)) >> 1).astype(jnp.int32)
    cols = [[] for _ in range(d)]
    type_cols = []
    for iloc in range(nc):
        if eclass == ECLASS_HEX:
            cid = jnp.full(b.shape, iloc, jnp.int32)
            type_cols.append(jnp.zeros(b.shape, jnp.int32))
        else:
            packed = _lut(dec, b * nc + iloc)
            cid = packed & 7
            type_cols.append(packed >> 3)
        for k, c in enumerate(coords):
            cols[k].append(c + h2 * ((cid >> k) & 1))
    for k in range(d):
        outs[k][...] = jnp.stack(cols[k], axis=-1)
    ob_ref[...] = jnp.stack(type_cols, axis=-1)


def _tree_transform_body(d: int, M, c, tmap, refs):
    """Cross-tree coordinate change (cmesh gluing): anchor' = M @ anchor + c
    minus h on reflected axes, type through the d!-entry typemap.  M / c /
    tmap are per-connection compile-time constants (a handful per coarse
    mesh, each tiny), so the body is straight-line vector code; the signed
    permutation turns the matmul into one lane copy (+ negate) per axis."""
    L = MAXLEVEL[d]
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, ox_ref, oy_ref, oz_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
        outs = (ox_ref, oy_ref, oz_ref)
    else:
        x_ref, y_ref, lvl_ref, b_ref, ox_ref, oy_ref, ob_ref = refs
        coords = (x_ref[...], y_ref[...])
        outs = (ox_ref, oy_ref)
    lvl = lvl_ref[...]
    b = b_ref[...]
    h = (jnp.int32(1) << (L - lvl)).astype(jnp.int32)
    for k in range(d):
        (ax,) = [j for j in range(d) if M[k][j] != 0]
        if M[k][ax] > 0:
            outs[k][...] = coords[ax] + jnp.int32(c[k])
        else:
            outs[k][...] = jnp.int32(c[k]) - coords[ax] - h
    ob_ref[...] = _lut(tmap, b)


def _owner_rank_body(num_markers: int, refs):
    """Owner-rank resolution against the partition-marker table: the rank of
    key (t, k) is the index of the last marker lex-<= (t, k), clamped to 0 —
    a vectorized searchsorted.  The marker table (one entry per rank, padded
    to a power of two with +inf sentinels) is tiny and identical for every
    lane, so the P-entry scan is unrolled into straight-line compare/add
    vector code; the uint64 keys are carried as (hi, lo) uint32 pairs."""
    t_ref, hi_ref, lo_ref, mt_ref, mhi_ref, mlo_ref, o_ref = refs
    t, hi, lo = t_ref[...], hi_ref[...], lo_ref[...]
    mt, mhi, mlo = mt_ref[...], mhi_ref[...], mlo_ref[...]
    o_ref[...] = _owner_count_expr(num_markers, t, hi, lo, mt, mhi, mlo)


def _owner_count_expr(num_markers: int, t, hi, lo, mt, mhi, mlo):
    """The unrolled marker-scan expression shared by `owner_rank_kernel` and
    the fused `eval_route_kernel` (single-op and fused paths cannot drift)."""
    count = jnp.zeros(t.shape, jnp.int32)
    for k in range(num_markers):
        le = (mt[k] < t) | (
            (mt[k] == t) & ((mhi[k] < hi) | ((mhi[k] == hi) & (mlo[k] <= lo)))
        )
        count = count + le.astype(jnp.int32)
    return jnp.maximum(count - 1, 0)


def _eval_route_body(d: int, num_markers: int, refs):
    """Fused Balance/Ghost routing eval over a (block, d+1) face tile: the
    neighbor interval's last key (key | span-1, uint64 as two uint32 words
    via an O(log) select mask — keys are span-aligned) and the [first, last]
    owner-rank range of the interval against the marker table."""
    L = MAXLEVEL[d]
    (t_ref, hi_ref, lo_ref, lvl_ref, mt_ref, mhi_ref, mlo_ref,
     ohhi_ref, ohlo_ref, ofirst_ref, olast_ref) = refs
    t, hi, lo, lvl = t_ref[...], hi_ref[...], lo_ref[...], lvl_ref[...]
    mt, mhi, mlo = mt_ref[...], mhi_ref[...], mlo_ref[...]
    sb = d * (L - lvl)
    one = u64m.U64(jnp.zeros_like(hi), jnp.full_like(lo, 1))
    mask = u64m.dec(u64m.select_shl(one, sb, 63))
    kh = u64m.or_(u64m.U64(hi, lo), mask)
    ohhi_ref[...] = kh.hi
    ohlo_ref[...] = kh.lo
    ofirst_ref[...] = _owner_count_expr(num_markers, t, hi, lo, mt, mhi, mlo)
    olast_ref[...] = _owner_count_expr(num_markers, t, kh.hi, kh.lo, mt, mhi, mlo)


def _inside_body(d: int, eclass: int, refs):
    """Constant-time inside-root test (Proposition 23 with T = root, type 0;
    box containment for hexes)."""
    if d == 3:
        x_ref, y_ref, z_ref, lvl_ref, b_ref, o_ref = refs
        coords = (x_ref[...], y_ref[...], z_ref[...])
    else:
        x_ref, y_ref, lvl_ref, b_ref, o_ref = refs
        coords = (x_ref[...], y_ref[...])
    if eclass == ECLASS_HEX:
        o_ref[...] = _hex_inside_expr(d, coords, lvl_ref[...])
    else:
        o_ref[...] = _inside_expr(d, coords, lvl_ref[...], b_ref[...])


# --------------------------------------------------------------- pallas_call
def _specs(n_in, n_out, block):
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return [spec] * n_in, [spec] * n_out


def morton_key_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                      eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), type — int32, shape (N,) with N % block == 0.
    Returns (hi, lo) uint32 morton keys."""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), 2, block)
    return pl.pallas_call(
        lambda *refs: _encode_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32)] * 2,
        interpret=interpret,
    )(*arrays)


def decode_kernel(d: int, hi, lo, level, block: int = DEFAULT_BLOCK, interpret: bool = True,
                  eclass: int = ECLASS_SIMPLEX):
    """Returns x, y, (z,), type from morton keys + level."""
    n = hi.shape[0]
    in_specs, out_specs = _specs(3, d + 1, block)
    return pl.pallas_call(
        lambda *refs: _decode_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * (d + 1),
        interpret=interpret,
    )(hi, lo, level)


def face_neighbor_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                         eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type, face — int32 (N,).
    Returns x, y, (z,), type, dual_face of the same-level neighbor."""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), d + 2, block)
    return pl.pallas_call(
        lambda *refs: _neighbor_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * (d + 2),
        interpret=interpret,
    )(*arrays)


def face_sweep_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                      eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type — int32 (N,) with N % block == 0.
    One fused dispatch over ALL nf faces (d+1 simplex, 2d hex): returns
    x, y, (z,), type, dual, inside, key_hi, key_lo of every same-level face
    neighbor, each output a (N, nf) tile with one column per face.
    key_hi/lo are uint32 morton-key words; inside is an int32 0/1 mask."""
    n = arrays[0].shape[0]
    nf = faces_per_element(d, eclass)
    in_specs, _ = _specs(len(arrays), 0, block)
    out_spec = pl.BlockSpec((block, nf), lambda i: (i, 0))
    n_out = d + 3  # coords, type, dual, inside (+ hi, lo below)
    return pl.pallas_call(
        lambda *refs: _face_sweep_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=[out_spec] * (n_out + 2),
        out_shape=[jax.ShapeDtypeStruct((n, nf), jnp.int32)] * n_out
        + [jax.ShapeDtypeStruct((n, nf), jnp.uint32)] * 2,
        interpret=interpret,
    )(*arrays)


def parent_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                  eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type — int32 (N,).
    Returns x, y, (z,), type of the parent plus the element's SFC local index
    (the parent's level is the caller's `level - 1`)."""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), d + 2, block)
    return pl.pallas_call(
        lambda *refs: _parent_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * (d + 2),
        interpret=interpret,
    )(*arrays)


def children_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                    eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type — int32 (N,).
    Returns x, y, (z,), type of all 2^d SFC-ordered children, each (N, 2^d)."""
    n = arrays[0].shape[0]
    nc = 2 ** d
    in_specs, _ = _specs(len(arrays), 0, block)
    out_spec = pl.BlockSpec((block, nc), lambda i: (i, 0))
    return pl.pallas_call(
        lambda *refs: _children_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=[out_spec] * (d + 1),
        out_shape=[jax.ShapeDtypeStruct((n, nc), jnp.int32)] * (d + 1),
        interpret=interpret,
    )(*arrays)


def inside_root_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                       eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type — int32 (N,).
    Returns an int32 0/1 mask: does the element lie inside the root?"""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), 1, block)
    return pl.pallas_call(
        lambda *refs: _inside_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(*arrays)


def tree_transform_kernel(d: int, M, c, tmap, *arrays,
                          block: int = DEFAULT_BLOCK, interpret: bool = True):
    """arrays: x, y, (z,), level, type — int32 (N,).  M/c/tmap are the
    per-connection gluing constants as nested int tuples (c pre-wrapped to
    int32, see repro.core.cmesh.wrap_i32).
    Returns x, y, (z,), type of the elements in the neighbor tree's frame."""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), d + 1, block)
    return pl.pallas_call(
        lambda *refs: _tree_transform_body(d, M, c, tmap, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * (d + 1),
        interpret=interpret,
    )(*arrays)


def owner_rank_kernel(t, hi, lo, mt, mhi, mlo,
                      block: int = DEFAULT_BLOCK, interpret: bool = True):
    """t/hi/lo: element tree + key words, int32/uint32 (N,) with N % block == 0.
    mt/mhi/mlo: partition-marker tree + key words (P,), sorted, padded with
    tree = int32 max sentinels.  Returns the int32 owner rank per element."""
    n = t.shape[0]
    num_markers = mt.shape[0]
    spec = pl.BlockSpec((block,), lambda i: (i,))
    mspec = pl.BlockSpec((num_markers,), lambda i: (0,))
    return pl.pallas_call(
        lambda *refs: _owner_rank_body(num_markers, refs),
        grid=(n // block,),
        in_specs=[spec] * 3 + [mspec] * 3,
        out_specs=[spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(t, hi, lo, mt, mhi, mlo)[0]


def eval_route_kernel(d: int, t, hi, lo, lvl, mt, mhi, mlo,
                      block: int = DEFAULT_BLOCK, interpret: bool = True):
    """t/hi/lo/lvl: per-(element, face) target tree, neighbor key words and
    element level, each a (N, nf) tile with N % block == 0 (nf is read off
    the input tile, so both element classes share this body).  mt/mhi/mlo:
    sentinel-padded partition markers (P,).  Returns (khi64_hi, khi64_lo,
    first, last): the interval-end key words (uint32) and the owner-rank
    range (int32) per pair, each (N, nf)."""
    n = t.shape[0]
    nf = t.shape[1]
    num_markers = mt.shape[0]
    spec = pl.BlockSpec((block, nf), lambda i: (i, 0))
    mspec = pl.BlockSpec((num_markers,), lambda i: (0,))
    return pl.pallas_call(
        lambda *refs: _eval_route_body(d, num_markers, refs),
        grid=(n // block,),
        in_specs=[spec] * 4 + [mspec] * 3,
        out_specs=[spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((n, nf), jnp.uint32)] * 2
        + [jax.ShapeDtypeStruct((n, nf), jnp.int32)] * 2,
        interpret=interpret,
    )(t, hi, lo, lvl, mt, mhi, mlo)


def successor_kernel(d: int, *arrays, block: int = DEFAULT_BLOCK, interpret: bool = True,
                     eclass: int = ECLASS_SIMPLEX):
    """arrays: x, y, (z,), level, type — int32 (N,).
    Returns x, y, (z,), type of the SFC successor at the same level."""
    n = arrays[0].shape[0]
    in_specs, out_specs = _specs(len(arrays), d + 1, block)
    return pl.pallas_call(
        lambda *refs: _successor_body(d, eclass, refs),
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * (d + 1),
        interpret=interpret,
    )(*arrays)
