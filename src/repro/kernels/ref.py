"""Pure-jnp oracles for the Pallas SFC kernels (delegate to repro.core.ops)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import u64 as u64m
from repro.core.ops import get_ops
from repro.core.types import ECLASS_SIMPLEX, Simplex


def _simplex(d, *arrays):
    if d == 3:
        x, y, z, level, stype = arrays
        anchor = jnp.stack([x, y, z], axis=-1)
    else:
        x, y, level, stype = arrays
        anchor = jnp.stack([x, y], axis=-1)
    return Simplex(anchor, level, stype)


def morton_key_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    """x, y, (z,), type -> (hi, lo).  Level plays no role in the padded key
    (trailing digits of the T_0-chain are zero), so we evaluate at MAXLEVEL."""
    o = get_ops(d, eclass)
    coords, stype = arrays[:-1], arrays[-1]
    level = jnp.full(stype.shape, o.L, jnp.int32)
    key = o.morton_key(_simplex(d, *coords, level, stype))
    return key.hi, key.lo


def decode_ref(d, hi, lo, level, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    s = o.decode_key(u64m.U64(hi, lo), level)
    outs = [s.anchor[..., k] for k in range(d)]
    return (*outs, s.stype)


def parent_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    s = _simplex(d, *arrays)
    p = o.parent(s)
    outs = [p.anchor[..., k] for k in range(d)]
    return (*outs, p.stype, o.local_index(s))


def children_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    kids = o.children_tm(_simplex(d, *arrays))  # (..., nc) batch
    outs = [kids.anchor[..., k] for k in range(d)]
    return (*outs, kids.stype)


def is_inside_root_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    return o.is_inside_root(_simplex(d, *arrays))


def face_neighbor_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    *fields, face = arrays
    o = get_ops(d, eclass)
    s = _simplex(d, *fields)
    nb, dual = o.face_neighbor(s, face)
    outs = [nb.anchor[..., k] for k in range(d)]
    return (*outs, nb.stype, dual)


def face_sweep_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    """Composed oracle of the fused face sweep: per face, face_neighbor +
    is_inside_root + morton_key, stacked with a trailing face axis to match
    the kernel's (n, nf) tiles (nf = d+1 simplex, 2d hex)."""
    o = get_ops(d, eclass)
    s = _simplex(d, *arrays)
    cols = [[] for _ in range(d + 5)]
    for f in range(o.nf):
        nb, dual = o.face_neighbor(s, jnp.int32(f))
        inside = o.is_inside_root(nb)
        key = o.morton_key(nb)
        for k in range(d):
            cols[k].append(nb.anchor[..., k])
        cols[d].append(nb.stype)
        cols[d + 1].append(dual)
        cols[d + 2].append(inside.astype(jnp.int32))
        cols[d + 3].append(key.hi)
        cols[d + 4].append(key.lo)
    return tuple(jnp.stack(c, axis=-1) for c in cols)


def tree_transform_ref(d, M, c, tmap, *arrays, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    s2 = o.tree_transform(_simplex(d, *arrays), M, c, tmap)
    outs = [s2.anchor[..., k] for k in range(d)]
    return (*outs, s2.stype)


def owner_rank_ref(t, hi, lo, mt, mhi, mlo):
    """Vectorized searchsorted against the partition-marker table: index of
    the last marker lex-<= (tree, key), clamped to 0 — delegates to the one
    shared compare chain in `repro.core.batch` (the kernel unrolls the same
    chain over the marker entries)."""
    from repro.core.batch import owner_rank_lex

    return owner_rank_lex(t, hi, lo, mt, mhi, mlo)


def eval_route_ref(d, t, hi, lo, lvl, mt, mhi, mlo):
    """Oracle of the fused routing eval: interval-end key words (key |
    span-1 over the (hi, lo) uint32 pair) and the [first, last] owner-rank
    range, elementwise over (n, d+1) tiles — same math as the kernel body
    but through the shared `owner_rank_lex` compare chain."""
    from repro.core.batch import owner_rank_lex

    L = get_ops(d).L
    sb = d * (L - lvl)
    one = u64m.U64(jnp.zeros_like(hi), jnp.full_like(lo, 1))
    mask = u64m.dec(u64m.select_shl(one, sb, 63))
    kh = u64m.or_(u64m.U64(hi, lo), mask)
    shp = t.shape
    first = owner_rank_lex(
        t.reshape(-1), hi.reshape(-1), lo.reshape(-1), mt, mhi, mlo
    ).reshape(shp)
    last = owner_rank_lex(
        t.reshape(-1), kh.hi.reshape(-1), kh.lo.reshape(-1), mt, mhi, mlo
    ).reshape(shp)
    return kh.hi, kh.lo, first, last


def successor_ref(d, *arrays, eclass=ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    s = _simplex(d, *arrays)
    nxt = o.successor(s)
    outs = [nxt.anchor[..., k] for k in range(d)]
    return (*outs, nxt.stype)
