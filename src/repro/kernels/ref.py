"""Pure-jnp oracles for the Pallas SFC kernels (delegate to repro.core.ops)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import u64 as u64m
from repro.core.ops import get_ops
from repro.core.types import Simplex


def _simplex(d, *arrays):
    if d == 3:
        x, y, z, level, stype = arrays
        anchor = jnp.stack([x, y, z], axis=-1)
    else:
        x, y, level, stype = arrays
        anchor = jnp.stack([x, y], axis=-1)
    return Simplex(anchor, level, stype)


def morton_key_ref(d, *arrays):
    """x, y, (z,), type -> (hi, lo).  Level plays no role in the padded key
    (trailing digits of the T_0-chain are zero), so we evaluate at MAXLEVEL."""
    o = get_ops(d)
    coords, stype = arrays[:-1], arrays[-1]
    level = jnp.full(stype.shape, o.L, jnp.int32)
    key = o.morton_key(_simplex(d, *coords, level, stype))
    return key.hi, key.lo


def decode_ref(d, hi, lo, level):
    o = get_ops(d)
    lid = u64m.select_shr(u64m.U64(hi, lo), (o.L - level) * d, d * o.L)
    s = o.from_linear_id(lid, level)
    outs = [s.anchor[..., k] for k in range(d)]
    return (*outs, s.stype)


def face_neighbor_ref(d, *arrays):
    *fields, face = arrays
    o = get_ops(d)
    s = _simplex(d, *fields)
    nb, dual = o.face_neighbor(s, face)
    outs = [nb.anchor[..., k] for k in range(d)]
    return (*outs, nb.stype, dual)


def successor_ref(d, *arrays):
    o = get_ops(d)
    s = _simplex(d, *arrays)
    nxt = o.successor(s)
    outs = [nxt.anchor[..., k] for k in range(d)]
    return (*outs, nxt.stype)
