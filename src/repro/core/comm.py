"""Communication layer for the forest algorithms: one `Comm` surface,
three bindings.

The forest code (`repro.core.forest`) is written SPMD style: every rank
computes its own view, and all cross-rank data moves through the two
collectives below.  A process may host one rank (production) or all P ranks
(the in-process simulator used by tests and benchmarks); the `Comm` object
says which global ranks are resident via `local_ranks`, and every collective
takes/returns *per-local-rank* payload lists so the same forest code runs
unchanged under either hosting:

  SimComm(P)   all P ranks in this process; collectives are list shuffles.
               This is the seed's simulator, conformed to the shared surface.
  LocalComm()  the degenerate single-rank world (P = 1, no wire anywhere).
  DistComm()   one rank per process, bound to mpi4py when available and
               initialized, otherwise to the jax.distributed coordination
               service (each payload travels through the key-value store of
               the coordinator that `jax.distributed.initialize` brings up).

Payloads are nested tuples/lists/dicts of numpy arrays and scalars.  The
base class meters every collective: bytes that would cross a rank boundary
are accumulated into per-phase counters (`comm.phase("balance")`), which is
how the benchmarks attribute wire volume to Balance / Ghost / Partition and
how the boundary-layer exchange is shown to beat the allgathered-leaf-table
baseline.
"""

from __future__ import annotations

import contextlib
import struct
from typing import Sequence

import numpy as np

__all__ = [
    "Comm",
    "SimComm",
    "LocalComm",
    "DistComm",
    "payload_nbytes",
    "encode_payload",
    "decode_payload",
]


# ------------------------------------------------------------- byte metering
def payload_nbytes(obj) -> int:
    """Wire size of a nested payload (arrays dominate; scalars count 8)."""
    if obj is None:
        return 1
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj.encode())
    if isinstance(obj, (bool, int, float, np.integer, np.floating, np.bool_)):
        return 8
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v) for k, v in obj.items())
    if isinstance(obj, (list, tuple)):
        return sum(payload_nbytes(v) for v in obj)
    raise TypeError(f"unsupported payload type {type(obj)!r}")


# ------------------------------------------------------- wire serialization
# Self-describing tagged format for the payload types above — the DistComm
# KV-store transport.  No pickle: only data, no code.  (The optional mpi4py
# binding uses mpi4py's own object collectives instead, which pickle; that
# path assumes the usual MPI trust model of mutually trusted ranks.)
def _enc(obj, out: list) -> None:
    if obj is None:
        out.append(b"N")
    elif isinstance(obj, (bool, np.bool_)):
        out.append(b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        v = int(obj)
        if 0 <= v < 1 << 64:
            out.append(b"u" + struct.pack("<Q", v))
        elif -(1 << 63) <= v < 1 << 63:
            out.append(b"i" + struct.pack("<q", v))
        else:  # arbitrary precision fallback
            s = str(v).encode()
            out.append(b"I" + struct.pack("<I", len(s)) + s)
    elif isinstance(obj, (float, np.floating)):
        out.append(b"f" + struct.pack("<d", float(obj)))
    elif isinstance(obj, str):
        s = obj.encode()
        out.append(b"s" + struct.pack("<I", len(s)) + s)
    elif isinstance(obj, (bytes, bytearray)):
        out.append(b"y" + struct.pack("<I", len(obj)) + bytes(obj))
    elif isinstance(obj, np.ndarray):
        assert obj.dtype.names is None, "structured dtypes are not wire types"
        dt = obj.dtype.str.encode()
        a = np.ascontiguousarray(obj)
        out.append(b"a" + struct.pack("<B", len(dt)) + dt
                   + struct.pack("<B", a.ndim)
                   + struct.pack(f"<{a.ndim}I", *a.shape)
                   + a.tobytes())
    elif isinstance(obj, (list, tuple)):
        out.append((b"l" if isinstance(obj, list) else b"t")
                   + struct.pack("<I", len(obj)))
        for v in obj:
            _enc(v, out)
    elif isinstance(obj, dict):
        out.append(b"d" + struct.pack("<I", len(obj)))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        raise TypeError(f"unsupported payload type {type(obj)!r}")


def encode_payload(obj) -> bytes:
    out: list = []
    _enc(obj, out)
    return b"".join(out)


def _dec(buf: bytes, off: int):
    tag = buf[off:off + 1]
    off += 1
    if tag == b"N":
        return None, off
    if tag == b"T":
        return True, off
    if tag == b"F":
        return False, off
    if tag == b"u":
        return struct.unpack_from("<Q", buf, off)[0], off + 8
    if tag == b"i":
        return struct.unpack_from("<q", buf, off)[0], off + 8
    if tag == b"I":
        n = struct.unpack_from("<I", buf, off)[0]
        return int(buf[off + 4:off + 4 + n].decode()), off + 4 + n
    if tag == b"f":
        return struct.unpack_from("<d", buf, off)[0], off + 8
    if tag == b"s":
        n = struct.unpack_from("<I", buf, off)[0]
        return buf[off + 4:off + 4 + n].decode(), off + 4 + n
    if tag == b"y":
        n = struct.unpack_from("<I", buf, off)[0]
        return buf[off + 4:off + 4 + n], off + 4 + n
    if tag == b"a":
        dl = struct.unpack_from("<B", buf, off)[0]
        off += 1
        dt = np.dtype(buf[off:off + dl].decode())
        off += dl
        ndim = struct.unpack_from("<B", buf, off)[0]
        off += 1
        shape = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        n = int(np.prod(shape)) if ndim else 1
        nb = n * dt.itemsize
        arr = np.frombuffer(buf[off:off + nb], dt).reshape(shape).copy()
        return arr, off + nb
    if tag in (b"l", b"t"):
        n = struct.unpack_from("<I", buf, off)[0]
        off += 4
        items = []
        for _ in range(n):
            v, off = _dec(buf, off)
            items.append(v)
        return (items if tag == b"l" else tuple(items)), off
    if tag == b"d":
        n = struct.unpack_from("<I", buf, off)[0]
        off += 4
        d = {}
        for _ in range(n):
            k, off = _dec(buf, off)
            v, off = _dec(buf, off)
            d[k] = v
        return d, off
    raise ValueError(f"bad wire tag {tag!r} at offset {off - 1}")


def decode_payload(buf: bytes):
    obj, off = _dec(bytes(buf), 0)
    assert off == len(buf), "trailing bytes in wire payload"
    return obj


# ----------------------------------------------------------------- the seam
class Comm:
    """Abstract communicator: rank/size plus the two forest collectives.

    `local_ranks` lists the global ranks resident in this process; every
    collective consumes a list with one payload per local rank and returns,
    per local rank, the global view (`allgather`: length-P list; `alltoallv`:
    length-P list of what each global rank sent here).  Subclasses implement
    `_allgather` / `_alltoallv`; the base class meters byte volume into
    per-phase counters.
    """

    size: int
    rank: int            # first (usually only) local rank
    local_ranks: range

    def __init__(self):
        self.counters: dict = {}
        self._phases: list[str] = []

    # -- metering ----------------------------------------------------------
    @contextlib.contextmanager
    def phase(self, name: str):
        """Attribute subsequent traffic to `name` (nested phases stack; the
        innermost label wins — forest algorithms label their own traffic)."""
        self._phases.append(name)
        try:
            yield self
        finally:
            self._phases.pop()

    def _bucket(self) -> dict:
        name = self._phases[-1] if self._phases else "default"
        return self.counters.setdefault(
            name, {"allgather_bytes": 0, "alltoallv_bytes": 0,
                   "allgather_calls": 0, "alltoallv_calls": 0})

    def bytes_for(self, phase: str | None = None) -> int:
        """Total bytes crossing rank boundaries (one phase, or all)."""
        buckets = ([self.counters.get(phase, {})] if phase is not None
                   else list(self.counters.values()))
        return sum(b.get("allgather_bytes", 0) + b.get("alltoallv_bytes", 0)
                   for b in buckets)

    def stats(self) -> dict:
        out = {k: dict(v) for k, v in self.counters.items()}
        out["total_bytes"] = self.bytes_for()
        return out

    def reset_counters(self) -> None:
        self.counters.clear()

    # -- collectives -------------------------------------------------------
    def allgather(self, per_local: Sequence) -> list:
        """per_local[i] from local rank i -> full per-global-rank list."""
        assert len(per_local) == len(self.local_ranks)
        b = self._bucket()
        b["allgather_calls"] += 1
        b["allgather_bytes"] += sum(
            payload_nbytes(x) * (self.size - 1) for x in per_local)
        return self._allgather(list(per_local))

    def alltoallv(self, send: Sequence[Sequence]) -> list:
        """send[i][q]: payload from local rank i to global rank q.
        Returns recv[i][p]: what global rank p sent to local rank i."""
        assert len(send) == len(self.local_ranks)
        b = self._bucket()
        b["alltoallv_calls"] += 1
        for i, g in enumerate(self.local_ranks):
            assert len(send[i]) == self.size
            b["alltoallv_bytes"] += sum(
                payload_nbytes(x) for q, x in enumerate(send[i]) if q != g)
        return self._alltoallv([list(row) for row in send])

    def barrier(self) -> None:  # pragma: no cover - trivial default
        pass

    def _allgather(self, per_local: list) -> list:
        raise NotImplementedError

    def _alltoallv(self, send: list) -> list:
        raise NotImplementedError


class SimComm(Comm):
    """All P ranks in this process — the tests/benchmarks simulator.

    Collectives are pure list shuffles; the byte counters still meter what
    WOULD cross rank boundaries, which is what the benchmarks record.
    """

    def __init__(self, num_ranks: int):
        super().__init__()
        self.size = num_ranks
        self.rank = 0
        self.local_ranks = range(num_ranks)

    # legacy alias (the seed called it .P everywhere)
    @property
    def P(self) -> int:
        return self.size

    def _allgather(self, per_local: list) -> list:
        return list(per_local)

    def _alltoallv(self, send: list) -> list:
        P = self.size
        return [[send[p][q] for p in range(P)] for q in range(P)]


class LocalComm(SimComm):
    """Degenerate single-rank world: every collective is the identity."""

    def __init__(self):
        super().__init__(1)


class DistComm(Comm):
    """One rank per process, over mpi4py or the jax.distributed coordinator.

    Binding order: an initialized mpi4py world with more than one process
    wins; otherwise `jax.distributed.initialize()` must have been called and
    payloads travel through the coordination service's key-value store
    (set/get/delete per generation, with a barrier before cleanup).  Either
    way the surface is identical to `SimComm` with `local_ranks == [rank]`,
    so the forest algorithms run unmodified.
    """

    def __init__(self, timeout_s: float = 120.0):
        super().__init__()
        self._timeout_ms = int(timeout_s * 1000)
        self._gen = 0
        self._mpi = None
        self._client = None
        mpi = self._try_mpi()
        if mpi is not None:
            self._mpi = mpi
            self.rank = mpi.Get_rank()
            self.size = mpi.Get_size()
        else:
            import jax
            from jax._src import distributed

            client = getattr(distributed.global_state, "client", None)
            if client is None:
                raise RuntimeError(
                    "DistComm needs an initialized jax.distributed runtime "
                    "(call jax.distributed.initialize) or an mpi4py world")
            self._client = client
            self.rank = jax.process_index()
            self.size = jax.process_count()
        self.local_ranks = range(self.rank, self.rank + 1)

    @staticmethod
    def _try_mpi():
        try:
            from mpi4py import MPI  # noqa: PLC0415
        except ImportError:
            return None
        if not MPI.Is_initialized() or MPI.COMM_WORLD.Get_size() < 2:
            return None
        return MPI.COMM_WORLD

    # legacy alias
    @property
    def P(self) -> int:
        return self.size

    # -- KV-store transport ------------------------------------------------
    def _kv_exchange(self, outbox: dict[int, bytes], tag: str) -> dict[int, bytes]:
        """Deliver outbox[q] to each rank q; return {p: payload_from_p}.
        Peers that sent nothing are absent from the result."""
        c = self._client
        gen = self._gen
        self._gen += 1
        me = self.rank
        for q, blob in outbox.items():
            c.key_value_set_bytes(f"repro_comm/{gen}/{tag}/{me}>{q}", blob)
        # publish which peers each rank targeted so receivers know what to get
        targets = ",".join(str(q) for q in sorted(outbox))
        c.key_value_set(f"repro_comm/{gen}/{tag}/targets/{me}", targets or "-")
        inbox: dict[int, bytes] = {}
        for p in range(self.size):
            if p == me:
                continue
            t = c.blocking_key_value_get(
                f"repro_comm/{gen}/{tag}/targets/{p}", self._timeout_ms)
            if t != "-" and str(me) in t.split(","):
                inbox[p] = c.blocking_key_value_get_bytes(
                    f"repro_comm/{gen}/{tag}/{p}>{me}", self._timeout_ms)
        c.wait_at_barrier(f"repro_comm_{gen}_{tag}", self._timeout_ms)
        for q in outbox:
            c.key_value_delete(f"repro_comm/{gen}/{tag}/{me}>{q}")
        c.key_value_delete(f"repro_comm/{gen}/{tag}/targets/{me}")
        return inbox

    def barrier(self) -> None:
        if self._mpi is not None:
            self._mpi.Barrier()
        else:
            gen = self._gen
            self._gen += 1
            self._client.wait_at_barrier(f"repro_comm_{gen}_b", self._timeout_ms)

    def _allgather(self, per_local: list) -> list:
        x = per_local[0]
        if self._mpi is not None:
            return list(self._mpi.allgather(x))
        blob = encode_payload(x)
        inbox = self._kv_exchange(
            {q: blob for q in range(self.size) if q != self.rank}, "ag")
        out = [None] * self.size
        out[self.rank] = x
        for p, b in inbox.items():
            out[p] = decode_payload(b)
        return out

    def _alltoallv(self, send: list) -> list:
        row = send[0]
        if self._mpi is not None:
            return [list(self._mpi.alltoall(row))]
        outbox = {q: encode_payload(row[q])
                  for q in range(self.size) if q != self.rank}
        inbox = self._kv_exchange(outbox, "a2a")
        recv = [None] * self.size
        recv[self.rank] = row[self.rank]
        for p, b in inbox.items():
            recv[p] = decode_payload(b)
        return [recv]
