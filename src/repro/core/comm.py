"""Communication layer for the forest algorithms: one `Comm` surface,
three bindings.

The forest code (`repro.core.forest`) is written SPMD style: every rank
computes its own view, and all cross-rank data moves through the two
collectives below.  A process may host one rank (production) or all P ranks
(the in-process simulator used by tests and benchmarks); the `Comm` object
says which global ranks are resident via `local_ranks`, and every collective
takes/returns *per-local-rank* payload lists so the same forest code runs
unchanged under either hosting:

  SimComm(P)   all P ranks in this process; collectives are list shuffles.
               This is the seed's simulator, conformed to the shared surface.
  LocalComm()  the degenerate single-rank world (P = 1, no wire anywhere).
  DistComm()   one rank per process, bound to mpi4py when available and
               initialized, otherwise to the jax.distributed coordination
               service (each payload travels through the key-value store of
               the coordinator that `jax.distributed.initialize` brings up).

Every collective also exists in a *nonblocking* form — `iallgather` /
`ialltoallv` return a `CommHandle` whose `wait()` delivers the same result
the blocking call would (the blocking calls are literally post + wait).
`SimComm` handles complete immediately, `DistComm` posts mpi4py nonblocking
point-to-point exchanges or KV-store writes and only blocks in `wait()`,
and `LatencyComm` simulates round-trip time so overlap can be measured
in-process.  Handles of one communicator must be waited in the order they
were posted, the same on every rank (the SPMD forest code does this; MPI
tag/collective matching relies on it).  A handle that polls `done() ==
True` has a free `wait()`: the data is cached and no transport round-trips
remain.

Payloads are nested tuples/lists/dicts of numpy arrays and scalars.  The
base class meters every collective *at post time*: bytes that would cross a
rank boundary are accumulated into per-phase counters
(`comm.phase("balance")`), which is how the benchmarks attribute wire
volume to Balance / Ghost / Partition and how the boundary-layer exchange
is shown to beat the allgathered-leaf-table baseline.
"""

from __future__ import annotations

import contextlib
import hashlib
import random
import struct
import time
import zlib
from typing import Callable, Sequence

import numpy as np

from .errors import CommTimeoutError, WireFormatError, WireIntegrityError

__all__ = [
    "Comm",
    "CommHandle",
    "SimComm",
    "LocalComm",
    "LatencyComm",
    "DistComm",
    "payload_nbytes",
    "encode_payload",
    "decode_payload",
    "frame_blob",
    "unframe_blob",
    "CommTimeoutError",
    "WireFormatError",
    "WireIntegrityError",
]


# ------------------------------------------------------------- byte metering
def payload_nbytes(obj) -> int:
    """Wire size of a nested payload (arrays dominate; scalars count 8)."""
    if obj is None:
        return 1
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj.encode())
    if isinstance(obj, (bool, int, float, np.integer, np.floating, np.bool_)):
        return 8
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v) for k, v in obj.items())
    if isinstance(obj, (list, tuple)):
        return sum(payload_nbytes(v) for v in obj)
    raise TypeError(f"unsupported payload type {type(obj)!r}")


# ------------------------------------------------------- wire serialization
# Self-describing tagged format for the payload types above — the ONE wire
# codec of BOTH DistComm transports.  No pickle: only data, no code.  (The
# mpi4py binding used mpi4py's pickling object collectives while the KV
# path used this codec, so the two bindings moved different bytes; the
# mpi4py path now ships exactly these buffers over MPI.BYTE point-to-point
# exchanges, and `DistComm.wire_digest()` lets tests pin the parity.)
def _enc(obj, out: list) -> None:
    if obj is None:
        out.append(b"N")
    elif isinstance(obj, (bool, np.bool_)):
        out.append(b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        v = int(obj)
        if 0 <= v < 1 << 64:
            out.append(b"u" + struct.pack("<Q", v))
        elif -(1 << 63) <= v < 1 << 63:
            out.append(b"i" + struct.pack("<q", v))
        else:  # arbitrary precision fallback
            s = str(v).encode()
            out.append(b"I" + struct.pack("<I", len(s)) + s)
    elif isinstance(obj, (float, np.floating)):
        out.append(b"f" + struct.pack("<d", float(obj)))
    elif isinstance(obj, str):
        s = obj.encode()
        out.append(b"s" + struct.pack("<I", len(s)) + s)
    elif isinstance(obj, (bytes, bytearray)):
        out.append(b"y" + struct.pack("<I", len(obj)) + bytes(obj))
    elif isinstance(obj, np.ndarray):
        assert obj.dtype.names is None, "structured dtypes are not wire types"
        dt = obj.dtype.str.encode()
        a = np.ascontiguousarray(obj)
        out.append(b"a" + struct.pack("<B", len(dt)) + dt
                   + struct.pack("<B", a.ndim)
                   + struct.pack(f"<{a.ndim}I", *a.shape)
                   + a.tobytes())
    elif isinstance(obj, (list, tuple)):
        out.append((b"l" if isinstance(obj, list) else b"t")
                   + struct.pack("<I", len(obj)))
        for v in obj:
            _enc(v, out)
    elif isinstance(obj, dict):
        out.append(b"d" + struct.pack("<I", len(obj)))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        raise TypeError(f"unsupported payload type {type(obj)!r}")


def encode_payload(obj) -> bytes:
    out: list = []
    _enc(obj, out)
    return b"".join(out)


def _need(buf: bytes, off: int, n: int, what: str) -> None:
    """Bounds check: the next `n` bytes must exist, else the buffer is
    truncated — a structured `WireFormatError`, never an IndexError or a
    short `struct.error` read."""
    if n < 0 or off + n > len(buf):
        raise WireFormatError(
            f"truncated wire payload: need {n} byte(s) for {what} at "
            f"offset {off}, have {len(buf) - off}")


def _dec(buf: bytes, off: int):
    _need(buf, off, 1, "tag")
    tag = buf[off:off + 1]
    off += 1
    if tag == b"N":
        return None, off
    if tag == b"T":
        return True, off
    if tag == b"F":
        return False, off
    if tag == b"u":
        _need(buf, off, 8, "u64")
        return struct.unpack_from("<Q", buf, off)[0], off + 8
    if tag == b"i":
        _need(buf, off, 8, "i64")
        return struct.unpack_from("<q", buf, off)[0], off + 8
    if tag == b"I":
        _need(buf, off, 4, "bigint length")
        n = struct.unpack_from("<I", buf, off)[0]
        _need(buf, off + 4, n, "bigint digits")
        try:
            v = int(buf[off + 4:off + 4 + n].decode())
        except (UnicodeDecodeError, ValueError) as e:
            raise WireFormatError(
                f"malformed bigint in wire payload at offset {off}: {e}"
            ) from e
        return v, off + 4 + n
    if tag == b"f":
        _need(buf, off, 8, "f64")
        return struct.unpack_from("<d", buf, off)[0], off + 8
    if tag == b"s":
        _need(buf, off, 4, "string length")
        n = struct.unpack_from("<I", buf, off)[0]
        _need(buf, off + 4, n, "string bytes")
        try:
            s = buf[off + 4:off + 4 + n].decode()
        except UnicodeDecodeError as e:
            raise WireFormatError(
                f"malformed utf-8 string in wire payload at offset {off}: {e}"
            ) from e
        return s, off + 4 + n
    if tag == b"y":
        _need(buf, off, 4, "bytes length")
        n = struct.unpack_from("<I", buf, off)[0]
        _need(buf, off + 4, n, "bytes body")
        return buf[off + 4:off + 4 + n], off + 4 + n
    if tag == b"a":
        _need(buf, off, 1, "dtype length")
        dl = struct.unpack_from("<B", buf, off)[0]
        off += 1
        _need(buf, off, dl, "dtype string")
        try:
            dt = np.dtype(buf[off:off + dl].decode())
        except (UnicodeDecodeError, TypeError, ValueError) as e:
            raise WireFormatError(
                f"bad array dtype in wire payload at offset {off}: {e}"
            ) from e
        if dt.hasobject:
            raise WireFormatError(
                f"object dtype {dt!r} is not a wire type (offset {off})")
        off += dl
        _need(buf, off, 1, "ndim")
        ndim = struct.unpack_from("<B", buf, off)[0]
        off += 1
        _need(buf, off, 4 * ndim, "shape")
        shape = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        n = 1
        for s in shape:
            n *= int(s)
        if not ndim:
            n = 1
        nb = n * dt.itemsize
        _need(buf, off, nb, f"array body {dt.str}{tuple(shape)}")
        try:
            arr = np.frombuffer(buf[off:off + nb], dt).reshape(shape).copy()
        except (ValueError, TypeError) as e:
            raise WireFormatError(
                f"malformed array in wire payload at offset {off}: {e}"
            ) from e
        return arr, off + nb
    if tag in (b"l", b"t"):
        _need(buf, off, 4, "sequence count")
        n = struct.unpack_from("<I", buf, off)[0]
        off += 4
        # every element takes >= 1 byte, so a count beyond the remaining
        # bytes is garbage — reject before allocating or looping on it
        _need(buf, off, n, f"{n} sequence element(s)")
        items = []
        for _ in range(n):
            v, off = _dec(buf, off)
            items.append(v)
        return (items if tag == b"l" else tuple(items)), off
    if tag == b"d":
        _need(buf, off, 4, "dict count")
        n = struct.unpack_from("<I", buf, off)[0]
        off += 4
        _need(buf, off, 2 * n, f"{n} dict item(s)")
        d = {}
        for _ in range(n):
            k, off = _dec(buf, off)
            v, off = _dec(buf, off)
            try:
                d[k] = v
            except TypeError as e:  # unhashable decoded key
                raise WireFormatError(
                    f"unhashable dict key in wire payload at offset {off}: {e}"
                ) from e
        return d, off
    raise WireFormatError(f"bad wire tag {tag!r} at offset {off - 1}")


def decode_payload(buf: bytes):
    """Decode one `encode_payload` buffer.  Malformed input of ANY shape —
    truncation, trailing garbage, bad tags, bogus counts/dtypes — raises a
    structured `WireFormatError` (a ValueError subclass); it never leaks a
    bare `struct.error`, never returns silently wrong columns."""
    buf = bytes(buf)
    try:
        obj, off = _dec(buf, 0)
    except WireFormatError:
        raise
    except (struct.error, ValueError, TypeError, OverflowError,
            MemoryError, RecursionError) as e:
        raise WireFormatError(f"malformed wire payload: {e}") from e
    if off != len(buf):
        raise WireFormatError(
            f"trailing bytes in wire payload: decoded {off} of {len(buf)}")
    return obj


# ------------------------------------------------------- integrity framing
# Every blob a DistComm transport moves travels inside a 16-byte integrity
# frame: magic, u64 body length, CRC32 of the body.  `unframe_blob` turns
# corruption, truncation, and duplication into a typed `WireIntegrityError`
# instead of a downstream mis-decode — and because the smallest frame is 16
# bytes, no transport value can ever be the 1-byte blob that segfaults
# jaxlib's `blocking_key_value_get_bytes` (`encode_payload(None)` is b"N").
# Framing lives strictly BETWEEN the codec and the transport: byte meters
# and `wire_digest()` both see the unframed payload blobs, so digests stay
# comparable across bindings and with the in-process simulators.
_FRAME = struct.Struct("<4sQI")
_FRAME_MAGIC = b"RW01"


def frame_blob(blob: bytes) -> bytes:
    """Wrap a payload blob for the wire: magic + length + CRC32 header."""
    blob = bytes(blob)
    return _FRAME.pack(_FRAME_MAGIC, len(blob), zlib.crc32(blob)) + blob


def unframe_blob(buf: bytes, *, where: str = "") -> bytes:
    """Verify and strip a `frame_blob` header; raises `WireIntegrityError`
    (tagged with `where`: phase/generation/peer) on any mismatch."""
    buf = bytes(buf)
    if len(buf) < _FRAME.size:
        raise WireIntegrityError("frame shorter than header", where=where,
                                 expected=_FRAME.size, actual=len(buf))
    magic, length, crc = _FRAME.unpack_from(buf, 0)
    if magic != _FRAME_MAGIC:
        raise WireIntegrityError("bad frame magic", where=where,
                                 expected=_FRAME_MAGIC, actual=magic)
    body = buf[_FRAME.size:]
    if len(body) != length:
        raise WireIntegrityError("frame length mismatch", where=where,
                                 expected=int(length), actual=len(body))
    got = zlib.crc32(body)
    if got != crc:
        raise WireIntegrityError("frame checksum mismatch", where=where,
                                 expected=int(crc), actual=int(got))
    return body


# ------------------------------------------------------------------ handles
class CommHandle:
    """Waitable result of a nonblocking collective (`iallgather` /
    `ialltoallv`).

    `wait()` blocks until delivery and returns the collective's result —
    idempotent, later calls return the same object.  `done()` polls for
    completion without blocking and doubles as the transport's progress
    driver; once it returns True, `wait()` performs no further transport
    round-trips.  Handles must be waited in posting order, identically on
    every rank (MPI tag and collective matching rely on it); the SPMD
    forest code always does.

    Every handle is stamped by the posting `Comm` with the `phase` active
    at post time and a monotonically increasing `seq`, and — when the comm
    has a deadline (`comm.set_deadline(s)`, off by default) — a wall-clock
    deadline.  A deadlined `wait()` drives the transport's poll in an
    exponential-backoff + jitter loop and raises a structured
    `CommTimeoutError` (phase, seq, elapsed, retries, pending peers,
    liveness detail) instead of hanging; without a deadline, `wait()` is
    the exact single blocking transport call it always was.
    """

    __slots__ = ("_complete", "_poll", "_result", "_done",
                 "phase", "seq", "_deadline", "_pending", "_diagnose")

    def __init__(self, complete: Callable | None = None,
                 poll: Callable[[], bool] | None = None,
                 result=None, done: bool = False):
        self._complete = complete
        self._poll = poll
        self._result = result
        self._done = done
        self.phase = "default"
        self.seq = -1
        self._deadline = None    # absolute time.monotonic() bound, or None
        self._pending = None     # () -> [peer ranks not yet delivered]
        self._diagnose = None    # () -> detail dict for CommTimeoutError

    @classmethod
    def ready(cls, result) -> "CommHandle":
        """An already-completed handle (immediate transports, e.g. SimComm)."""
        return cls(result=result, done=True)

    def done(self) -> bool:
        """True once the collective's data is available — `wait()` will not
        block on peers' payloads and performs no transport round-trips.  A
        deferred handle whose binding supplied no poll conservatively
        reports False."""
        if self._done:
            return True
        if self._poll is not None:
            return self._poll()
        return False

    def wait(self, timeout: float | None = None):
        """Deliver the result, blocking if the exchange is still in flight.

        With a deadline (stamped at post time, or the tighter of that and
        an explicit `timeout`), completion is driven through the poll with
        exponential backoff + jitter and expiry raises `CommTimeoutError`;
        with none (the default), this is one blocking transport call."""
        if self._done:
            return self._result
        deadline = self._deadline
        if timeout is not None:
            t = time.monotonic() + timeout
            deadline = t if deadline is None else min(deadline, t)
        if deadline is not None and self._poll is not None:
            start = time.monotonic()
            retries = 0
            delay = 0.0005
            while not self._poll():
                now = time.monotonic()
                if now >= deadline:
                    raise CommTimeoutError(
                        phase=self.phase, seq=self.seq,
                        elapsed_s=now - start, retries=retries,
                        pending=self._pending() if self._pending else None,
                        detail=self._diagnose() if self._diagnose else None)
                retries += 1
                time.sleep(min(delay, deadline - now) * (0.5 + random.random()))
                delay = min(delay * 2.0, 0.05)
        self._result = self._complete()
        self._complete = self._poll = None
        self._done = True
        return self._result


# ----------------------------------------------------------------- the seam
class Comm:
    """Abstract communicator: rank/size plus the two forest collectives.

    `local_ranks` lists the global ranks resident in this process; every
    collective consumes a list with one payload per local rank and returns,
    per local rank, the global view (`allgather`: length-P list; `alltoallv`:
    length-P list of what each global rank sent here).  Both collectives
    exist blocking (`allgather`/`alltoallv`) and nonblocking
    (`iallgather`/`ialltoallv` -> `CommHandle`); the blocking forms are
    post + `wait()`.  Subclasses implement `_allgather` / `_alltoallv` (and
    optionally the nonblocking `_iallgather` / `_ialltoallv`, which default
    to immediate completion); the base class meters byte volume into
    per-phase counters at post time.
    """

    size: int
    rank: int            # first (usually only) local rank
    local_ranks: range
    deadline_s: float | None = None   # per-collective wait budget (opt-in)

    def __init__(self):
        self.counters: dict = {}
        self._phases: list[str] = []
        self._hseq = 0

    def set_deadline(self, seconds: float | None) -> None:
        """Give every subsequently posted collective a wall-clock wait
        budget: `wait()` past it raises `CommTimeoutError` naming the
        phase, seq, and (where the transport knows) the pending peers.
        `None` (the default) restores plain blocking waits."""
        self.deadline_s = seconds

    def _stamp(self, h: CommHandle) -> CommHandle:
        """Tag a freshly posted handle with phase/seq/deadline context."""
        self._hseq += 1
        h.seq = self._hseq
        h.phase = self._phases[-1] if self._phases else "default"
        if self.deadline_s is not None and not h._done:
            h._deadline = time.monotonic() + self.deadline_s
        return h

    # -- metering ----------------------------------------------------------
    @contextlib.contextmanager
    def phase(self, name: str):
        """Attribute subsequent traffic to `name` (nested phases stack; the
        innermost label wins — forest algorithms label their own traffic)."""
        self._phases.append(name)
        try:
            yield self
        finally:
            self._phases.pop()

    def _bucket(self) -> dict:
        name = self._phases[-1] if self._phases else "default"
        return self.counters.setdefault(
            name, {"allgather_bytes": 0, "alltoallv_bytes": 0,
                   "allgather_calls": 0, "alltoallv_calls": 0})

    def bytes_for(self, phase: str | None = None) -> int:
        """Total bytes crossing rank boundaries (one phase, or all)."""
        buckets = ([self.counters.get(phase, {})] if phase is not None
                   else list(self.counters.values()))
        return sum(b.get("allgather_bytes", 0) + b.get("alltoallv_bytes", 0)
                   for b in buckets)

    def stats(self) -> dict:
        out = {k: dict(v) for k, v in self.counters.items()}
        out["total_bytes"] = self.bytes_for()
        return out

    def reset_counters(self) -> None:
        self.counters.clear()

    # -- collectives -------------------------------------------------------
    def allgather(self, per_local: Sequence) -> list:
        """per_local[i] from local rank i -> full per-global-rank list."""
        return self.iallgather(per_local).wait()

    def alltoallv(self, send: Sequence[Sequence]) -> list:
        """send[i][q]: payload from local rank i to global rank q.
        Returns recv[i][p]: what global rank p sent to local rank i."""
        return self.ialltoallv(send).wait()

    def iallgather(self, per_local: Sequence) -> CommHandle:
        """Nonblocking `allgather`: posts the exchange, meters its bytes to
        the phase active NOW, and returns a waitable `CommHandle`."""
        assert len(per_local) == len(self.local_ranks)
        b = self._bucket()
        b["allgather_calls"] += 1
        b["allgather_bytes"] += sum(
            payload_nbytes(x) * (self.size - 1) for x in per_local)
        return self._stamp(self._iallgather(list(per_local)))

    def ialltoallv(self, send: Sequence[Sequence]) -> CommHandle:
        """Nonblocking `alltoallv`: posts, meters at post time, returns a
        `CommHandle` delivering recv[i][p] on `wait()`."""
        assert len(send) == len(self.local_ranks)
        b = self._bucket()
        b["alltoallv_calls"] += 1
        for i, g in enumerate(self.local_ranks):
            assert len(send[i]) == self.size
            b["alltoallv_bytes"] += sum(
                payload_nbytes(x) for q, x in enumerate(send[i]) if q != g)
        return self._stamp(self._ialltoallv([list(row) for row in send]))

    def barrier(self) -> None:  # pragma: no cover - trivial default
        pass

    def _allgather(self, per_local: list) -> list:
        raise NotImplementedError

    def _alltoallv(self, send: list) -> list:
        raise NotImplementedError

    # Default nonblocking forms: complete-at-post via the blocking transport
    # (correct for any binding; real transports override to defer the wait).
    def _iallgather(self, per_local: list) -> CommHandle:
        return CommHandle.ready(self._allgather(per_local))

    def _ialltoallv(self, send: list) -> CommHandle:
        return CommHandle.ready(self._alltoallv(send))


class SimComm(Comm):
    """All P ranks in this process — the tests/benchmarks simulator.

    Collectives are pure list shuffles; the byte counters still meter what
    WOULD cross rank boundaries, which is what the benchmarks record.
    """

    def __init__(self, num_ranks: int):
        super().__init__()
        self.size = num_ranks
        self.rank = 0
        self.local_ranks = range(num_ranks)

    # legacy alias (the seed called it .P everywhere)
    @property
    def P(self) -> int:
        return self.size

    def _allgather(self, per_local: list) -> list:
        return list(per_local)

    def _alltoallv(self, send: list) -> list:
        P = self.size
        return [[send[p][q] for p in range(P)] for q in range(P)]


class LocalComm(SimComm):
    """Degenerate single-rank world: every collective is the identity."""

    def __init__(self):
        super().__init__(1)


class LatencyComm(SimComm):
    """SimComm plus a simulated per-collective round-trip time.

    A collective's result is not *deliverable* until `latency_s` after it
    was posted: blocking calls (post + wait) therefore pay the full latency,
    while a nonblocking handle matures in the background and `wait()` only
    sleeps whatever the caller's compute did not already cover.  This is the
    in-process stand-in for transports dominated by round-trip time (the
    DistComm KV store's per-exchange RPCs); the overlap benchmark uses it to
    measure how much of a Balance round's communication the double-buffered
    loop actually hides.  Results are bit-identical to `SimComm` — only
    timing changes.
    """

    def __init__(self, num_ranks: int, latency_s: float = 0.0):
        super().__init__(num_ranks)
        self.latency_s = latency_s

    def _delayed(self, result) -> CommHandle:
        ready_at = time.monotonic() + self.latency_s

        def complete():
            rem = ready_at - time.monotonic()
            if rem > 0:
                time.sleep(rem)
            return result

        return CommHandle(complete, poll=lambda: time.monotonic() >= ready_at)

    def _iallgather(self, per_local: list) -> CommHandle:
        return self._delayed(self._allgather(per_local))

    def _ialltoallv(self, send: list) -> CommHandle:
        return self._delayed(self._alltoallv(send))


class DistComm(Comm):
    """One rank per process, over mpi4py or the jax.distributed coordinator.

    Binding order: an initialized mpi4py world with more than one process
    wins; otherwise `jax.distributed.initialize()` must have been called and
    payloads travel through the coordination service's key-value store (one
    key per peer per generation, deleted by its single reader right after
    the fetch — no cleanup barrier anywhere).  Either way the surface is
    identical to `SimComm` with `local_ranks == [rank]`, so the forest
    algorithms run unmodified.

    BOTH transports move exactly the `encode_payload` buffers — the mpi4py
    binding ships alltoallv rows as MPI.BYTE point-to-point pairs (length
    header, then payload) and allgathers as native Iallgather+Iallgatherv
    over the same packed bytes, never mpi4py's pickling object collectives —
    so the bindings are byte-for-byte interchangeable; `wire_digest()`
    exposes a running sha256 over every posted payload blob for tests to
    pin that.

    Nonblocking semantics: `iallgather`/`ialltoallv` *post* (KV writes are
    issued, MPI sends/collectives and header receives are in flight) and
    return a `CommHandle`; the blocking receive side runs in `wait()`, and
    `done()` polls (an MPI progress driver that posts the payload
    receives/collectives once the size headers land, or a short-timeout KV
    probe that caches what it fetches).  Once `done()` is True, `wait()` is
    free: no KV round-trips, no blocking MPI calls.  Handles must be waited
    in posting order, identically on every rank.  `namespace` isolates
    several DistComm instances sharing one runtime (e.g. an overlapped and
    a serialized benchmark run): it prefixes the KV keys, and gives the
    mpi4py binding its own duplicated communicator so interleaved exchanges
    cannot cross-match by tag or collective order.
    """

    def __init__(self, timeout_s: float = 120.0, namespace: str = "",
                 beacon: bool = False):
        super().__init__()
        self._timeout_ms = int(timeout_s * 1000)
        self._ns = namespace
        self._gen = 0
        self._mpi = None
        self._MPI = None
        self._client = None
        self._wire = hashlib.sha256()
        self.retry_counts: dict[str, int] = {}
        mpi = self._try_mpi()
        if mpi is not None:
            from mpi4py import MPI  # noqa: PLC0415

            # a namespaced instance needs its own tag-matching space: MPI
            # matches by (source, tag, communicator), and two instances
            # with independent generation counters would cross-match on a
            # shared communicator (Dup is collective — every rank builds
            # its DistComm instances in the same order).  The dup is owned
            # by this instance: `close()` frees it (context ids are a
            # finite MPI resource).
            self._owns_mpi = bool(namespace)
            self._mpi = mpi.Dup() if namespace else mpi
            self._MPI = MPI
            self.rank = mpi.Get_rank()
            self.size = mpi.Get_size()
        else:
            import jax
            from jax._src import distributed

            client = getattr(distributed.global_state, "client", None)
            if client is None:
                raise RuntimeError(
                    "DistComm needs an initialized jax.distributed runtime "
                    "(call jax.distributed.initialize) or an mpi4py world")
            self._client = client
            self.rank = jax.process_index()
            self.size = jax.process_count()
        # the liveness beacon is KV-only and OPT-IN: each posted generation
        # leaves a breadcrumb key so survivors can report a dead peer's
        # last-alive generation in CommTimeoutError diagnostics
        self._beacon = bool(beacon) and self._client is not None
        self.local_ranks = range(self.rank, self.rank + 1)

    @classmethod
    def _testing_instance(cls, rank: int, size: int, *, mpi=None, MPI=None,
                          client=None, timeout_s: float = 5.0,
                          namespace: str = "",
                          beacon: bool = False) -> "DistComm":
        """Build a DistComm over injected transports (fake MPI module / fake
        KV client) without a real runtime — the offline transport tests."""
        self = cls.__new__(cls)
        Comm.__init__(self)
        self._timeout_ms = int(timeout_s * 1000)
        self._ns = namespace
        self._gen = 0
        self._mpi = mpi
        self._MPI = MPI
        self._client = client
        self._wire = hashlib.sha256()
        self.retry_counts = {}
        self._beacon = bool(beacon) and client is not None
        self.rank = rank
        self.size = size
        self.local_ranks = range(rank, rank + 1)
        return self

    @staticmethod
    def _try_mpi():
        try:
            from mpi4py import MPI  # noqa: PLC0415
        except ImportError:
            return None
        if not MPI.Is_initialized() or MPI.COMM_WORLD.Get_size() < 2:
            return None
        return MPI.COMM_WORLD

    # legacy alias
    @property
    def P(self) -> int:
        return self.size

    def close(self) -> None:
        """Release owned transport resources: frees the communicator a
        namespaced mpi4py binding Dup()ed (collective — close on every
        rank, after all handles are waited).  The KV binding holds nothing
        beyond per-generation keys, which each exchange already cleans."""
        if getattr(self, "_owns_mpi", False) and self._mpi is not None:
            self._mpi.Free()
            self._mpi = None
            self._owns_mpi = False

    # -- wire accounting ---------------------------------------------------
    def _wire_update(self, outbox: dict[int, bytes]) -> None:
        """Fold every posted payload blob into the running wire digest, in
        deterministic (peer, length, bytes) order — transport independent."""
        for q in sorted(outbox):
            self._wire.update(struct.pack("<II", q, len(outbox[q])))
            self._wire.update(outbox[q])

    def wire_digest(self) -> str:
        """sha256 over every payload blob this rank has posted so far; equal
        runs over either transport yield equal digests (the packed-codec
        parity the tests assert)."""
        return self._wire.hexdigest()

    # -- KV-store transport ------------------------------------------------
    # Every exchange posts one payload key per peer (both collectives build
    # a full outbox — an empty alltoallv row still encodes as b"N"), so the
    # key's presence IS the posted signal: no targets index, and cleanup is
    # reader-side (rank q deletes `p>q` right after fetching it — exactly
    # one reader per key, so no barrier is needed anywhere).  Fetched blobs
    # are cached in the exchange state, which is what keeps cleanup off the
    # `wait()` critical path: once the poll has seen every peer
    # (`done() == True`), `wait()` touches the KV store zero times.
    def _key(self, gen: int, tag: str, rest: str) -> str:
        return f"repro_comm/{self._ns}{gen}/{tag}/{rest}"

    def _bkey(self, rank: int, gen: int) -> str:
        return f"repro_beacon/{self._ns}/{rank}/{gen}"

    def _kv_post(self, outbox: dict[int, bytes], tag: str):
        """Publish outbox[q] for each rank q; the exchange state carries the
        inbox cache that the poll and the wait fill cooperatively."""
        c = self._client
        gen = self._gen
        self._gen += 1
        me = self.rank
        for q, blob in outbox.items():
            c.key_value_set_bytes(self._key(gen, tag, f"{me}>{q}"), blob)
        if self._beacon:
            c.key_value_set_bytes(self._bkey(me, gen),
                                  frame_blob(struct.pack("<Q", gen)))
        return {"gen": gen, "tag": tag, "inbox": {},
                "phase": self._phases[-1] if self._phases else "default"}

    def _kv_fetch(self, st, p: int, timeout_ms: int) -> None:
        """Fetch-and-delete peer p's payload into the inbox cache (raises on
        timeout; the single-reader delete is this exchange's only cleanup)."""
        c = self._client
        key = self._key(st["gen"], st["tag"], f"{p}>{self.rank}")
        st["inbox"][p] = c.blocking_key_value_get_bytes(key, timeout_ms)
        c.key_value_delete(key)

    def _kv_complete(self, st) -> dict[int, bytes]:
        """Blocking receive side: fetch whatever the poll has not already
        cached — short probes in a bounded exponential-backoff + jitter
        loop instead of one flat transport-timeout RPC per peer, so a dead
        peer surfaces as a `CommTimeoutError` carrying the phase, the
        generation, the pending peers, and (with the beacon on) each one's
        last-alive generation.  Returns {p: payload_from_p}; no barrier,
        and no KV traffic at all when the handle already polled done."""
        missing = [p for p in range(self.size)
                   if p != self.rank and p not in st["inbox"]]
        if not missing:
            return st["inbox"]
        start = time.monotonic()
        deadline = start + self._timeout_ms / 1000.0
        probe_ms = max(1, min(50, self._timeout_ms))
        retries = 0
        delay = 0.0005
        while True:
            for p in list(missing):
                try:
                    self._kv_fetch(st, p, probe_ms)
                    missing.remove(p)
                except Exception:  # noqa: BLE001 - not posted yet
                    pass
            if not missing:
                if retries:
                    ph = st["phase"]
                    self.retry_counts[ph] = self.retry_counts.get(ph, 0) + retries
                return st["inbox"]
            now = time.monotonic()
            if now >= deadline:
                ph = st["phase"]
                self.retry_counts[ph] = self.retry_counts.get(ph, 0) + retries
                raise CommTimeoutError(
                    phase=ph, seq=st["gen"], elapsed_s=now - start,
                    retries=retries, rank=self.rank, size=self.size,
                    pending=missing, detail=self._beacon_probe(missing))
            retries += 1
            time.sleep(min(delay, deadline - now) * (0.5 + random.random()))
            delay = min(delay * 2.0, 0.05)

    def _beacon_probe(self, peers) -> dict:
        """Last-alive generation per stalled peer (or -1 if none seen in
        the probe window).  Beacon keys are write-only breadcrumbs, never
        deleted while the run lives, so this is a read-only diagnosis."""
        if not self._beacon:
            return {}
        out = {}
        lo = max(0, self._gen - 16)
        for p in peers:
            last = -1
            for g in range(self._gen, lo - 1, -1):
                try:
                    self._client.blocking_key_value_get_bytes(
                        self._bkey(p, g), 1)
                    last = g
                    break
                except Exception:  # noqa: BLE001 - no beacon at this gen
                    continue
            out[int(p)] = last
        return {"last_alive_gen": out}

    def _kv_ready(self, st) -> bool:
        """Poll-as-progress-driver: probe missing peers with a zero-ish
        timeout and cache (and clean up) whatever has landed, so a True
        return means `wait()` is KV-free."""
        for p in range(self.size):
            if p == self.rank or p in st["inbox"]:
                continue
            try:
                self._kv_fetch(st, p, 1)
            except Exception:  # noqa: BLE001 - miss/timeout: not posted yet
                return False
        return True

    # -- mpi4py transport --------------------------------------------------
    # Point-to-point packed exchange (alltoallv): each peer gets an 8-byte
    # length header then the integrity-framed `encode_payload` blob, both
    # as MPI.BYTE-class buffers (no pickle anywhere).  Sends and header receives post
    # immediately; payload receives post once the headers have sized their
    # buffers (in wait() or the poll).  Allgather does NOT use this path:
    # replicating one blob to P-1 peers as point-to-point pairs is O(P^2)
    # messages across the world, so it rides the native nonblocking
    # collectives below instead — same `encode_payload` buffers, same wire
    # digest.
    def _mpi_post(self, outbox: dict[int, bytes]):
        MPI, w = self._MPI, self._mpi
        gen = self._gen
        self._gen += 1
        t_hdr = (2 * gen) % 32000
        t_pay = t_hdr + 1
        keep, sreqs = [], []
        for q, blob in outbox.items():
            hdr = np.array([len(blob)], np.int64)
            buf = np.frombuffer(blob, np.uint8) if blob else np.zeros(0, np.uint8)
            keep.append((hdr, buf))
            sreqs.append(w.Isend([hdr, MPI.INT64_T], dest=q, tag=t_hdr))
            sreqs.append(w.Isend([buf, MPI.BYTE], dest=q, tag=t_pay))
        peers = [p for p in range(self.size) if p != self.rank]
        rhdr = {p: np.empty(1, np.int64) for p in peers}
        rreq = [w.Irecv([rhdr[p], MPI.INT64_T], source=p, tag=t_hdr)
                for p in peers]
        return {"keep": keep, "sreqs": sreqs, "peers": peers,
                "rhdr": rhdr, "rreq": rreq, "t_pay": t_pay}

    def _mpi_payload_recvs(self, st) -> None:
        """Once the headers are in, size the buffers and post the payload
        receives (idempotent; shared by the poll and the blocking wait)."""
        if "bufs" in st:
            return
        MPI, w = self._MPI, self._mpi
        st["bufs"] = {p: np.empty(int(st["rhdr"][p][0]), np.uint8)
                      for p in st["peers"]}
        st["preq"] = [w.Irecv([st["bufs"][p], MPI.BYTE], source=p,
                              tag=st["t_pay"])
                      for p in st["peers"]]

    def _mpi_complete(self, st) -> dict[int, bytes]:
        MPI = self._MPI
        if "bufs" not in st:
            MPI.Request.Waitall(st["rreq"])
            self._mpi_payload_recvs(st)
        MPI.Request.Waitall(st["preq"])
        MPI.Request.Waitall(st["sreqs"])
        return {p: st["bufs"][p].tobytes() for p in st["peers"]}

    def _mpi_test(self, st) -> bool:
        """Nonblocking progress driver: posts the payload receives as soon
        as the headers have completed, and reports True only when payloads
        AND sends are done — i.e. `wait()` will not block."""
        MPI = self._MPI
        if "bufs" not in st:
            if not MPI.Request.Testall(st["rreq"]):
                return False
            self._mpi_payload_recvs(st)
        return (bool(MPI.Request.Testall(st["preq"]))
                and bool(MPI.Request.Testall(st["sreqs"])))

    # Native-collective allgather: one Iallgather of the int64 blob sizes,
    # then one Iallgatherv of the payload bytes sized by it.  The payload
    # collective can only post once the sizes are in, and MPI matches
    # nonblocking collectives by POSTING ORDER on the communicator, so
    # pending payload posts drain through a FIFO — every rank posts them in
    # the same order no matter which handle's poll or wait drives progress.
    def _mpi_iag_post(self, blob: bytes):
        MPI, w = self._MPI, self._mpi
        hdr = np.array([len(blob)], np.int64)
        counts = np.zeros(self.size, np.int64)
        sbuf = np.frombuffer(blob, np.uint8) if blob else np.zeros(0, np.uint8)
        st = {"hdr": hdr, "counts": counts, "sbuf": sbuf,
              "hreq": w.Iallgather([hdr, MPI.INT64_T], [counts, MPI.INT64_T])}
        if not hasattr(self, "_iag_fifo"):
            self._iag_fifo = []
        self._iag_fifo.append(st)
        return st

    def _mpi_iag_drain(self) -> None:
        """Post payload Iallgatherv's for every pending exchange whose size
        collective has completed, in FIFO order; stop at the first that has
        not (posting a later one first would mismatch across ranks)."""
        MPI, w = self._MPI, self._mpi
        while self._iag_fifo:
            st = self._iag_fifo[0]
            if not MPI.Request.Testall([st["hreq"]]):
                return
            counts = st["counts"]
            displs = np.zeros(self.size, np.int64)
            np.cumsum(counts[:-1], out=displs[1:])
            st["rbuf"] = np.empty(int(counts.sum()), np.uint8)
            st["displs"] = displs
            st["preq"] = w.Iallgatherv(
                [st["sbuf"], MPI.BYTE],
                [st["rbuf"], counts.tolist(), displs.tolist(), MPI.BYTE])
            self._iag_fifo.pop(0)

    def _mpi_iag_complete(self, st) -> dict[int, bytes]:
        MPI = self._MPI
        if "preq" not in st:
            MPI.Request.Waitall([st["hreq"]])
            self._mpi_iag_drain()
            assert "preq" in st, "iallgather waited out of posting order"
        MPI.Request.Waitall([st["preq"]])
        d, c, buf = st["displs"], st["counts"], st["rbuf"]
        return {p: buf[int(d[p]):int(d[p]) + int(c[p])].tobytes()
                for p in range(self.size)}

    def _mpi_iag_test(self, st) -> bool:
        self._mpi_iag_drain()
        return ("preq" in st
                and bool(self._MPI.Request.Testall([st["preq"]])))

    # -- collectives -------------------------------------------------------
    def barrier(self) -> None:
        if self._mpi is not None:
            self._mpi.Barrier()
        else:
            gen = self._gen
            self._gen += 1
            self._client.wait_at_barrier(
                f"repro_comm_{self._ns}{gen}_b", self._timeout_ms)

    def _post(self, outbox: dict[int, bytes], tag: str):
        """Post one packed exchange on whichever transport is bound; returns
        (complete, poll, pending, diagnose) — closures delivering/probing
        {p: blob_from_p}, naming the undelivered peers, and (beacon on)
        reporting their last-alive generations.  The digest and the byte
        meters see the raw codec blobs; each transport value travels inside
        an integrity frame that `complete` verifies and strips, so a
        corrupted/truncated/duplicated wire byte surfaces as a
        `WireIntegrityError` naming the phase, generation, and peer."""
        self._wire_update(outbox)
        phase = self._phases[-1] if self._phases else "default"
        gen = self._gen
        framed = {q: frame_blob(b) for q, b in outbox.items()}
        if self._mpi is not None:
            st = self._mpi_post(framed)
            raw, poll = (lambda: self._mpi_complete(st)), \
                        (lambda: self._mpi_test(st))
            pending = None
        else:
            st = self._kv_post(framed, tag)
            raw, poll = (lambda: self._kv_complete(st)), \
                        (lambda: self._kv_ready(st))
            pending = lambda: [p for p in range(self.size)
                               if p != self.rank and p not in st["inbox"]]

        def complete():
            return {p: unframe_blob(
                        b, where=f"{phase}:{tag}:gen{gen}:{p}->{self.rank}")
                    for p, b in raw().items()}

        diagnose = ((lambda: self._beacon_probe(pending()))
                    if (pending is not None and self._beacon) else None)
        return complete, poll, pending, diagnose

    def _iallgather(self, per_local: list) -> CommHandle:
        x = per_local[0]
        blob = encode_payload(x)
        outbox = {q: blob for q in range(self.size) if q != self.rank}
        if self._mpi is not None:
            # native collective path: O(log P) fan-out instead of P-1 p2p
            # pairs per rank, over the SAME per-peer logical blobs — the
            # digest folds them exactly as the KV binding does, so
            # `wire_digest()` parity across bindings is preserved.  The
            # collective moves the framed blob; every rank's slice is
            # integrity-checked on delivery.
            self._wire_update(outbox)
            phase = self._phases[-1] if self._phases else "default"
            gen = self._gen
            st = self._mpi_iag_post(frame_blob(blob))

            def deliver():
                parts = self._mpi_iag_complete(st)
                out = [decode_payload(unframe_blob(
                           parts[p],
                           where=f"{phase}:iag:gen{gen}:{p}->{self.rank}"))
                       for p in range(self.size)]
                out[self.rank] = x
                return out

            return CommHandle(deliver, poll=lambda: self._mpi_iag_test(st))
        complete, poll, pending, diagnose = self._post(outbox, "ag")

        def deliver():
            out = [None] * self.size
            out[self.rank] = x
            for p, b in complete().items():
                out[p] = decode_payload(b)
            return out

        h = CommHandle(deliver, poll=poll)
        h._pending = pending
        h._diagnose = diagnose
        return h

    def _ialltoallv(self, send: list) -> CommHandle:
        row = send[0]
        outbox = {q: encode_payload(row[q])
                  for q in range(self.size) if q != self.rank}
        complete, poll, pending, diagnose = self._post(outbox, "a2a")

        def deliver():
            recv = [None] * self.size
            recv[self.rank] = row[self.rank]
            for p, b in complete().items():
                recv[p] = decode_payload(b)
            return [recv]

        h = CommHandle(deliver, poll=poll)
        h._pending = pending
        h._diagnose = diagnose
        return h
