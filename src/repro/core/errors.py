"""Structured errors of the fault-tolerant runtime.

One tiny dependency-free module so every layer — the wire codec
(`repro.core.types`, `repro.core.comm`), the transports (`DistComm`), the
chaos harness (`repro.core.resilience`), the checkpoint store
(`repro.checkpoint.forest_io`), and the subprocess launcher
(`repro.launch.multiproc`) — can raise and catch the same exception types
without import cycles.  `repro.core.resilience` re-exports them as the
user-facing surface.

The hierarchy turns the three historical failure modes of the distributed
pipeline — a bare `struct.error` from a malformed buffer, a silent wrong
decode, and a flat 120-second hang with no diagnosis — into typed errors
that carry enough context (phase, peer, generation, retry counts, checksum
mismatch) to reproduce and route around the fault.
"""

from __future__ import annotations

__all__ = [
    "ResilienceError",
    "WireFormatError",
    "WireIntegrityError",
    "CommTimeoutError",
    "CheckpointIntegrityError",
    "InjectedCrash",
    "RankTimeoutError",
]


class ResilienceError(RuntimeError):
    """Base class of every structured fault-path error in this repo."""


class WireFormatError(ResilienceError, ValueError):
    """A wire buffer is not a well-formed payload.

    Raised by `repro.core.comm.decode_payload` and
    `repro.core.types.unpack_wire` for truncated, trailing-garbage, or
    structurally invalid buffers — never a bare `struct.error`, `KeyError`,
    or a silently misaligned column decode."""


class WireIntegrityError(ResilienceError):
    """A framed transport payload failed its integrity check.

    Every `DistComm` transport blob travels as `frame_blob` output — a
    (magic, length, CRC32) header plus the raw `encode_payload` bytes — and
    `unframe_blob` raises this when the magic, length, or checksum does not
    match (corruption, truncation, or duplication on the wire)."""

    def __init__(self, reason: str, *, where: str = "",
                 expected=None, actual=None):
        self.reason = reason
        self.where = where
        self.expected = expected
        self.actual = actual
        msg = f"wire integrity failure: {reason}"
        if expected is not None or actual is not None:
            msg += f" (expected {expected!r}, got {actual!r})"
        if where:
            msg += f" [{where}]"
        super().__init__(msg)


class CommTimeoutError(ResilienceError, TimeoutError):
    """A collective did not complete before its deadline.

    Replaces the bare hang / opaque transport exception with the context a
    survivor needs to diagnose (and a driver needs to recover from) a dead
    or stalled peer: which `phase` the pipeline was in ("balance",
    "ghost", "repartition", "checkpoint", ...), which collective `seq`
    (posting generation) stalled, how long we waited and how many poll
    retries ran, and — where the transport knows — which `pending` peers
    never delivered plus a `detail` dict (e.g. the last liveness-beacon
    generation seen per peer)."""

    def __init__(self, *, phase: str = "default", seq: int = -1,
                 elapsed_s: float = 0.0, retries: int = 0,
                 rank: int | None = None, size: int | None = None,
                 pending=None, detail: dict | None = None):
        self.phase = phase
        self.seq = seq
        self.elapsed_s = elapsed_s
        self.retries = retries
        self.rank = rank
        self.size = size
        self.pending = None if pending is None else sorted(int(p) for p in pending)
        self.detail = detail or {}
        who = "" if rank is None else f" on rank {rank}" + (
            f"/{size}" if size is not None else "")
        peers = ("" if self.pending is None
                 else f"; still waiting on peers {self.pending}")
        extra = f"; {self.detail}" if self.detail else ""
        super().__init__(
            f"collective #{seq} in phase '{phase}' timed out after "
            f"{elapsed_s:.3f}s{who} ({retries} poll retries){peers}{extra}")


class CheckpointIntegrityError(ResilienceError):
    """A forest checkpoint is unreadable, corrupted, or invalid on restore.

    Raised by `repro.checkpoint.forest_io.load_forest` when a payload blob
    is truncated/garbage, a stored CRC32 disagrees with the bytes on disk,
    the element count contradicts the manifest, or the restored global
    forest fails `forest.validate`."""


class InjectedCrash(ResilienceError):
    """A `ChaosComm` crash-at-collective fault fired (in-process mode).

    Subprocess chaos runs use a hard `os._exit` instead so the process dies
    exactly like a real rank failure; in-process (SimComm-hosted) runs
    raise this so tests can catch the crash and exercise `recover`."""

    def __init__(self, *, phase: str, seq: int, rank: int):
        self.phase = phase
        self.seq = seq
        self.rank = rank
        super().__init__(
            f"injected crash at collective #{seq} in phase '{phase}' "
            f"on rank {rank}")


class RankTimeoutError(ResilienceError, TimeoutError):
    """`run_ranks` hit its wall-clock budget and killed the fleet.

    Carries every rank's exit state and captured stderr tail so a hung
    subprocess run fails FAST with a diagnosis instead of stalling the
    test tier; `per_rank` maps rank -> (state, stderr_tail)."""

    def __init__(self, message: str, per_rank: dict | None = None):
        self.per_rank = per_rank or {}
        super().__init__(message)
