"""Core library: the tetrahedral-Morton space-filling curve (Burstedde-Holke).

Layers:
  tables     — derived lookup tables (types, TM order, neighbors, Prop. 23)
  types      — the Tet / Simplex SoA data type (10/14-byte encoding at rest)
  u64        — uint32-pair integer arithmetic (TPU-safe 64-bit emulation)
  ops        — vectorized constant-time element algorithms (paper Section 4)
  batch      — batched element-ops dispatch (reference / jnp / pallas backends)
  cmesh      — coarse-mesh inter-tree connectivity (gluing tables, transforms)
  comm       — the Comm surface: SimComm / LocalComm / DistComm + byte meters
  reference  — pure-Python oracles (tests only)
  forest     — forest-of-trees AMR: New / Adapt / Partition / Balance / Ghost
  placement  — SFC-based load balancing applied to LM training workloads
"""

from .tables import MAXLEVEL, SFCTables, get_tables
from .types import Simplex, root, simplex
from .ops import SimplexOps, get_ops, ops2d, ops3d
from .batch import BatchedOps, get_batch_ops, get_backend, set_backend, use_backend
from .comm import Comm, DistComm, LocalComm, SimComm
from .cmesh import (
    Cmesh,
    cmesh_brick,
    cmesh_disconnected,
    cmesh_rotated_pair,
    cmesh_single,
    cmesh_unit_cube,
)
from . import u64

__all__ = [
    "MAXLEVEL",
    "SFCTables",
    "get_tables",
    "Cmesh",
    "cmesh_brick",
    "cmesh_disconnected",
    "cmesh_rotated_pair",
    "cmesh_single",
    "cmesh_unit_cube",
    "Simplex",
    "root",
    "simplex",
    "SimplexOps",
    "get_ops",
    "ops2d",
    "ops3d",
    "BatchedOps",
    "Comm",
    "DistComm",
    "LocalComm",
    "SimComm",
    "get_batch_ops",
    "get_backend",
    "set_backend",
    "use_backend",
    "u64",
]
