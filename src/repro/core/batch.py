"""Batched element-ops dispatch layer for the forest hot loops.

The paper's New/Adapt/Balance/Ghost pipelines spend essentially all their
time in constant-time element queries (parent, children, face-neighbor,
successor, encode/decode — Sections 4.5-4.6).  This module is the single
seam through which the forest layer reaches that math, with three
interchangeable backends over `Simplex` batches:

  reference   the existing `SimplexOps` methods, dispatched eagerly op by op
              (the seed's behaviour; every intermediate materialises).
  jnp         the same algorithms under `jax.jit` with power-of-two padding
              buckets, so each op is one fused XLA program and the number of
              distinct compiled shapes stays O(log n).
  pallas      the tiled Pallas kernels from `repro.kernels` (interpret mode
              on CPU, compiled tiles on TPU).

All three produce bit-identical integer results; the backend knob trades
dispatch overhead against compile time.  Select globally via the
``REPRO_BACKEND`` env var, `set_backend()`, or the `use_backend()` context
manager.  Unknown names fall back to `reference`; a `pallas` backend that
fails its self-test (e.g. no Pallas lowering on this host) falls back to
`jnp` — both with a warning, never an error.

Future scaling PRs (sharding, multi-device partition) plug in here: a new
backend only has to implement the `BatchedOps` method surface (the eight
per-element algorithms, the cross-tree `tree_transform`, and the
marker-table `owner_rank` searchsorted that routes the message-based
Balance/Ghost).
"""

from __future__ import annotations

import contextlib
import functools
import os
import warnings
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import u64 as u64m
from .ops import ElementOps, get_ops
from .types import ECLASS_SIMPLEX, Simplex

__all__ = [
    "BACKENDS",
    "BatchedOps",
    "FaceSweep",
    "SweepHandle",
    "LeafTable",
    "RoutePairs",
    "get_backend",
    "set_backend",
    "use_backend",
    "get_batch_ops",
    "dispatch_counts",
    "reset_dispatch_counts",
    "count_dispatch",
    "trace_counts",
    "reset_trace_counts",
    "host_fetch_counts",
    "reset_host_fetch_counts",
]

BACKENDS = ("reference", "jnp", "pallas")
_ENV_VAR = "REPRO_BACKEND"
_active: str | None = None  # resolved lazily so the env var can be set late


def _resolve(name: str, source: str) -> str:
    name = name.strip().lower()
    if name not in BACKENDS:
        warnings.warn(
            f"unknown element-ops backend {name!r} from {source}; "
            f"falling back to 'reference' (choices: {BACKENDS})",
            stacklevel=3,
        )
        return "reference"
    return name


def get_backend() -> str:
    """The active backend name (env var ``REPRO_BACKEND``, default reference)."""
    global _active
    if _active is None:
        _active = _resolve(os.environ.get(_ENV_VAR, "reference"), f"${_ENV_VAR}")
    return _active


def set_backend(name: str) -> None:
    global _active
    _active = _resolve(name, "set_backend()")


@contextlib.contextmanager
def use_backend(name: str):
    """Temporarily switch the element-ops backend (tests / benchmarks)."""
    global _active
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        _active = prev


# ---------------------------------------------------------- dispatch counters
# One increment per BatchedOps op invocation (any backend) — the observable
# the fused face sweep optimizes: Balance/Ghost evaluation must issue ONE
# `face_sweep` dispatch per eval layer instead of 3 x (d+1) per-face ops.
# Benchmarks and tests read/reset these around a measured region.
_dispatch_counts: dict[str, int] = {}


def reset_dispatch_counts() -> None:
    """Zero the per-op dispatch counters."""
    _dispatch_counts.clear()


def dispatch_counts() -> dict[str, int]:
    """Snapshot of {op name: number of BatchedOps dispatches} since reset."""
    return dict(_dispatch_counts)


def count_dispatch(name: str) -> None:
    """Charge one dispatch to `name` without dispatching: callers that
    memoize a batched-op result on immutable data (e.g. the per-Forest
    resident sweep) keep the meters' evals-per-round semantics by charging
    each reuse like the dispatch it replaces."""
    _dispatch_counts[name] = _dispatch_counts.get(name, 0) + 1


# Trace counters: one increment per *jit trace* of a fused-eval program
# (bumped inside the traced body, so cache hits cost nothing).  With padded
# power-of-two buckets the totals must stay O(log n) for the process — the
# retrace-guard test asserts zero NEW traces when Balance re-runs at the
# same bucket sizes.
_trace_counts: dict[str, int] = {}

# Host-fetch counters: one increment per device->host materialization on the
# fused eval path (`eval_2to1` / `eval_cache` / `eval_route` each fetch ONE
# compacted result).  The device_eval benchmark asserts <= 2 per rank per
# Balance round, replacing the old per-field np.asarray fan-out.
_host_fetch_counts: dict[str, int] = {}


def reset_trace_counts() -> None:
    """Zero the fused-eval jit trace counters."""
    _trace_counts.clear()


def trace_counts() -> dict[str, int]:
    """Snapshot of {program name: jit traces} since reset."""
    return dict(_trace_counts)


def _bump_trace(name: str) -> None:
    _trace_counts[name] = _trace_counts.get(name, 0) + 1


def reset_host_fetch_counts() -> None:
    """Zero the fused-eval host materialization counters."""
    _host_fetch_counts.clear()


def host_fetch_counts() -> dict[str, int]:
    """Snapshot of {eval stage: device->host materializations} since reset."""
    return dict(_host_fetch_counts)


def _bump_fetch(name: str) -> None:
    _host_fetch_counts[name] = _host_fetch_counts.get(name, 0) + 1


class FaceSweep(NamedTuple):
    """Result of the fused all-faces sweep, leading axis = face (nf rows:
    d+1 for simplices, 2d for hexes).

    neighbor  same-level neighbor per face: anchor (nf, n, d), level/stype
              (nf, n) — possibly outside the root (check `inside`)
    dual      (nf, n) int32 neighbor's face index back to us
    inside    (nf, n) bool inside-root mask
    key       (nf, n) U64 neighbor morton keys (garbage where ~inside on a
              domain boundary — never read them there)
    """

    neighbor: Simplex
    dual: jax.Array
    inside: jax.Array
    key: u64m.U64


# ---------------------------------------------------------------- jnp backend
def _bucket(n: int) -> int:
    """Next power-of-two batch size (>= 16): bounds jit recompiles to O(log n)."""
    return max(16, 1 << max(0, n - 1).bit_length())


def _pad1(a, m):
    return jnp.pad(a, [(0, m - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def _pad_simplex(s: Simplex, m: int) -> Simplex:
    return Simplex(_pad1(s.anchor, m), _pad1(s.level, m), _pad1(s.stype, m))


def _face_sweep_fused(o: ElementOps):
    """One jitted program for the whole face sweep: vmap over the nf face
    indices of (face_neighbor, is_inside_root, morton_key) — a single XLA
    dispatch instead of 3 x nf."""

    def fn(s: Simplex) -> FaceSweep:
        def one(f):
            nb, dual = o.face_neighbor(s, f)
            return FaceSweep(nb, dual, o.is_inside_root(nb), o.morton_key(nb))

        return jax.vmap(one)(jnp.arange(o.nf, dtype=jnp.int32))

    return fn


@functools.lru_cache(maxsize=None)
def _jnp_fns(d: int, eclass: int = ECLASS_SIMPLEX):
    o = get_ops(d, eclass)
    return {
        "morton_key": jax.jit(o.morton_key),
        "decode": jax.jit(o.decode_key),
        "parent": jax.jit(o.parent),
        "parent_and_local_index": jax.jit(lambda s: (o.parent(s), o.local_index(s))),
        "children": jax.jit(o.children_tm),
        "face_neighbor": jax.jit(o.face_neighbor),
        "face_sweep": jax.jit(_face_sweep_fused(o)),
        "successor": jax.jit(o.successor),
        "is_inside_root": jax.jit(o.is_inside_root),
        "local_index": jax.jit(o.local_index),
        "tree_transform": jax.jit(o.tree_transform),
    }


def _pad_markers(marker_tree: np.ndarray, marker_key: np.ndarray):
    """Pad the per-rank marker table to a power of two (>= 8) with lex-+inf
    sentinels (tree = int32 max) so compiled shapes stay O(log P) and padding
    never counts in the searchsorted."""
    P = len(marker_tree)
    m = max(8, 1 << max(0, P - 1).bit_length())
    mt = np.full(m, np.iinfo(np.int32).max, np.int32)
    mk = np.zeros(m, np.uint64)
    mt[:P] = marker_tree
    mk[:P] = marker_key
    return mt, mk


# Memoized pad + device transfer of the marker table, keyed on CONTENT (the
# marker bytes): every Balance round calls `owner_rank` many times with the
# same P-entry table, and re-padding/re-uploading it per call was pure
# overhead.  The previous identity key (`id(mt), id(mk)`) silently served
# stale device markers to a caller that mutated a table in place (identity
# unchanged, content changed) — the content key closes that hole and also
# dedupes equal-content tables that arrive as fresh arrays.  Hashing P
# entries per call is O(P) host work, noise next to one dispatch.
_marker_pad_cache: OrderedDict = OrderedDict()
_MARKER_CACHE_SIZE = 16

# Same idea for the per-rank boundary scalars of the fused eval programs
# (8 device scalars per (markers, rank)) and the rank-id scalar.
_boundary_scalar_cache: OrderedDict = OrderedDict()
_rank_scalar_cache: dict[int, jax.Array] = {}


def _rank_scalar(g: int):
    hit = _rank_scalar_cache.get(g)
    if hit is None:
        hit = _rank_scalar_cache[g] = jnp.int32(g)
    return hit


def _padded_markers_cached(mt: np.ndarray, mk: np.ndarray):
    """(device marker_tree, device marker_key U64), padded with sentinels."""
    key = (mt.tobytes(), mk.tobytes())
    hit = _marker_pad_cache.get(key)
    if hit is not None:
        _marker_pad_cache.move_to_end(key)
        return hit
    mt_p, mk_p = _pad_markers(mt, mk)
    val = (jnp.asarray(mt_p), u64m.from_int(mk_p))
    _marker_pad_cache[key] = val
    while len(_marker_pad_cache) > _MARKER_CACHE_SIZE:
        _marker_pad_cache.popitem(last=False)
    return val


def owner_rank_lex(t, hi, lo, mt, mhi, mlo):
    """The one shared lex searchsorted: index of the last marker (mt, mhi,
    mlo) lex-<= (t, hi, lo), clamped to 0.  The jnp backend jits exactly
    this; `repro.kernels.ref.owner_rank_ref` delegates here so the Pallas
    kernel's oracle can never drift from the backend implementations."""
    le = (mt[None, :] < t[:, None]) | (
        (mt[None, :] == t[:, None])
        & ((mhi[None, :] < hi[:, None])
           | ((mhi[None, :] == hi[:, None]) & (mlo[None, :] <= lo[:, None])))
    )
    return jnp.maximum(le.astype(jnp.int32).sum(axis=1) - 1, 0)


_owner_rank_jnp = jax.jit(owner_rank_lex)


# ------------------------------------------------------- device-resident eval
# The fused Balance/Ghost eval stage.  A round's evaluation is three device
# programs over ONE resident face sweep — need-mask vs the local leaf table,
# need-mask vs the remote-leaf cache, and boundary query routing — with the
# host only slicing the compacted routing rows to build wire triples.  The
# reference backend runs the same algorithms eagerly in numpy and is the
# bit-identical oracle.


class SweepHandle(NamedTuple):
    """One face sweep of an element layer, resident where the backend
    computes: `host` numpy arrays under `reference`, bucket-padded device
    arrays under `jnp`/`pallas` (stable shapes, so the fused eval programs
    never retrace across Balance rounds at a fixed bucket).

      host  (tgt, nkey, valid, dual, level): target tree (d+1, n) int32,
            neighbor keys (d+1, n) uint64, validity mask (d+1, n) bool,
            dual faces (d+1, n) int32, element levels (n,) int32
      dev   (tgt, khi, klo, valid, dual, level) padded to bucket m, the
            uint64 keys carried as (hi, lo) uint32 words
    """

    n: int
    host: tuple | None
    dev: tuple | None


class LeafTable(NamedTuple):
    """A lex-sorted (tree, key, level) leaf table — the local leaves or the
    remote-leaf cache — uploaded once per Balance round.  `host` feeds the
    reference oracle; `dev` is padded to a power of two with lex-+inf
    sentinels (tree = int32 max, level = -1) so the device binary search
    never counts padding."""

    n: int
    host: tuple | None
    dev: tuple | None


class RoutePairs(NamedTuple):
    """Compacted query candidates from `eval_route`: one row per (face,
    element) pair whose neighbor key interval reaches outside the calling
    rank's partition — the ONLY sweep data the host slices off device on
    the routing path."""

    tree: np.ndarray
    key: np.ndarray
    level: np.ndarray
    dual: np.ndarray
    first: np.ndarray
    last: np.ndarray


def _empty_route() -> RoutePairs:
    z = np.zeros(0, np.int32)
    return RoutePairs(z, np.zeros(0, np.uint64), z.copy(), z.copy(), z.copy(), z.copy())


def _spans_np(d: int, L: int, level: np.ndarray) -> np.ndarray:
    """Keys covered by one element at `level`: 2^(d*(L-level)), uint64."""
    return np.uint64(1) << (
        np.uint64(d) * (np.uint64(L) - np.asarray(level).astype(np.uint64))
    )


def _range_max_np(values: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Per-slice max(values[lo:hi]) (or -1 for empty slices), vectorized via
    maximum.reduceat over independent [lo, hi) segment pairs."""
    out = np.full(len(lo), -1, np.int32)
    m = hi > lo
    if not m.any():
        return out
    ext = np.append(np.asarray(values, np.int32), np.int32(-1))  # allow hi == len
    idx = np.nonzero(m)[0]
    pairs = np.stack([lo[idx], hi[idx]], axis=1).reshape(-1)
    out[idx] = np.maximum.reduceat(ext, pairs)[::2]
    return out


def _owner_np(tree: np.ndarray, key: np.ndarray, mt: np.ndarray, mk: np.ndarray):
    """Host mirror of `owner_rank_lex` over uint64 keys."""
    le = (mt[None, :] < tree[:, None]) | (
        (mt[None, :] == tree[:, None]) & (mk[None, :] <= key[:, None])
    )
    return np.maximum(le.sum(axis=1).astype(np.int32) - 1, 0)


@functools.lru_cache(maxsize=None)
def _eval_progs(d: int, eclass: int = ECLASS_SIMPLEX):
    """The jitted device programs of the fused eval stage, per (dimension,
    element class).

    Every program takes padded buffers only — element buffers quantized to
    `_bucket` sizes, leaf tables and markers to their own power-of-two pads
    — so the set of compiled shapes is O(log n) for the life of the process
    (`trace_counts()` observes it; the device_eval suite asserts it)."""
    o = get_ops(d, eclass)
    L = o.L
    nf = o.nf

    def lex_lt(t1, h1, l1, t2, h2, l2):
        return (t1 < t2) | (
            (t1 == t2) & ((h1 < h2) | ((h1 == h2) & (l1 < l2)))
        )

    def lower_bound(lt, lhi, llo, qt, qhi, qlo):
        # Uniform binary search over the pow2-padded lex table: first index
        # whose (tree, key) is lex->= the query.  The residual compare after
        # the loop settles the all-entries-less case (pos would stick at
        # m - 1 without it).
        m = lt.shape[0]
        pos = jnp.zeros(qt.shape, jnp.int32)
        sz = m // 2
        while sz >= 1:
            mid = pos + (sz - 1)
            go = lex_lt(lt[mid], lhi[mid], llo[mid], qt, qhi, qlo)
            pos = jnp.where(go, pos + jnp.int32(sz), pos)
            sz //= 2
        go = lex_lt(lt[pos], lhi[pos], llo[pos], qt, qhi, qlo)
        return pos + go.astype(jnp.int32)

    def interval_end(khi, klo, lev2):
        # Element keys are span-aligned, so the last key of the neighbor's
        # interval is key | (2^(d*(L-level)) - 1) — a dynamic-width mask
        # from O(log) selects over the (hi, lo) words.
        sb = d * (L - lev2)
        one = u64m.U64(jnp.zeros_like(khi), jnp.full_like(klo, 1))
        mask = u64m.dec(u64m.select_shl(one, sb, 63))
        return u64m.or_(u64m.U64(khi, klo), mask)

    def finer_mask(tgt, khi, klo, lev2, kend, lt, lhi, llo, llev):
        # cnt[thr, j] = #leaves among the first j with level >= thr, so the
        # "any strictly-finer-than-l+1 leaf in the interval" test is one
        # subtraction per (face, element) pair.  Sentinel rows carry
        # level = -1 and never count; thr is clamped to L + 1 so levels
        # >= L - 1 (which can never have a 2-finer leaf) read a zero row.
        rows = jnp.arange(L + 2, dtype=jnp.int32)
        incr = (llev[None, :] >= rows[:, None]).astype(jnp.int32)
        cnt = jnp.concatenate(
            [jnp.zeros((L + 2, 1), jnp.int32), jnp.cumsum(incr, axis=1)], axis=1
        )
        lo_i = lower_bound(lt, lhi, llo, tgt, khi, klo)
        hi_i = lower_bound(lt, lhi, llo, tgt, kend.hi, kend.lo)
        thr = jnp.minimum(lev2 + 2, L + 1)
        return (cnt[thr, hi_i] - cnt[thr, lo_i]) > 0

    def off_mask(tgt, khi, klo, kh, b0t, b0h, b0l, h0, b1t, b1h, b1l, h1):
        # Interval escapes this rank's partition range [marker_g,
        # marker_{g+1}): lex (t, k) below the lower marker, or lex
        # (t, k_end) at/above the upper one.  h0/h1 gate the domain ends.
        off = h0 & lex_lt(tgt, khi, klo, b0t, b0h, b0l)
        return off | (h1 & ~lex_lt(tgt, kh.hi, kh.lo, b1t, b1h, b1l))

    def sweep_jnp(s, tree, n):
        _bump_trace("sweep")
        m = s.level.shape[0]
        sw = _face_sweep_fused(o)(s)
        valid = sw.inside & (jnp.arange(m) < n)[None, :]
        tgt = jnp.broadcast_to(tree[None, :], (nf, m))
        return tgt, sw.key.hi, sw.key.lo, valid, sw.dual, s.level

    def sweep_pallas(s, tree, n):
        _bump_trace("sweep_pallas")
        from repro.kernels import ops as kops

        m = s.level.shape[0]
        nb, dual, inside, key = kops.face_sweep(d, s, min(1024, m), eclass)
        valid = inside & (jnp.arange(m) < n)[None, :]
        tgt = jnp.broadcast_to(tree[None, :], (nf, m))
        return tgt, key.hi, key.lo, valid, dual, s.level

    def need_fn(tgt, khi, klo, valid, lev,
                lt, lhi, llo, llev,
                b0t, b0h, b0l, h0, b1t, b1h, b1l, h1):
        _bump_trace("eval_need")
        m = lev.shape[0]
        lev2 = jnp.broadcast_to(lev[None, :], (nf, m))
        kh = interval_end(khi, klo, lev2)
        kend = u64m.inc(kh)
        finer = finer_mask(tgt, khi, klo, lev2, kend, lt, lhi, llo, llev)
        need = jnp.any(valid & finer, axis=0)
        off = off_mask(tgt, khi, klo, kh, b0t, b0h, b0l, h0, b1t, b1h, b1l, h1)
        bmask = jnp.any(valid & off, axis=0)
        return need, bmask

    def cache_fn(tgt, khi, klo, valid, lev,
                 lt, lhi, llo, llev,
                 b0t, b0h, b0l, h0, b1t, b1h, b1l, h1):
        _bump_trace("eval_cache")
        m = lev.shape[0]
        lev2 = jnp.broadcast_to(lev[None, :], (nf, m))
        kh = interval_end(khi, klo, lev2)
        kend = u64m.inc(kh)
        off = off_mask(tgt, khi, klo, kh, b0t, b0h, b0l, h0, b1t, b1h, b1l, h1)
        bmask = jnp.any(valid & off, axis=0)
        evalp = valid & bmask[None, :]
        finer = finer_mask(tgt, khi, klo, lev2, kend, lt, lhi, llo, llev)
        return jnp.any(evalp & finer, axis=0)

    def route_pack(t, khi, klo, lev, dual, first, last, remote):
        # Cumsum-scatter compaction: remote rows land densely at the front,
        # non-remote lanes dump into the extra row sz (never read — the
        # host slices [:count]).  Flattened C-order of (d+1, m) keeps the
        # face-major row order of the host oracle's np.nonzero.
        sz = t.shape[0]
        idx = jnp.cumsum(remote.astype(jnp.int32)) - 1
        scat = jnp.where(remote, idx, jnp.int32(sz))
        cols = jnp.stack(
            [t, khi.astype(jnp.int32), klo.astype(jnp.int32),
             lev, dual, first, last], axis=1)
        packed = jnp.zeros((sz + 1, 7), jnp.int32).at[scat].set(cols)
        return remote.astype(jnp.int32).sum(), packed

    def route_fn(tgt, khi, klo, valid, dual, lev, mt, mhi, mlo, g):
        _bump_trace("eval_route")
        m = lev.shape[0]
        lev2 = jnp.broadcast_to(lev[None, :], (nf, m))
        kh = interval_end(khi, klo, lev2)
        tf, hf, lf = tgt.reshape(-1), khi.reshape(-1), klo.reshape(-1)
        first = owner_rank_lex(tf, hf, lf, mt, mhi, mlo)
        last = owner_rank_lex(
            tf, kh.hi.reshape(-1), kh.lo.reshape(-1), mt, mhi, mlo)
        remote = valid.reshape(-1) & ((first != g) | (last != g))
        return route_pack(tf, hf, lf, lev2.reshape(-1), dual.reshape(-1),
                          first, last, remote)

    def route_pallas(tgt, khi, klo, valid, dual, lev, mt, mhi, mlo, g):
        _bump_trace("eval_route_pallas")
        from repro.kernels import ops as kops

        m = lev.shape[0]
        lev2 = jnp.broadcast_to(lev[None, :], (nf, m))
        _hh, _hl, first, last = kops.eval_route(
            d, tgt, khi, klo, lev2, mt, mhi, mlo, min(1024, m))
        first, last = first.reshape(-1), last.reshape(-1)
        remote = valid.reshape(-1) & ((first != g) | (last != g))
        return route_pack(tgt.reshape(-1), khi.reshape(-1), klo.reshape(-1),
                          lev2.reshape(-1), dual.reshape(-1),
                          first, last, remote)

    return {
        "sweep": jax.jit(sweep_jnp),
        "sweep_pallas": jax.jit(sweep_pallas),
        "need": jax.jit(need_fn),
        "cache": jax.jit(cache_fn),
        "route": jax.jit(route_fn),
        "route_pallas": jax.jit(route_pallas),
    }


# ------------------------------------------------------------- pallas backend
@functools.lru_cache(maxsize=None)
def _pallas_ok(d: int, eclass: int = ECLASS_SIMPLEX) -> bool:
    """One-element self-test; on failure the pallas backend degrades to jnp."""
    try:
        from repro.kernels import ops as kops

        nf = get_ops(d, eclass).nf
        s = Simplex(
            jnp.zeros((1, d), jnp.int32), jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32)
        )
        kops.morton_key(d, s, 16, eclass)
        kops.face_sweep(d, s, 16, eclass)
        z2 = jnp.zeros((nf, 16), jnp.int32)
        u2 = jnp.zeros((nf, 16), jnp.uint32)
        kops.eval_route(
            d, z2, u2, u2, z2,
            jnp.full(8, np.iinfo(np.int32).max, jnp.int32),
            jnp.zeros(8, jnp.uint32), jnp.zeros(8, jnp.uint32), 16)
        return True
    except Exception as e:  # noqa: BLE001 - any lowering failure means fallback
        warnings.warn(
            f"pallas backend unavailable for d={d}, eclass={eclass} ({e!r}); "
            f"using jnp")
        return False


# -------------------------------------------------------------------- dispatch
class BatchedOps:
    """Backend-bound batched element ops over `Simplex` arrays of shape (n,).

    The methods mirror the paper's constant-time element algorithms (plus
    the cross-tree coordinate change of `repro.core.cmesh`); every forest
    hot loop (adapt's child generation and family-head scan, balance's and
    ghost's neighbor sweeps — across tree faces included) consumes exactly
    this surface.
    """

    def __init__(self, d: int, backend: str, eclass: int = ECLASS_SIMPLEX):
        backend = _resolve(backend, "get_batch_ops()")
        if backend == "pallas" and not _pallas_ok(d, eclass):
            backend = "jnp"
        self.d = d
        self.eclass = eclass
        self.backend = backend
        self.ops: ElementOps = get_ops(d, eclass)
        self.nf = self.ops.nf

    # -- helpers -----------------------------------------------------------
    def _which(self, n: int, name: str | None = None) -> str:
        # Empty batches short-circuit to the eager path (a Pallas grid of 0
        # tiles is invalid, and there is nothing to fuse anyway).
        if name is not None:
            _dispatch_counts[name] = _dispatch_counts.get(name, 0) + 1
        return "reference" if n == 0 else self.backend

    def _jnp(self, name, s: Simplex, *extra):
        n = s.level.shape[0]
        m = _bucket(n)
        out = _jnp_fns(self.d, self.eclass)[name](_pad_simplex(s, m), *extra)
        return out, n

    @staticmethod
    def _cut(x, n):
        return jax.tree_util.tree_map(lambda a: a[:n], x)

    def _pallas(self, fn, s: Simplex, *extra):
        """Bucket-pad before the jit'd kernel wrapper (same O(log n) compiled
        shapes as the jnp path), then slice the outputs back."""
        n = s.level.shape[0]
        m = _bucket(n)
        return self._cut(
            fn(self.d, _pad_simplex(s, m), *extra, min(1024, m), self.eclass), n)

    # -- API ---------------------------------------------------------------
    def morton_key(self, s: Simplex) -> u64m.U64:
        """Level-padded consecutive index (the mixed-level SFC sort key)."""
        which = self._which(s.level.shape[0], "morton_key")
        if which == "reference":
            return self.ops.morton_key(s)
        if which == "jnp":
            out, n = self._jnp("morton_key", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        hi, lo = self._pallas(kops.morton_key, s)
        return u64m.U64(hi, lo)

    def morton_key_np(self, s: Simplex) -> np.ndarray:
        """Host-side uint64 keys (the forest's storage format)."""
        return u64m.to_np(self.morton_key(s))

    def decode(self, key: u64m.U64, level) -> Simplex:
        """Algorithm 4.8 from a level-padded key (inverse of `morton_key`)."""
        level = jnp.asarray(level, jnp.int32)
        which = self._which(key.hi.shape[0], "decode")
        if which == "reference":
            return self.ops.decode_key(key, level)
        if which == "jnp":
            n = key.hi.shape[0]
            m = _bucket(n)
            padded = u64m.U64(_pad1(key.hi, m), _pad1(key.lo, m))
            return self._cut(
                _jnp_fns(self.d, self.eclass)["decode"](padded, _pad1(level, m)), n)
        from repro.kernels import ops as kops

        n = key.hi.shape[0]
        m = _bucket(n)
        padded = u64m.U64(_pad1(key.hi, m), _pad1(key.lo, m))
        return self._cut(
            kops.decode(self.d, padded, _pad1(level, m), min(1024, m), self.eclass), n
        )

    def parent(self, s: Simplex) -> Simplex:
        """Algorithm 4.3."""
        which = self._which(s.level.shape[0], "parent")
        if which == "reference":
            return self.ops.parent(s)
        if which == "jnp":
            out, n = self._jnp("parent", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.parent, s)

    def parent_and_local_index(self, s: Simplex):
        """Fused Algorithm 4.3 + Table 6: (parent, TM child index) in one
        pass — the pair every family scan needs together."""
        which = self._which(s.level.shape[0], "parent_and_local_index")
        if which == "reference":
            return self.ops.parent(s), self.ops.local_index(s)
        if which == "jnp":
            out, n = self._jnp("parent_and_local_index", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.parent_and_local_index, s)

    def children(self, s: Simplex) -> Simplex:
        """All 2^d children in TM order: batch shape (n, 2^d)."""
        which = self._which(s.level.shape[0], "children")
        if which == "reference":
            return self.ops.children_tm(s)
        if which == "jnp":
            out, n = self._jnp("children", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.children, s)

    def face_neighbor(self, s: Simplex, face):
        """Algorithm 4.6: (same-level neighbor, dual face)."""
        which = self._which(s.level.shape[0], "face_neighbor")
        if which == "reference":
            return self.ops.face_neighbor(s, jnp.int32(face))
        if which == "jnp":
            out, n = self._jnp("face_neighbor", s, jnp.int32(face))
            return self._cut(out, n)
        from repro.kernels import ops as kops

        face = jnp.asarray(face, jnp.int32)
        if face.ndim:
            face = _pad1(face, _bucket(s.level.shape[0]))
        return self._pallas(kops.face_neighbor, s, face)

    def _face_sweep_reference(self, s: Simplex) -> FaceSweep:
        """Eager per-face compose of (face_neighbor, is_inside_root,
        morton_key) — the oracle the fused paths must match bit for bit."""
        cols = [[] for _ in range(4)]
        for f in range(self.nf):
            nb, dual = self.ops.face_neighbor(s, jnp.int32(f))
            cols[0].append(nb)
            cols[1].append(dual)
            cols[2].append(self.ops.is_inside_root(nb))
            cols[3].append(self.ops.morton_key(nb))
        nbs, duals, insides, keys = cols
        return FaceSweep(
            Simplex(
                jnp.stack([x.anchor for x in nbs]),
                jnp.stack([x.level for x in nbs]),
                jnp.stack([x.stype for x in nbs]),
            ),
            jnp.stack(duals),
            jnp.stack(insides),
            u64m.U64(jnp.stack([k.hi for k in keys]),
                     jnp.stack([k.lo for k in keys])),
        )

    def face_sweep(self, s: Simplex) -> FaceSweep:
        """Fused all-faces sweep: (face_neighbor, is_inside_root, morton_key)
        for every face 0..nf-1 in ONE backend dispatch — the hot query of the
        Balance/Ghost eval loops (which previously issued 3 x nf separate
        dispatches per layer).  Results carry a leading face axis; slicing
        row f yields exactly what composing the three per-face ops would."""
        n = s.level.shape[0]
        which = self._which(n, "face_sweep")
        if which == "reference":
            return self._face_sweep_reference(s)
        m = _bucket(n)
        cut = functools.partial(jax.tree_util.tree_map, lambda a: a[:, :n])
        if which == "jnp":
            return cut(_jnp_fns(self.d, self.eclass)["face_sweep"](_pad_simplex(s, m)))
        from repro.kernels import ops as kops

        nb, dual, inside, key = kops.face_sweep(
            self.d, _pad_simplex(s, m), min(1024, m), self.eclass)
        return cut(FaceSweep(nb, dual, inside, key))

    def successor(self, s: Simplex) -> Simplex:
        """Batch Algorithm 4.10: next same-level element along the SFC."""
        which = self._which(s.level.shape[0], "successor")
        if which == "reference":
            return self.ops.successor(s)
        if which == "jnp":
            out, n = self._jnp("successor", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.successor, s)

    def is_inside_root(self, s: Simplex):
        """Section 4.4 inside-root test (Proposition 23 vs. the root simplex)."""
        which = self._which(s.level.shape[0], "is_inside_root")
        if which == "reference":
            return self.ops.is_inside_root(s)
        if which == "jnp":
            out, n = self._jnp("is_inside_root", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.is_inside_root, s)

    def local_index(self, s: Simplex):
        """TM child index within the parent (paper Table 6)."""
        which = self._which(s.level.shape[0], "local_index")
        if which == "reference":
            return self.ops.local_index(s)
        if which == "jnp":
            out, n = self._jnp("local_index", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.local_index, s)

    def owner_rank(self, tree, key, marker_tree, marker_key) -> np.ndarray:
        """Owner-rank resolution for the message-based Balance/Ghost: the
        rank whose partition range [marker_r, marker_{r+1}) contains the lex
        (tree, key) — a vectorized searchsorted against the allgathered
        marker table (`forest.partition_markers`), clamped to rank 0 for
        keys before the global first element.  Host-side numpy in/out (the
        forest's routing tables live on the host); the jnp and pallas paths
        run the identical unrolled compare chain over (hi, lo) uint32 words.
        """
        tree = np.asarray(tree, np.int32)
        key = np.asarray(key, np.uint64)
        mt = np.asarray(marker_tree, np.int32)
        mk = np.asarray(marker_key, np.uint64)
        n = len(tree)
        which = self._which(n, "owner_rank")
        if which == "reference":
            le = (mt[None, :] < tree[:, None]) | (
                (mt[None, :] == tree[:, None]) & (mk[None, :] <= key[:, None])
            )
            return np.maximum(le.sum(axis=1).astype(np.int32) - 1, 0)
        mt_j, mkey = _padded_markers_cached(mt, mk)
        m = _bucket(n)
        t_p = _pad1(jnp.asarray(tree), m)
        k = u64m.from_int(key)
        hi, lo = _pad1(k.hi, m), _pad1(k.lo, m)
        if which == "jnp":
            out = _owner_rank_jnp(t_p, hi, lo, mt_j, mkey.hi, mkey.lo)
            return np.asarray(out[:n], np.int32)
        from repro.kernels import ops as kops

        out = kops.owner_rank(
            u64m.U64(hi, lo), t_p, (mt_j, mkey), min(1024, m))
        return np.asarray(out[:n], np.int32)

    # -- fused Balance/Ghost eval stage -------------------------------------
    def sweep_full(self, s: Simplex, tree_ids) -> SweepHandle | None:
        """Face-sweep an element layer and keep the result resident: ONE
        `face_sweep` dispatch whose eight fields never fan out to numpy on
        the device backends — the fused eval programs consume the handle
        directly and only `eval_route`'s compacted rows cross to the host."""
        n = int(s.level.shape[0])
        if n == 0:
            return None
        which = self._which(n, "face_sweep")
        tree_ids = np.asarray(tree_ids, np.int32)
        if which == "reference":
            sw = self._face_sweep_reference(s)
            tgt = np.broadcast_to(tree_ids, (self.nf, n)).copy()
            host = (tgt, u64m.to_np(sw.key), np.asarray(sw.inside),
                    np.asarray(sw.dual), np.asarray(s.level))
            return SweepHandle(n, host, None)
        m = _bucket(n)
        prog = "sweep" if which == "jnp" else "sweep_pallas"
        dev = _eval_progs(self.d, self.eclass)[prog](
            _pad_simplex(s, m), _pad1(jnp.asarray(tree_ids), m), jnp.int32(n))
        return SweepHandle(n, None, dev)

    def sweep_from_host(self, tgt, nkey, valid, dual, level) -> SweepHandle | None:
        """Wrap a host-computed sweep (the cmesh cross-tree path) as a
        resident handle — padding + one upload, no dispatch counted (the
        sweep itself was already dispatched by `face_sweep_layer`)."""
        n = int(np.asarray(level).shape[0])
        if n == 0:
            return None
        tgt = np.asarray(tgt, np.int32)
        nkey = np.asarray(nkey, np.uint64)
        valid = np.asarray(valid, bool)
        dual = np.asarray(dual, np.int32)
        level = np.asarray(level, np.int32)
        host = (tgt, nkey, valid, dual, level)
        if self.backend == "reference":
            return SweepHandle(n, host, None)
        m = _bucket(n)
        pad2 = ((0, 0), (0, m - n))
        dev = (
            jnp.asarray(np.pad(tgt, pad2)),
            jnp.asarray(np.pad((nkey >> np.uint64(32)).astype(np.uint32), pad2)),
            jnp.asarray(np.pad(nkey.astype(np.uint32), pad2)),
            jnp.asarray(np.pad(valid, pad2)),
            jnp.asarray(np.pad(dual, pad2)),
            jnp.asarray(np.pad(level, (0, m - n))),
        )
        return SweepHandle(n, host, dev)

    def upload_table(self, tree, keys, level) -> LeafTable | None:
        """Upload a lex-sorted (tree, key, level) leaf table for the fused
        eval programs (None for an empty table — callers skip the eval)."""
        tree = np.asarray(tree, np.int32)
        keys = np.asarray(keys, np.uint64)
        level = np.asarray(level, np.int32)
        n = len(level)
        if n == 0:
            return None
        host = (tree, keys, level)
        if self.backend == "reference":
            return LeafTable(n, host, None)
        m = _bucket(n)
        lt = np.full(m, np.iinfo(np.int32).max, np.int32)
        lhi = np.zeros(m, np.uint32)
        llo = np.zeros(m, np.uint32)
        llev = np.full(m, -1, np.int32)
        lt[:n] = tree
        lhi[:n] = (keys >> np.uint64(32)).astype(np.uint32)
        llo[:n] = keys.astype(np.uint32)
        llev[:n] = level
        dev = (jnp.asarray(lt), jnp.asarray(lhi),
               jnp.asarray(llo), jnp.asarray(llev))
        return LeafTable(n, host, dev)

    @staticmethod
    def _boundary_scalars(mt, mk, g: int, P: int):
        """The two partition markers bounding rank g, as traced device
        scalars (so changing ranks or markers never retraces the eval
        programs).  Content-cached: a Balance round calls this for every
        rank against the SAME marker table, and eight scalar device_puts
        per call were pure overhead."""
        ckey = (mt.tobytes(), mk.tobytes(), g, P)
        hit = _boundary_scalar_cache.get(ckey)
        if hit is not None:
            _boundary_scalar_cache.move_to_end(ckey)
            return hit

        def words(t, k):
            k = int(k)
            return (jnp.int32(int(t)), jnp.uint32(k >> 32),
                    jnp.uint32(k & 0xFFFFFFFF))

        lo = words(mt[g], mk[g]) if g > 0 else words(0, 0)
        hi = words(mt[g + 1], mk[g + 1]) if g + 1 < P else words(0, 0)
        val = (*lo, jnp.bool_(g > 0), *hi, jnp.bool_(g + 1 < P))
        _boundary_scalar_cache[ckey] = val
        while len(_boundary_scalar_cache) > 4 * _MARKER_CACHE_SIZE:
            _boundary_scalar_cache.popitem(last=False)
        return val

    def _bmask_ref(self, sw: SweepHandle, mt, mk, g: int, P: int) -> np.ndarray:
        """Host oracle of the boundary-adjacent mask: some valid face
        interval escapes [marker_g, marker_{g+1})."""
        tgt, nkey, valid, _dual, lev = sw.host
        bmask = np.zeros(sw.n, bool)
        fi, ei = np.nonzero(valid)
        if len(ei) == 0:
            return bmask
        span = _spans_np(self.d, self.ops.L, lev)
        t_v = tgt[fi, ei]
        k_lo = nkey[fi, ei]
        k_hi = k_lo + span[ei] - np.uint64(1)
        off = np.zeros(len(ei), bool)
        if g > 0:
            off |= (t_v < mt[g]) | ((t_v == mt[g]) & (k_lo < mk[g]))
        if g + 1 < P:
            off |= (t_v > mt[g + 1]) | ((t_v == mt[g + 1]) & (k_hi >= mk[g + 1]))
        bmask[ei[off]] = True
        return bmask

    def _need_ref(self, sw: SweepHandle, table: LeafTable,
                  pairs_mask: np.ndarray) -> np.ndarray:
        """Host oracle of the 2:1 need-mask: for each (face, element) pair
        in `pairs_mask`, is some leaf of `table` in the neighbor interval
        more than one level finer than the element?"""
        tgt, nkey, _valid, _dual, lev = sw.host
        need = np.zeros(sw.n, bool)
        tt, kk, ll = table.host
        span = _spans_np(self.d, self.ops.L, lev)
        for t in np.unique(tgt[pairs_mask]):
            fi, ei = np.nonzero(pairs_mask & (tgt == t))
            a, b = np.searchsorted(tt, [t, t + 1])
            keys_t = kk[a:b]
            lo = np.searchsorted(keys_t, nkey[fi, ei])
            hi = np.searchsorted(keys_t, nkey[fi, ei] + span[ei])
            upd = _range_max_np(ll[a:b], lo, hi) > lev[ei] + 1
            need[ei[upd]] = True
        return need

    def eval_2to1(self, sw: SweepHandle | None, table: LeafTable | None,
                  mt, mk, g: int):
        """Fused interior 2:1 eval: (need, boundary) element masks from one
        resident sweep vs the local leaf table — one device program, one
        host materialization."""
        if sw is None or sw.n == 0:
            z = np.zeros(0, bool)
            return z, z.copy()
        mt = np.asarray(mt, np.int32)
        mk = np.asarray(mk, np.uint64)
        P = len(mt)
        which = self._which(sw.n, "eval_2to1")
        if which == "reference" or table is None:
            bmask = self._bmask_ref(sw, mt, mk, g, P)
            if table is None:
                return np.zeros(sw.n, bool), bmask
            need = self._need_ref(sw, table, sw.host[2])
            return need, bmask
        tgtD, khiD, kloD, validD, _dualD, levD = sw.dev
        need_d, bm_d = _eval_progs(self.d, self.eclass)["need"](
            tgtD, khiD, kloD, validD, levD, *table.dev,
            *self._boundary_scalars(mt, mk, g, P))
        _bump_fetch("eval_2to1")
        # owned copies: callers fold masks in place (jax views are read-only)
        return (np.array(need_d[:sw.n]), np.array(bm_d[:sw.n]))

    def eval_cache(self, sw: SweepHandle | None, cache: LeafTable | None,
                   mt, mk, g: int) -> np.ndarray:
        """Fused remote-cache 2:1 eval: need-mask of boundary-adjacent
        elements vs the remote-leaf cache (the off-rank witnesses folded in
        by earlier rounds)."""
        if sw is None or sw.n == 0 or cache is None:
            return np.zeros(0 if sw is None else sw.n, bool)
        mt = np.asarray(mt, np.int32)
        mk = np.asarray(mk, np.uint64)
        P = len(mt)
        which = self._which(sw.n, "eval_cache")
        if which == "reference":
            bmask = self._bmask_ref(sw, mt, mk, g, P)
            if not bmask.any():
                return np.zeros(sw.n, bool)
            return self._need_ref(sw, cache, sw.host[2] & bmask[None, :])
        tgtD, khiD, kloD, validD, _dualD, levD = sw.dev
        need_d = _eval_progs(self.d, self.eclass)["cache"](
            tgtD, khiD, kloD, validD, levD, *cache.dev,
            *self._boundary_scalars(mt, mk, g, P))
        _bump_fetch("eval_cache")
        return np.array(need_d[:sw.n])

    def eval_route(self, sw: SweepHandle | None, mt, mk, g: int) -> RoutePairs:
        """Fused boundary routing: compact the (face, element) pairs whose
        neighbor interval reaches outside rank g's partition, with the
        [first, last] owner-rank range per pair.  The host receives ONE
        (count, rows) materialization and builds wire triples from it."""
        if sw is None or sw.n == 0:
            return _empty_route()
        mt = np.asarray(mt, np.int32)
        mk = np.asarray(mk, np.uint64)
        which = self._which(sw.n, "eval_route")
        if which == "reference":
            tgt, nkey, valid, dual, lev = sw.host
            fi, ei = np.nonzero(valid)
            if len(ei) == 0:
                return _empty_route()
            span = _spans_np(self.d, self.ops.L, lev)
            t_v = tgt[fi, ei]
            k_v = nkey[fi, ei]
            first = _owner_np(t_v, k_v, mt, mk)
            last = _owner_np(t_v, k_v + span[ei] - np.uint64(1), mt, mk)
            sel = (first != g) | (last != g)
            return RoutePairs(
                t_v[sel].astype(np.int32), k_v[sel],
                lev[ei[sel]].astype(np.int32), dual[fi, ei][sel].astype(np.int32),
                first[sel], last[sel])
        mt_j, mkey = _padded_markers_cached(mt, mk)
        prog = "route" if which == "jnp" else "route_pallas"
        cnt, packed = _eval_progs(self.d, self.eclass)[prog](
            *sw.dev, mt_j, mkey.hi, mkey.lo, _rank_scalar(g))
        _bump_fetch("eval_route")
        c = int(cnt)
        if c == 0:
            return _empty_route()
        arr = np.asarray(packed[:c])
        khi = np.asarray(arr[:, 1], np.int64) & np.int64(0xFFFFFFFF)
        klo = np.asarray(arr[:, 2], np.int64) & np.int64(0xFFFFFFFF)
        key = (khi.astype(np.uint64) << np.uint64(32)) | klo.astype(np.uint64)
        return RoutePairs(arr[:, 0].copy(), key, arr[:, 3].copy(),
                          arr[:, 4].copy(), arr[:, 5].copy(), arr[:, 6].copy())

    def tree_transform(self, s: Simplex, M, c, typemap) -> Simplex:
        """Cross-tree coordinate change (the `repro.core.cmesh` gluing map):
        anchor' = M @ anchor + c with the reflected-axis anchor correction,
        type through the per-connection `typemap`.  The translation is
        carried modulo 2^32 (see `cmesh.wrap_i32`) so all backends wrap
        identically."""
        from .cmesh import wrap_i32

        M = np.asarray(M, np.int64)
        c32 = wrap_i32(c)
        tm = np.asarray(typemap, np.int64)
        which = self._which(s.level.shape[0], "tree_transform")
        if which == "reference":
            return self.ops.tree_transform(s, M, c32, tm)
        if which == "jnp":
            out, n = self._jnp(
                "tree_transform", s,
                jnp.asarray(M, jnp.int32), jnp.asarray(c32), jnp.asarray(tm, jnp.int32),
            )
            return self._cut(out, n)
        from repro.kernels import ops as kops

        key = (
            tuple(tuple(int(v) for v in row) for row in M.tolist()),
            tuple(int(v) for v in c32.tolist()),
            tuple(int(v) for v in tm.tolist()),
        )
        return self._pallas(kops.tree_transform, s, *key)


@functools.lru_cache(maxsize=None)
def _cached(d: int, backend: str, eclass: int) -> BatchedOps:
    return BatchedOps(d, backend, eclass)


def get_batch_ops(d: int, backend: str | None = None,
                  eclass: int = ECLASS_SIMPLEX) -> BatchedOps:
    """The batched element-ops dispatcher for dimension `d` and element
    class `eclass`.

    With no explicit `backend`, follows the global knob at every call — so
    `use_backend(...)` contexts affect forests that were built earlier.
    """
    return _cached(d, backend if backend is not None else get_backend(), eclass)
