"""Batched element-ops dispatch layer for the forest hot loops.

The paper's New/Adapt/Balance/Ghost pipelines spend essentially all their
time in constant-time element queries (parent, children, face-neighbor,
successor, encode/decode — Sections 4.5-4.6).  This module is the single
seam through which the forest layer reaches that math, with three
interchangeable backends over `Simplex` batches:

  reference   the existing `SimplexOps` methods, dispatched eagerly op by op
              (the seed's behaviour; every intermediate materialises).
  jnp         the same algorithms under `jax.jit` with power-of-two padding
              buckets, so each op is one fused XLA program and the number of
              distinct compiled shapes stays O(log n).
  pallas      the tiled Pallas kernels from `repro.kernels` (interpret mode
              on CPU, compiled tiles on TPU).

All three produce bit-identical integer results; the backend knob trades
dispatch overhead against compile time.  Select globally via the
``REPRO_BACKEND`` env var, `set_backend()`, or the `use_backend()` context
manager.  Unknown names fall back to `reference`; a `pallas` backend that
fails its self-test (e.g. no Pallas lowering on this host) falls back to
`jnp` — both with a warning, never an error.

Future scaling PRs (sharding, multi-device partition) plug in here: a new
backend only has to implement the `BatchedOps` method surface (the eight
per-element algorithms, the cross-tree `tree_transform`, and the
marker-table `owner_rank` searchsorted that routes the message-based
Balance/Ghost).
"""

from __future__ import annotations

import contextlib
import functools
import os
import warnings
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import u64 as u64m
from .ops import SimplexOps, get_ops
from .types import Simplex

__all__ = [
    "BACKENDS",
    "BatchedOps",
    "FaceSweep",
    "get_backend",
    "set_backend",
    "use_backend",
    "get_batch_ops",
    "dispatch_counts",
    "reset_dispatch_counts",
]

BACKENDS = ("reference", "jnp", "pallas")
_ENV_VAR = "REPRO_BACKEND"
_active: str | None = None  # resolved lazily so the env var can be set late


def _resolve(name: str, source: str) -> str:
    name = name.strip().lower()
    if name not in BACKENDS:
        warnings.warn(
            f"unknown element-ops backend {name!r} from {source}; "
            f"falling back to 'reference' (choices: {BACKENDS})",
            stacklevel=3,
        )
        return "reference"
    return name


def get_backend() -> str:
    """The active backend name (env var ``REPRO_BACKEND``, default reference)."""
    global _active
    if _active is None:
        _active = _resolve(os.environ.get(_ENV_VAR, "reference"), f"${_ENV_VAR}")
    return _active


def set_backend(name: str) -> None:
    global _active
    _active = _resolve(name, "set_backend()")


@contextlib.contextmanager
def use_backend(name: str):
    """Temporarily switch the element-ops backend (tests / benchmarks)."""
    global _active
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        _active = prev


# ---------------------------------------------------------- dispatch counters
# One increment per BatchedOps op invocation (any backend) — the observable
# the fused face sweep optimizes: Balance/Ghost evaluation must issue ONE
# `face_sweep` dispatch per eval layer instead of 3 x (d+1) per-face ops.
# Benchmarks and tests read/reset these around a measured region.
_dispatch_counts: dict[str, int] = {}


def reset_dispatch_counts() -> None:
    """Zero the per-op dispatch counters."""
    _dispatch_counts.clear()


def dispatch_counts() -> dict[str, int]:
    """Snapshot of {op name: number of BatchedOps dispatches} since reset."""
    return dict(_dispatch_counts)


class FaceSweep(NamedTuple):
    """Result of the fused all-faces sweep, leading axis = face (d+1 rows).

    neighbor  same-level neighbor per face: anchor (d+1, n, d), level/stype
              (d+1, n) — possibly outside the root (check `inside`)
    dual      (d+1, n) int32 neighbor's face index back to us
    inside    (d+1, n) bool inside-root mask
    key       (d+1, n) U64 neighbor morton keys (garbage where ~inside on a
              domain boundary — never read them there)
    """

    neighbor: Simplex
    dual: jax.Array
    inside: jax.Array
    key: u64m.U64


# ---------------------------------------------------------------- jnp backend
def _bucket(n: int) -> int:
    """Next power-of-two batch size (>= 16): bounds jit recompiles to O(log n)."""
    return max(16, 1 << max(0, n - 1).bit_length())


def _pad1(a, m):
    return jnp.pad(a, [(0, m - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def _pad_simplex(s: Simplex, m: int) -> Simplex:
    return Simplex(_pad1(s.anchor, m), _pad1(s.level, m), _pad1(s.stype, m))


def _face_sweep_fused(o: SimplexOps):
    """One jitted program for the whole face sweep: vmap over the d+1 face
    indices of (face_neighbor, is_inside_root, morton_key) — a single XLA
    dispatch instead of 3 x (d+1)."""

    def fn(s: Simplex) -> FaceSweep:
        def one(f):
            nb, dual = o.face_neighbor(s, f)
            return FaceSweep(nb, dual, o.is_inside_root(nb), o.morton_key(nb))

        return jax.vmap(one)(jnp.arange(o.d + 1, dtype=jnp.int32))

    return fn


@functools.lru_cache(maxsize=None)
def _jnp_fns(d: int):
    o = get_ops(d)
    return {
        "morton_key": jax.jit(o.morton_key),
        "decode": jax.jit(o.decode_key),
        "parent": jax.jit(o.parent),
        "parent_and_local_index": jax.jit(lambda s: (o.parent(s), o.local_index(s))),
        "children": jax.jit(o.children_tm),
        "face_neighbor": jax.jit(o.face_neighbor),
        "face_sweep": jax.jit(_face_sweep_fused(o)),
        "successor": jax.jit(o.successor),
        "is_inside_root": jax.jit(o.is_inside_root),
        "local_index": jax.jit(o.local_index),
        "tree_transform": jax.jit(o.tree_transform),
    }


def _pad_markers(marker_tree: np.ndarray, marker_key: np.ndarray):
    """Pad the per-rank marker table to a power of two (>= 8) with lex-+inf
    sentinels (tree = int32 max) so compiled shapes stay O(log P) and padding
    never counts in the searchsorted."""
    P = len(marker_tree)
    m = max(8, 1 << max(0, P - 1).bit_length())
    mt = np.full(m, np.iinfo(np.int32).max, np.int32)
    mk = np.zeros(m, np.uint64)
    mt[:P] = marker_tree
    mk[:P] = marker_key
    return mt, mk


# Memoized pad + device transfer of the marker table, keyed on CONTENT (the
# marker bytes): every Balance round calls `owner_rank` many times with the
# same P-entry table, and re-padding/re-uploading it per call was pure
# overhead.  The previous identity key (`id(mt), id(mk)`) silently served
# stale device markers to a caller that mutated a table in place (identity
# unchanged, content changed) — the content key closes that hole and also
# dedupes equal-content tables that arrive as fresh arrays.  Hashing P
# entries per call is O(P) host work, noise next to one dispatch.
_marker_pad_cache: OrderedDict = OrderedDict()
_MARKER_CACHE_SIZE = 16


def _padded_markers_cached(mt: np.ndarray, mk: np.ndarray):
    """(device marker_tree, device marker_key U64), padded with sentinels."""
    key = (mt.tobytes(), mk.tobytes())
    hit = _marker_pad_cache.get(key)
    if hit is not None:
        _marker_pad_cache.move_to_end(key)
        return hit
    mt_p, mk_p = _pad_markers(mt, mk)
    val = (jnp.asarray(mt_p), u64m.from_int(mk_p))
    _marker_pad_cache[key] = val
    while len(_marker_pad_cache) > _MARKER_CACHE_SIZE:
        _marker_pad_cache.popitem(last=False)
    return val


def owner_rank_lex(t, hi, lo, mt, mhi, mlo):
    """The one shared lex searchsorted: index of the last marker (mt, mhi,
    mlo) lex-<= (t, hi, lo), clamped to 0.  The jnp backend jits exactly
    this; `repro.kernels.ref.owner_rank_ref` delegates here so the Pallas
    kernel's oracle can never drift from the backend implementations."""
    le = (mt[None, :] < t[:, None]) | (
        (mt[None, :] == t[:, None])
        & ((mhi[None, :] < hi[:, None])
           | ((mhi[None, :] == hi[:, None]) & (mlo[None, :] <= lo[:, None])))
    )
    return jnp.maximum(le.astype(jnp.int32).sum(axis=1) - 1, 0)


_owner_rank_jnp = jax.jit(owner_rank_lex)


# ------------------------------------------------------------- pallas backend
@functools.lru_cache(maxsize=None)
def _pallas_ok(d: int) -> bool:
    """One-element self-test; on failure the pallas backend degrades to jnp."""
    try:
        from repro.kernels import ops as kops

        s = Simplex(
            jnp.zeros((1, d), jnp.int32), jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32)
        )
        kops.morton_key(d, s, 16)
        kops.face_sweep(d, s, 16)
        return True
    except Exception as e:  # noqa: BLE001 - any lowering failure means fallback
        warnings.warn(f"pallas backend unavailable for d={d} ({e!r}); using jnp")
        return False


# -------------------------------------------------------------------- dispatch
class BatchedOps:
    """Backend-bound batched element ops over `Simplex` arrays of shape (n,).

    The methods mirror the paper's constant-time element algorithms (plus
    the cross-tree coordinate change of `repro.core.cmesh`); every forest
    hot loop (adapt's child generation and family-head scan, balance's and
    ghost's neighbor sweeps — across tree faces included) consumes exactly
    this surface.
    """

    def __init__(self, d: int, backend: str):
        backend = _resolve(backend, "get_batch_ops()")
        if backend == "pallas" and not _pallas_ok(d):
            backend = "jnp"
        self.d = d
        self.backend = backend
        self.ops: SimplexOps = get_ops(d)

    # -- helpers -----------------------------------------------------------
    def _which(self, n: int, name: str | None = None) -> str:
        # Empty batches short-circuit to the eager path (a Pallas grid of 0
        # tiles is invalid, and there is nothing to fuse anyway).
        if name is not None:
            _dispatch_counts[name] = _dispatch_counts.get(name, 0) + 1
        return "reference" if n == 0 else self.backend

    def _jnp(self, name, s: Simplex, *extra):
        n = s.level.shape[0]
        m = _bucket(n)
        out = _jnp_fns(self.d)[name](_pad_simplex(s, m), *extra)
        return out, n

    @staticmethod
    def _cut(x, n):
        return jax.tree_util.tree_map(lambda a: a[:n], x)

    def _pallas(self, fn, s: Simplex, *extra):
        """Bucket-pad before the jit'd kernel wrapper (same O(log n) compiled
        shapes as the jnp path), then slice the outputs back."""
        n = s.level.shape[0]
        m = _bucket(n)
        return self._cut(fn(self.d, _pad_simplex(s, m), *extra, min(1024, m)), n)

    # -- API ---------------------------------------------------------------
    def morton_key(self, s: Simplex) -> u64m.U64:
        """Level-padded consecutive index (the mixed-level SFC sort key)."""
        which = self._which(s.level.shape[0], "morton_key")
        if which == "reference":
            return self.ops.morton_key(s)
        if which == "jnp":
            out, n = self._jnp("morton_key", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        hi, lo = self._pallas(kops.morton_key, s)
        return u64m.U64(hi, lo)

    def morton_key_np(self, s: Simplex) -> np.ndarray:
        """Host-side uint64 keys (the forest's storage format)."""
        return u64m.to_np(self.morton_key(s))

    def decode(self, key: u64m.U64, level) -> Simplex:
        """Algorithm 4.8 from a level-padded key (inverse of `morton_key`)."""
        level = jnp.asarray(level, jnp.int32)
        which = self._which(key.hi.shape[0], "decode")
        if which == "reference":
            return self.ops.decode_key(key, level)
        if which == "jnp":
            n = key.hi.shape[0]
            m = _bucket(n)
            padded = u64m.U64(_pad1(key.hi, m), _pad1(key.lo, m))
            return self._cut(_jnp_fns(self.d)["decode"](padded, _pad1(level, m)), n)
        from repro.kernels import ops as kops

        n = key.hi.shape[0]
        m = _bucket(n)
        padded = u64m.U64(_pad1(key.hi, m), _pad1(key.lo, m))
        return self._cut(
            kops.decode(self.d, padded, _pad1(level, m), min(1024, m)), n
        )

    def parent(self, s: Simplex) -> Simplex:
        """Algorithm 4.3."""
        which = self._which(s.level.shape[0], "parent")
        if which == "reference":
            return self.ops.parent(s)
        if which == "jnp":
            out, n = self._jnp("parent", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.parent, s)

    def parent_and_local_index(self, s: Simplex):
        """Fused Algorithm 4.3 + Table 6: (parent, TM child index) in one
        pass — the pair every family scan needs together."""
        which = self._which(s.level.shape[0], "parent_and_local_index")
        if which == "reference":
            return self.ops.parent(s), self.ops.local_index(s)
        if which == "jnp":
            out, n = self._jnp("parent_and_local_index", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.parent_and_local_index, s)

    def children(self, s: Simplex) -> Simplex:
        """All 2^d children in TM order: batch shape (n, 2^d)."""
        which = self._which(s.level.shape[0], "children")
        if which == "reference":
            return self.ops.children_tm(s)
        if which == "jnp":
            out, n = self._jnp("children", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.children, s)

    def face_neighbor(self, s: Simplex, face):
        """Algorithm 4.6: (same-level neighbor, dual face)."""
        which = self._which(s.level.shape[0], "face_neighbor")
        if which == "reference":
            return self.ops.face_neighbor(s, jnp.int32(face))
        if which == "jnp":
            out, n = self._jnp("face_neighbor", s, jnp.int32(face))
            return self._cut(out, n)
        from repro.kernels import ops as kops

        face = jnp.asarray(face, jnp.int32)
        if face.ndim:
            face = _pad1(face, _bucket(s.level.shape[0]))
        return self._pallas(kops.face_neighbor, s, face)

    def face_sweep(self, s: Simplex) -> FaceSweep:
        """Fused all-faces sweep: (face_neighbor, is_inside_root, morton_key)
        for every face 0..d in ONE backend dispatch — the hot query of the
        Balance/Ghost eval loops (which previously issued 3 x (d+1) separate
        dispatches per layer).  Results carry a leading face axis; slicing
        row f yields exactly what composing the three per-face ops would."""
        n = s.level.shape[0]
        which = self._which(n, "face_sweep")
        if which == "reference":
            cols = [[] for _ in range(4)]
            for f in range(self.d + 1):
                nb, dual = self.ops.face_neighbor(s, jnp.int32(f))
                cols[0].append(nb)
                cols[1].append(dual)
                cols[2].append(self.ops.is_inside_root(nb))
                cols[3].append(self.ops.morton_key(nb))
            nbs, duals, insides, keys = cols
            return FaceSweep(
                Simplex(
                    jnp.stack([x.anchor for x in nbs]),
                    jnp.stack([x.level for x in nbs]),
                    jnp.stack([x.stype for x in nbs]),
                ),
                jnp.stack(duals),
                jnp.stack(insides),
                u64m.U64(jnp.stack([k.hi for k in keys]),
                         jnp.stack([k.lo for k in keys])),
            )
        m = _bucket(n)
        cut = functools.partial(jax.tree_util.tree_map, lambda a: a[:, :n])
        if which == "jnp":
            return cut(_jnp_fns(self.d)["face_sweep"](_pad_simplex(s, m)))
        from repro.kernels import ops as kops

        nb, dual, inside, key = kops.face_sweep(
            self.d, _pad_simplex(s, m), min(1024, m))
        return cut(FaceSweep(nb, dual, inside, key))

    def successor(self, s: Simplex) -> Simplex:
        """Batch Algorithm 4.10: next same-level element along the SFC."""
        which = self._which(s.level.shape[0], "successor")
        if which == "reference":
            return self.ops.successor(s)
        if which == "jnp":
            out, n = self._jnp("successor", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.successor, s)

    def is_inside_root(self, s: Simplex):
        """Section 4.4 inside-root test (Proposition 23 vs. the root simplex)."""
        which = self._which(s.level.shape[0], "is_inside_root")
        if which == "reference":
            return self.ops.is_inside_root(s)
        if which == "jnp":
            out, n = self._jnp("is_inside_root", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.is_inside_root, s)

    def local_index(self, s: Simplex):
        """TM child index within the parent (paper Table 6)."""
        which = self._which(s.level.shape[0], "local_index")
        if which == "reference":
            return self.ops.local_index(s)
        if which == "jnp":
            out, n = self._jnp("local_index", s)
            return self._cut(out, n)
        from repro.kernels import ops as kops

        return self._pallas(kops.local_index, s)

    def owner_rank(self, tree, key, marker_tree, marker_key) -> np.ndarray:
        """Owner-rank resolution for the message-based Balance/Ghost: the
        rank whose partition range [marker_r, marker_{r+1}) contains the lex
        (tree, key) — a vectorized searchsorted against the allgathered
        marker table (`forest.partition_markers`), clamped to rank 0 for
        keys before the global first element.  Host-side numpy in/out (the
        forest's routing tables live on the host); the jnp and pallas paths
        run the identical unrolled compare chain over (hi, lo) uint32 words.
        """
        tree = np.asarray(tree, np.int32)
        key = np.asarray(key, np.uint64)
        mt = np.asarray(marker_tree, np.int32)
        mk = np.asarray(marker_key, np.uint64)
        n = len(tree)
        which = self._which(n, "owner_rank")
        if which == "reference":
            le = (mt[None, :] < tree[:, None]) | (
                (mt[None, :] == tree[:, None]) & (mk[None, :] <= key[:, None])
            )
            return np.maximum(le.sum(axis=1).astype(np.int32) - 1, 0)
        mt_j, mkey = _padded_markers_cached(mt, mk)
        m = _bucket(n)
        t_p = _pad1(jnp.asarray(tree), m)
        k = u64m.from_int(key)
        hi, lo = _pad1(k.hi, m), _pad1(k.lo, m)
        if which == "jnp":
            out = _owner_rank_jnp(t_p, hi, lo, mt_j, mkey.hi, mkey.lo)
            return np.asarray(out[:n], np.int32)
        from repro.kernels import ops as kops

        out = kops.owner_rank(
            u64m.U64(hi, lo), t_p, (mt_j, mkey), min(1024, m))
        return np.asarray(out[:n], np.int32)

    def tree_transform(self, s: Simplex, M, c, typemap) -> Simplex:
        """Cross-tree coordinate change (the `repro.core.cmesh` gluing map):
        anchor' = M @ anchor + c with the reflected-axis anchor correction,
        type through the per-connection `typemap`.  The translation is
        carried modulo 2^32 (see `cmesh.wrap_i32`) so all backends wrap
        identically."""
        from .cmesh import wrap_i32

        M = np.asarray(M, np.int64)
        c32 = wrap_i32(c)
        tm = np.asarray(typemap, np.int64)
        which = self._which(s.level.shape[0], "tree_transform")
        if which == "reference":
            return self.ops.tree_transform(s, M, c32, tm)
        if which == "jnp":
            out, n = self._jnp(
                "tree_transform", s,
                jnp.asarray(M, jnp.int32), jnp.asarray(c32), jnp.asarray(tm, jnp.int32),
            )
            return self._cut(out, n)
        from repro.kernels import ops as kops

        key = (
            tuple(tuple(int(v) for v in row) for row in M.tolist()),
            tuple(int(v) for v in c32.tolist()),
            tuple(int(v) for v in tm.tolist()),
        )
        return self._pallas(kops.tree_transform, s, *key)


@functools.lru_cache(maxsize=None)
def _cached(d: int, backend: str) -> BatchedOps:
    return BatchedOps(d, backend)


def get_batch_ops(d: int, backend: str | None = None) -> BatchedOps:
    """The batched element-ops dispatcher for dimension `d`.

    With no explicit `backend`, follows the global knob at every call — so
    `use_backend(...)` contexts affect forests that were built earlier.
    """
    return _cached(d, backend if backend is not None else get_backend())
