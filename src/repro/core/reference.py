"""Pure-Python reference oracles for the tetrahedral SFC.

Slow, per-element, arbitrary-precision implementations used ONLY in tests and
as the ground truth for the vectorized / Pallas implementations.  Everything
here is computed from the geometric first principles in `tables.py`
(Bey refinement + Kuhn-type matching), independent of the fused fast paths.
"""

from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

from .tables import MAXLEVEL, get_tables, _bey_children_vertices, _ref_simplex_vertices, _type_of

# A reference simplex is a tuple (anchor: tuple[int], level: int, type: int).


def ref_vertices(d, tet):
    anchor, level, b = tet
    h = 1 << (MAXLEVEL[d] - level)
    return _ref_simplex_vertices(d, b) * h + np.asarray(anchor, np.int64)


def ref_children_bey(d, tet):
    """Children in Bey order, as (anchor, level, type) tuples."""
    anchor, level, b = tet
    h2 = 1 << (MAXLEVEL[d] - level - 1)
    verts = ref_vertices(d, tet)
    out = []
    for cv in _bey_children_vertices(d, verts):
        a = cv.min(axis=0)
        ct = _type_of(d, cv, h2, a)
        out.append((tuple(int(v) for v in a), level + 1, ct))
    return out


def ref_parent(d, tet):
    """Parent by search: the unique level-1-coarser simplex with tet among its
    children."""
    anchor, level, b = tet
    assert level > 0
    h = 1 << (MAXLEVEL[d] - level)
    pa = tuple(int(a) & ~h for a in anchor)
    t = get_tables(d)
    for pb in range(t.num_types):
        cand = (pa, level - 1, pb)
        if tet in ref_children_bey(d, cand):
            return cand
    raise AssertionError(f"no parent found for {tet}")


def ref_ancestor_chain(d, tet):
    """[(anchor, level, type)] from the element itself up to the root."""
    chain = [tet]
    while chain[-1][1] > 0:
        chain.append(ref_parent(d, chain[-1]))
    return chain[::-1]


def ref_tm_index(d, tet) -> int:
    """TM-index (Definition 13) as an exact Python int with (d+1) bits per
    level (the 2^d-ary digit pairs of eq. (15))."""
    chain = ref_ancestor_chain(d, tet)
    L = MAXLEVEL[d]
    m = 0
    digit_bits = d + 3 if d == 3 else d + 2  # (zyx) + 3 type bits (3D) / (yx)+2 (2D)
    # Use (15): per level i (1-based), digits (cube-id, type), base 2^d each
    # for the spatial part; the type occupies its own base-2^d digit.
    for i in range(1, L + 1):
        if i < len(chain):
            anchor = np.asarray(chain[i][0])
            cid = 0
            for k in range(d):
                cid |= ((int(anchor[k]) >> (L - i)) & 1) << k
            b = chain[i][2]
        else:
            cid, b = 0, 0
        m = (m << d) | cid
        m = (m << d) | b  # type digit in base 2^d (valid since d! < 2^d)
    return m


def ref_linear_id(d, tet) -> int:
    """Consecutive index via eq. (55), using local indices along the chain."""
    t = get_tables(d)
    chain = ref_ancestor_chain(d, tet)
    L = MAXLEVEL[d]
    I = 0
    for i in range(1, len(chain)):
        anchor = np.asarray(chain[i][0])
        cid = 0
        for k in range(d):
            cid |= ((int(anchor[k]) >> (L - i)) & 1) << k
        iloc = int(t.local_index[cid, chain[i][2]])
        I = (I << d) | iloc
    return I


@lru_cache(maxsize=None)
def ref_uniform_level(d, level):
    """All descendants of the root at `level`, sorted by TM-index.

    Exponential — keep level <= 3 (3D) / 5 (2D)."""
    tets = [((0,) * d, 0, 0)]
    for _ in range(level):
        tets = [c for t in tets for c in ref_children_bey(d, t)]
    return sorted(tets, key=lambda tt: ref_tm_index(d, tt))


def ref_is_descendant(d, tet, anc) -> bool:
    """Exact (slow) descendant test by walking tet up to anc's level."""
    cur = tet
    if cur[1] < anc[1]:
        return False
    while cur[1] > anc[1]:
        cur = ref_parent(d, cur)
    return cur == anc


def ref_face_neighbor(d, tet, f):
    """Same-level face neighbor by brute-force vertex matching (may lie
    outside the root).  Returns (neighbor, dual_face)."""
    t = get_tables(d)
    anchor, level, b = tet
    h = 1 << (MAXLEVEL[d] - level)
    nb = int(t.neighbor_type[b, f])
    na = tuple(int(a) + h * int(o) for a, o in zip(anchor, t.neighbor_offset[b, f]))
    return (na, level, nb), int(t.neighbor_face[b, f])


def ref_successor(d, tet):
    """Algorithm 4.10 (recursion form), exact."""
    t = get_tables(d)
    L = MAXLEVEL[d]

    def rec(cur, lvl):
        anchor, level, b = cur
        cid = 0
        for k in range(d):
            cid |= ((int(anchor[k]) >> (L - lvl)) & 1) << k
        iloc = int(t.local_index[cid, b])
        nxt = (iloc + 1) % (2 ** d)
        parent = ref_parent(d, cur)
        parent2 = rec(parent, lvl - 1) if nxt == 0 else parent
        # child `nxt` (TM order) of parent2
        pb = parent2[2]
        cid2 = int(t.cube_id_of_local[pb, nxt])
        tb2 = int(t.type_of_local[pb, nxt])
        h2 = 1 << (L - lvl)
        na = tuple(
            int(parent2[0][k]) + h2 * ((cid2 >> k) & 1) for k in range(d)
        )
        return (na, lvl, tb2)

    return rec(tet, tet[1])
