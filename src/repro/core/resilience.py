"""Fault injection and recovery for the distributed forest runtime.

Three pieces, one module:

`ChaosComm` wraps any `Comm` (SimComm, LatencyComm, DistComm) and conforms
to the full surface — blocking + nonblocking collectives, phase meters
(shared with the inner comm, so byte attribution is unchanged), wire
digest, barrier — while injecting *seeded, per-phase* faults at the exact
layer a real wire would corrupt them: the framed byte stream.  Fault
kinds: payload corruption (bit flips), truncation, duplication, delivery
delay (reordering completion against compute), rank stall (a handle that
never matures — surfaces through the deadline machinery as
`CommTimeoutError`), and crash-at-collective (an `InjectedCrash` raise
in-process, a hard `os._exit` in subprocess runs so the process dies like
a real rank).  Every byte fault goes through `frame_blob` -> mutate ->
`unframe_blob`/`decode_payload`, so detection is the SAME code path
production traffic uses; detected faults are retried (transient-fault
emulation) up to `max_retries` and counted in `fault_counts`, so a chaos
run either delivers bit-identical results or raises a typed error — never
a silently wrong forest.

`Autosaver` is a `forest.RESILIENCE_HOOKS` hook that checkpoints the
forest via `save_forest` every N `balance()`/`repartition()` entries, so
a crash mid-collective always has a consistent pre-phase checkpoint
behind it.

`recover(path, comm)` restores the forest elastically onto whatever comm
the survivors rebuilt — typically at reduced P after a rank death — with
checkpoint integrity verified and `validate()` run on the restored world.

Reproducing a failure is one seed: `ChaosConfig(seed=...)` derives its
stream from `(seed, rank)`, so an in-process SimComm run and a P-rank
subprocess run inject the same fault sequence per rank.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import time

import numpy as np

from .comm import (
    Comm,
    CommHandle,
    decode_payload,
    encode_payload,
    frame_blob,
    unframe_blob,
    _FRAME,
)
from .errors import (
    CheckpointIntegrityError,
    CommTimeoutError,
    InjectedCrash,
    RankTimeoutError,
    ResilienceError,
    WireFormatError,
    WireIntegrityError,
)

__all__ = [
    "ChaosConfig",
    "ChaosComm",
    "Autosaver",
    "recover",
    "ResilienceError",
    "WireFormatError",
    "WireIntegrityError",
    "CommTimeoutError",
    "CheckpointIntegrityError",
    "InjectedCrash",
    "RankTimeoutError",
]

_BYTE_FAULTS = ("corrupt", "truncate", "duplicate")


@dataclasses.dataclass
class ChaosConfig:
    """Seeded fault plan for a `ChaosComm`.

    Rates are per delivered payload (byte faults) or per posted collective
    (delay); `stall_after`/`crash_at` count collectives posted in an
    eligible phase.  `phases=None` means every phase is eligible;
    `max_faults` bounds total injected byte faults; `max_retries` bounds
    the transient-fault redelivery loop (exhaustion re-raises the
    detection error instead of looping forever)."""

    seed: int = 0
    p_corrupt: float = 0.0
    p_truncate: float = 0.0
    p_duplicate: float = 0.0
    p_delay: float = 0.0
    delay_s: float = 0.001
    stall_after: int | None = None   # collectives before handles stop maturing
    crash_at: int | None = None      # collective index that kills crash_ranks
    crash_ranks: tuple = ()
    hard_exit: bool = False          # os._exit(2) instead of InjectedCrash
    phases: tuple | None = None      # eligible phase names, None = all
    max_faults: int | None = None
    max_retries: int = 3
    # transient faults (default): a detected fault's redelivery is pristine,
    # so every byte fault costs exactly one bounded retry.  persistent_faults
    # re-rolls the fault on every redelivery — a rotten link — which is how
    # the tests prove the retry loop is bounded (exhaustion re-raises).
    persistent_faults: bool = False


class ChaosComm(Comm):
    """A `Comm` that injects the configured faults between post and
    delivery.  Meters, phases, and (for DistComm inners) the wire digest
    are shared with the wrapped comm; results under byte faults are
    bit-identical to the fault-free run because detection triggers
    redelivery of the pristine payload — exactly the retry contract the
    hardened transports implement for real corruption."""

    def __init__(self, inner: Comm, config: ChaosConfig | None = None, **kw):
        super().__init__()
        self.inner = inner
        self.cfg = config if config is not None else ChaosConfig(**kw)
        # share the metering state: one phase stack, one counter table
        self.counters = inner.counters
        self._phases = inner._phases
        self.size = inner.size
        self.rank = inner.rank
        self.local_ranks = inner.local_ranks
        self.fault_counts = {k: 0 for k in
                             (*_BYTE_FAULTS, "delay", "stall", "crash",
                              "detected", "retries")}
        self._ncoll: dict[str, int] = {}
        self._rng = np.random.default_rng([int(self.cfg.seed), int(inner.rank)])
        self._wire = hashlib.sha256()

    @property
    def P(self) -> int:
        return self.size

    def barrier(self) -> None:
        self.inner.barrier()

    def wire_digest(self) -> str:
        if hasattr(self.inner, "wire_digest"):
            return self.inner.wire_digest()
        return self._wire.hexdigest()

    def injected(self) -> int:
        """Total byte faults injected so far (the `max_faults` budget)."""
        return sum(self.fault_counts[k] for k in _BYTE_FAULTS)

    # -- fault plan --------------------------------------------------------
    def _phase_name(self) -> str:
        return self._phases[-1] if self._phases else "default"

    def _eligible(self, ph: str) -> bool:
        return self.cfg.phases is None or ph in self.cfg.phases

    def _me_crashes(self) -> bool:
        if not self.cfg.crash_ranks:
            return False
        if len(self.local_ranks) > 1:   # in-process world hosts the victim
            return True
        return self.rank in self.cfg.crash_ranks

    def _pre_post(self, ph: str) -> dict:
        """Advance the per-phase collective counter; fire crash faults and
        decide stall/delay for the handle about to be posted."""
        plan = {"stall": False, "delay": False}
        if not self._eligible(ph):
            return plan
        n = self._ncoll.get(ph, 0) + 1
        self._ncoll[ph] = n
        cfg = self.cfg
        if cfg.crash_at is not None and n >= cfg.crash_at and self._me_crashes():
            self.fault_counts["crash"] += 1
            victim = (self.rank if self.rank in cfg.crash_ranks
                      else int(cfg.crash_ranks[0]))
            if cfg.hard_exit:
                os._exit(2)
            raise InjectedCrash(phase=ph, seq=n, rank=victim)
        if cfg.stall_after is not None and n > cfg.stall_after:
            plan["stall"] = True
            self.fault_counts["stall"] += 1
        elif cfg.p_delay and float(self._rng.random()) < cfg.p_delay:
            plan["delay"] = True
            self.fault_counts["delay"] += 1
        return plan

    def _roll_byte_fault(self, ph: str) -> str | None:
        cfg = self.cfg
        if not self._eligible(ph):
            return None
        if cfg.max_faults is not None and self.injected() >= cfg.max_faults:
            return None
        u = float(self._rng.random())
        if u < cfg.p_corrupt:
            return "corrupt"
        if u < cfg.p_corrupt + cfg.p_truncate:
            return "truncate"
        if u < cfg.p_corrupt + cfg.p_truncate + cfg.p_duplicate:
            return "duplicate"
        return None

    def _mutate(self, framed: bytes, kind: str) -> bytes:
        rng = self._rng
        if kind == "corrupt":
            # flip one body byte: the CRC32 in the header must catch it
            idx = _FRAME.size + int(rng.integers(0, len(framed) - _FRAME.size))
            flip = 1 + int(rng.integers(0, 255))
            b = bytearray(framed)
            b[idx] ^= flip
            return bytes(b)
        if kind == "truncate":
            k = 1 + int(rng.integers(0, max(1, len(framed) // 4)))
            return framed[:-k]
        if kind == "duplicate":
            return framed + framed[_FRAME.size:]
        raise AssertionError(kind)

    def _deliver(self, val, ph: str, where: str):
        """Roundtrip one payload through the seeded wire.  A rolled fault
        mutates the framed bytes; detection (the production unframe/decode
        path) counts and redelivers — pristine bytes are re-faulted at the
        configured rate, so `max_retries` bounds a persistently bad link."""
        last_err = None
        for attempt in range(self.cfg.max_retries + 1):
            kind = (self._roll_byte_fault(ph)
                    if (attempt == 0 or self.cfg.persistent_faults) else None)
            if kind is None:
                if attempt:
                    self.fault_counts["retries"] += attempt
                return val
            framed = self._mutate(frame_blob(encode_payload(val)), kind)
            self.fault_counts[kind] += 1
            try:
                # a mutation that somehow passes both the frame check and
                # the codec is delivered decoded — the integrity tests
                # assert this branch is never reached by these fault kinds
                out = decode_payload(unframe_blob(framed, where=where))
                return out
            except (WireIntegrityError, WireFormatError) as e:
                self.fault_counts["detected"] += 1
                last_err = e
        self.fault_counts["retries"] += self.cfg.max_retries
        raise last_err

    # -- handle wrapping ---------------------------------------------------
    def _stalled(self, ph: str, seq: int) -> CommHandle:
        """A handle that never matures: `done()` stays False and a
        deadlined `wait()` raises `CommTimeoutError` naming the phase; an
        undeadlined `wait()` blocks — faithfully — forever."""

        def complete():
            while True:  # pragma: no cover - only reachable without deadline
                time.sleep(0.01)

        h = CommHandle(complete, poll=lambda: False)
        h.phase, h.seq = ph, seq
        return h

    def _wrap(self, h: CommHandle, plan: dict, transform) -> CommHandle:
        ready_at = (time.monotonic() + self.cfg.delay_s
                    if plan["delay"] else None)

        def poll() -> bool:
            if ready_at is not None and time.monotonic() < ready_at:
                return False
            return h.done()

        def complete():
            if ready_at is not None:
                rem = ready_at - time.monotonic()
                if rem > 0:
                    time.sleep(rem)
            return transform(h.wait())

        nh = CommHandle(complete, poll=poll)
        # keep the transport's per-peer diagnostics (pending ranks, beacon
        # probe) visible through the wrapper: a deadlined wait() must still
        # name WHO is missing, chaos or not
        nh._pending = h._pending
        nh._diagnose = h._diagnose
        return nh

    # -- collectives -------------------------------------------------------
    def iallgather(self, per_local):
        ph = self._phase_name()
        plan = self._pre_post(ph)
        if not hasattr(self.inner, "wire_digest"):
            for x in per_local:
                self._wire.update(encode_payload(x))
        if plan["stall"]:
            # meter what WOULD have been posted, then stall the handle
            h = self.inner.iallgather(per_local)
            return self._stamp(self._stalled(ph, self._hseq + 1))
        h = self.inner.iallgather(per_local)
        sim = len(self.local_ranks) > 1   # in-process: self rows fault too

        def transform(out):
            return [self._deliver(v, ph, f"{ph}:ag:{p}->{self.rank}")
                    if (sim or p != self.rank) else v
                    for p, v in enumerate(out)]

        return self._stamp(self._wrap(h, plan, transform))

    def ialltoallv(self, send):
        ph = self._phase_name()
        plan = self._pre_post(ph)
        if not hasattr(self.inner, "wire_digest"):
            for i, g in enumerate(self.local_ranks):
                for q, x in enumerate(send[i]):
                    if q != g:
                        self._wire.update(encode_payload(x))
        if plan["stall"]:
            h = self.inner.ialltoallv(send)
            return self._stamp(self._stalled(ph, self._hseq + 1))
        h = self.inner.ialltoallv(send)
        locs = list(self.local_ranks)

        def transform(rows):
            return [[self._deliver(v, ph, f"{ph}:a2a:{p}->{g}")
                     if p != g else v
                     for p, v in enumerate(row)]
                    for g, row in zip(locs, rows)]

        return self._stamp(self._wrap(h, plan, transform))


# ------------------------------------------------------------- checkpointing
class Autosaver:
    """A `forest.RESILIENCE_HOOKS` hook: periodic `save_forest` snapshots
    keyed to balance/repartition entry, so a rank crash mid-collective
    always has a consistent pre-phase checkpoint to `recover` from.

    Saves run under their own "checkpoint" comm phase (inside
    `save_forest`), so autosave traffic never pollutes the balance/ghost
    byte attribution the benchmarks record."""

    def __init__(self, path, *, every: int = 1,
                 events=("balance:begin", "repartition:begin"),
                 step0: int = 0):
        self.path = path
        self.every = max(1, int(every))
        self.events = tuple(events)
        self.count = 0
        self.step = int(step0)
        self.saved_steps: list[int] = []

    def __call__(self, event: str, forests, comm) -> None:
        if event not in self.events:
            return
        self.count += 1
        if (self.count - 1) % self.every:
            return
        from ..checkpoint.forest_io import save_forest  # noqa: PLC0415

        save_forest(self.path, forests, comm, step=self.step)
        self.saved_steps.append(self.step)
        self.step += 1

    def install(self) -> "Autosaver":
        from . import forest  # noqa: PLC0415

        forest.RESILIENCE_HOOKS.append(self)
        return self

    def uninstall(self) -> None:
        from . import forest  # noqa: PLC0415

        if self in forest.RESILIENCE_HOOKS:
            forest.RESILIENCE_HOOKS.remove(self)


def recover(path, comm, *, step: int | None = None, cmesh=None,
            weights=None, verify: bool = True):
    """Restore the forest from the last (or a given) checkpoint onto
    `comm` — elastically: the survivors' world may be smaller than the
    world that saved.  Integrity is checked (stored CRC32s, counts) and
    the restored global forest is validated before slicing; any failure
    is a `CheckpointIntegrityError`, never a silently wrong forest."""
    from ..checkpoint.forest_io import load_forest  # noqa: PLC0415

    return load_forest(path, comm, step=step, cmesh=cmesh, weights=weights,
                       verify=verify)
