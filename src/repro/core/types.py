"""Core data types for the tetrahedral-Morton SFC library.

A `Simplex` is the paper's `Tet` data type (Remark 20): anchor coordinates,
refinement level, and type.  We use a structure-of-arrays layout so that a
batch of N elements is three int32 arrays — the JAX/TPU-native equivalent of
the paper's 14-bytes-per-Tet encoding (coords int32 x d, level+type one byte
each; we keep level/type as int32 lanes for gather friendliness and pack them
to int8 at rest, see `pack`/`unpack`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------- element classes
# The element class is a *static dispatch axis*, never a per-element array
# lane: every leaf of a tree shares its tree's class, so batches are always
# single-class and the (d, eclass) pair selects the ops / kernels / program
# caches.  Simplices are the paper's tetrahedral-Morton curve; hexes ride
# the plain Morton curve (no type bits — `stype` is identically 0 and is
# dropped from the at-rest encoding).
ECLASS_SIMPLEX = 0
ECLASS_HEX = 1
NUM_ECLASSES = 2
ECLASS_NAMES = {ECLASS_SIMPLEX: "simplex", ECLASS_HEX: "hex"}


class Simplex(NamedTuple):
    """A batch of d-simplices (triangles or tetrahedra).

    anchor: (..., d) int32 — anchor node coordinates in [0, 2^MAXLEVEL).
    level:  (...,)  int32 — refinement level, 0 <= level <= MAXLEVEL.
    stype:  (...,)  int32 — type in [0, d!), cf. paper Definition 5.
    """

    anchor: jax.Array
    level: jax.Array
    stype: jax.Array

    @property
    def d(self) -> int:
        return self.anchor.shape[-1]

    @property
    def shape(self):
        return self.level.shape


def simplex(anchor, level, stype) -> Simplex:
    anchor = jnp.asarray(anchor, jnp.int32)
    level = jnp.broadcast_to(jnp.asarray(level, jnp.int32), anchor.shape[:-1])
    stype = jnp.broadcast_to(jnp.asarray(stype, jnp.int32), anchor.shape[:-1])
    return Simplex(anchor, level, stype)


def root(d: int) -> Simplex:
    """The root simplex T_d^0 (type 0, level 0, anchor at the origin)."""
    return Simplex(jnp.zeros((d,), jnp.int32), jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def concat(simplices, axis=0) -> Simplex:
    return Simplex(
        jnp.concatenate([s.anchor for s in simplices], axis=axis),
        jnp.concatenate([s.level for s in simplices], axis=axis),
        jnp.concatenate([s.stype for s in simplices], axis=axis),
    )


def take(s: Simplex, idx) -> Simplex:
    return Simplex(s.anchor[idx], s.level[idx], s.stype[idx])


def pack(s: Simplex, eclass: int = ECLASS_SIMPLEX) -> dict:
    """At-rest encoding (paper Remark 20): int32 coords + int8 level
    (+ int8 type for simplices only).

    Simplices: 10 bytes per triangle / 14 per tetrahedron — byte-identical
    to the pre-eclass layout so existing checkpoints restore unchanged.
    Hexes carry no type bits: 9 bytes per quad / 13 per hexahedron."""
    blob = {
        "anchor": np.asarray(s.anchor, np.int32),
        "level": np.asarray(s.level, np.int8),
    }
    if eclass == ECLASS_SIMPLEX:
        blob["stype"] = np.asarray(s.stype, np.int8)
    elif eclass != ECLASS_HEX:
        raise ValueError(f"unknown element class {eclass!r}")
    return blob


def unpack(blob: dict) -> Simplex:
    """Inverse of `pack`.  A blob without a "stype" column is a hex blob
    (plain Morton, no type bits) — its stype lane is identically 0."""
    level = jnp.asarray(blob["level"], jnp.int32)
    if "stype" in blob:
        stype = jnp.asarray(blob["stype"], jnp.int32)
    else:
        stype = jnp.zeros_like(level)
    return Simplex(jnp.asarray(blob["anchor"], jnp.int32), level, stype)


def nbytes_at_rest(s: Simplex, eclass: int = ECLASS_SIMPLEX) -> int:
    """Storage per paper Remark 20: 4*d + 2 bytes per simplex (coords +
    level + type), 4*d + 1 per hex (no type byte)."""
    d = s.anchor.shape[-1]
    n = int(np.prod(s.level.shape)) if s.level.shape else 1
    if eclass == ECLASS_SIMPLEX:
        return n * (4 * d + 2)
    if eclass == ECLASS_HEX:
        return n * (4 * d + 1)
    raise ValueError(f"unknown element class {eclass!r}")


# ----------------------------------------------------------- wire encoding
# The on-wire form of an element reference is the paper's Remark 20
# low-memory encoding: the level-padded key plus the level fully determine
# the element (anchor and type are recovered by Algorithm 4.8 / `decode`),
# so a (tree, key, level) triple is 13 bytes — what Balance/Ghost queries
# and boundary-layer notifications ship between ranks.  An optional extra
# byte rides along (Ghost uses it for the dual face index).
#
# The element class rides in bits 6-7 of the level byte: levels fit in six
# bits (MAXLEVEL <= 63 in every dimension), so simplex triples — eclass 0 —
# are byte-identical to the pre-eclass wire format, and a receiver can
# validate/dispatch per class without widening the entry.  Unknown class
# bits (eclass >= NUM_ECLASSES) are rejected like any other out-of-domain
# field, so hex keys can never be silently misrouted through simplex
# decode (nor vice versa).
WIRE_TRIPLE_BYTES = 13  # uint64 key + int32 tree + uint8 (eclass<<6 | level)
WIRE_QUAD_BYTES = 14    # ... + uint8 extra
WIRE_LEVEL_MASK = 0x3F
WIRE_ECLASS_SHIFT = 6


def _wire_dtype(with_extra: bool) -> np.dtype:
    fields = [("key", "<u8"), ("tree", "<i4"), ("level", "u1")]
    if with_extra:
        fields.append(("extra", "u1"))
    return np.dtype(fields)


def pack_wire(tree, key, level, extra=None, eclass=ECLASS_SIMPLEX) -> np.ndarray:
    """Pack (tree, key, level[, extra]) columns into a flat uint8 wire buffer
    (13 or 14 bytes per entry, little-endian).  `eclass` (scalar or per-entry
    column) is folded into bits 6-7 of the level byte."""
    tree = np.asarray(tree, np.int32)
    key = np.asarray(key, np.uint64)
    level = np.asarray(level, np.uint8)
    ec = np.asarray(eclass, np.uint8)
    if ec.size and int(ec.max(initial=0)) >= NUM_ECLASSES:
        raise ValueError(f"unknown element class in {np.unique(ec)!r}")
    rec = np.empty(len(key), _wire_dtype(extra is not None))
    rec["key"], rec["tree"] = key, tree
    rec["level"] = level | (ec << np.uint8(WIRE_ECLASS_SHIFT))
    if extra is not None:
        rec["extra"] = np.asarray(extra, np.uint8)
    return rec.view(np.uint8).reshape(-1)


def unpack_wire(buf: np.ndarray, with_extra: bool = False,
                with_eclass: bool = False):
    """Inverse of `pack_wire`: returns (tree, key, level[, extra][, eclass])
    columns (the eclass column only when `with_eclass`; it is validated
    either way).

    Malformed input — a buffer that is not a whole number of entries, a
    non-byte dtype, or entries with out-of-domain tree/level/eclass fields
    (a truncation that happens to land on an entry boundary decodes to
    garbage columns otherwise) — raises `WireFormatError`, never a bare
    assert or a silently misaligned view."""
    from .errors import WireFormatError  # noqa: PLC0415

    try:
        buf = np.asarray(buf, np.uint8).reshape(-1)
    except (ValueError, TypeError) as e:
        raise WireFormatError(f"wire buffer is not a byte array: {e}") from e
    dt = _wire_dtype(with_extra)
    if buf.size % dt.itemsize != 0:
        raise WireFormatError(
            f"wire buffer of {buf.size} byte(s) is not a whole number of "
            f"{dt.itemsize}-byte entries")
    rec = buf.view(dt)
    tree = rec["tree"].astype(np.int32)
    lv_byte = rec["level"].astype(np.int32)
    level = lv_byte & WIRE_LEVEL_MASK
    ec = lv_byte >> WIRE_ECLASS_SHIFT
    if rec.size:
        if int(tree.min()) < 0:
            raise WireFormatError(
                f"wire entries carry negative tree ids (min {int(tree.min())})")
        if int(ec.max()) >= NUM_ECLASSES:
            raise WireFormatError(
                f"wire entries carry an unknown element class "
                f"(max {int(ec.max())} >= {NUM_ECLASSES})")
    out = (tree, rec["key"].astype(np.uint64), level)
    if with_extra:
        out = out + (rec["extra"].astype(np.int32),)
    if with_eclass:
        out = out + (ec,)
    return out
