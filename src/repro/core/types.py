"""Core data types for the tetrahedral-Morton SFC library.

A `Simplex` is the paper's `Tet` data type (Remark 20): anchor coordinates,
refinement level, and type.  We use a structure-of-arrays layout so that a
batch of N elements is three int32 arrays — the JAX/TPU-native equivalent of
the paper's 14-bytes-per-Tet encoding (coords int32 x d, level+type one byte
each; we keep level/type as int32 lanes for gather friendliness and pack them
to int8 at rest, see `pack`/`unpack`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Simplex(NamedTuple):
    """A batch of d-simplices (triangles or tetrahedra).

    anchor: (..., d) int32 — anchor node coordinates in [0, 2^MAXLEVEL).
    level:  (...,)  int32 — refinement level, 0 <= level <= MAXLEVEL.
    stype:  (...,)  int32 — type in [0, d!), cf. paper Definition 5.
    """

    anchor: jax.Array
    level: jax.Array
    stype: jax.Array

    @property
    def d(self) -> int:
        return self.anchor.shape[-1]

    @property
    def shape(self):
        return self.level.shape


def simplex(anchor, level, stype) -> Simplex:
    anchor = jnp.asarray(anchor, jnp.int32)
    level = jnp.broadcast_to(jnp.asarray(level, jnp.int32), anchor.shape[:-1])
    stype = jnp.broadcast_to(jnp.asarray(stype, jnp.int32), anchor.shape[:-1])
    return Simplex(anchor, level, stype)


def root(d: int) -> Simplex:
    """The root simplex T_d^0 (type 0, level 0, anchor at the origin)."""
    return Simplex(jnp.zeros((d,), jnp.int32), jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def concat(simplices, axis=0) -> Simplex:
    return Simplex(
        jnp.concatenate([s.anchor for s in simplices], axis=axis),
        jnp.concatenate([s.level for s in simplices], axis=axis),
        jnp.concatenate([s.stype for s in simplices], axis=axis),
    )


def take(s: Simplex, idx) -> Simplex:
    return Simplex(s.anchor[idx], s.level[idx], s.stype[idx])


def pack(s: Simplex) -> dict:
    """At-rest encoding, 10 bytes per triangle / 14 bytes per tetrahedron
    (paper Remark 20): int32 coords + int8 level + int8 type."""
    return {
        "anchor": np.asarray(s.anchor, np.int32),
        "level": np.asarray(s.level, np.int8),
        "stype": np.asarray(s.stype, np.int8),
    }


def unpack(blob: dict) -> Simplex:
    return Simplex(
        jnp.asarray(blob["anchor"], jnp.int32),
        jnp.asarray(blob["level"], jnp.int32),
        jnp.asarray(blob["stype"], jnp.int32),
    )


def nbytes_at_rest(s: Simplex) -> int:
    """Storage per paper Remark 20: 4*d + 2 bytes per element."""
    d = s.anchor.shape[-1]
    n = int(np.prod(s.level.shape)) if s.level.shape else 1
    return n * (4 * d + 2)


# ----------------------------------------------------------- wire encoding
# The on-wire form of an element reference is the paper's Remark 20
# low-memory encoding: the level-padded key plus the level fully determine
# the element (anchor and type are recovered by Algorithm 4.8 / `decode`),
# so a (tree, key, level) triple is 13 bytes — what Balance/Ghost queries
# and boundary-layer notifications ship between ranks.  An optional extra
# byte rides along (Ghost uses it for the dual face index).
WIRE_TRIPLE_BYTES = 13  # uint64 key + int32 tree + uint8 level
WIRE_QUAD_BYTES = 14    # ... + uint8 extra


def _wire_dtype(with_extra: bool) -> np.dtype:
    fields = [("key", "<u8"), ("tree", "<i4"), ("level", "u1")]
    if with_extra:
        fields.append(("extra", "u1"))
    return np.dtype(fields)


def pack_wire(tree, key, level, extra=None) -> np.ndarray:
    """Pack (tree, key, level[, extra]) columns into a flat uint8 wire buffer
    (13 or 14 bytes per entry, little-endian)."""
    tree = np.asarray(tree, np.int32)
    key = np.asarray(key, np.uint64)
    level = np.asarray(level, np.uint8)
    rec = np.empty(len(key), _wire_dtype(extra is not None))
    rec["key"], rec["tree"], rec["level"] = key, tree, level
    if extra is not None:
        rec["extra"] = np.asarray(extra, np.uint8)
    return rec.view(np.uint8).reshape(-1)


def unpack_wire(buf: np.ndarray, with_extra: bool = False):
    """Inverse of `pack_wire`: returns (tree, key, level[, extra]) columns.

    Malformed input — a buffer that is not a whole number of entries, a
    non-byte dtype, or entries with out-of-domain tree/level fields (a
    truncation that happens to land on an entry boundary decodes to
    garbage columns otherwise) — raises `WireFormatError`, never a bare
    assert or a silently misaligned view."""
    from .errors import WireFormatError  # noqa: PLC0415

    try:
        buf = np.asarray(buf, np.uint8).reshape(-1)
    except (ValueError, TypeError) as e:
        raise WireFormatError(f"wire buffer is not a byte array: {e}") from e
    dt = _wire_dtype(with_extra)
    if buf.size % dt.itemsize != 0:
        raise WireFormatError(
            f"wire buffer of {buf.size} byte(s) is not a whole number of "
            f"{dt.itemsize}-byte entries")
    rec = buf.view(dt)
    tree = rec["tree"].astype(np.int32)
    level = rec["level"].astype(np.int32)
    if rec.size:
        if int(tree.min()) < 0:
            raise WireFormatError(
                f"wire entries carry negative tree ids (min {int(tree.min())})")
        if int(level.max()) > 63:
            raise WireFormatError(
                f"wire entries carry implausible levels "
                f"(max {int(level.max())} > 63)")
    out = (tree, rec["key"].astype(np.uint64), level)
    if with_extra:
        out = out + (rec["extra"].astype(np.int32),)
    return out
