"""SFC-based load balancing applied to LM training/serving workloads.

This is the bridge between the paper's contribution and the training
framework: the paper's Partition algorithm — *weighted, contiguous splitting
of a totally ordered element set in linear time* (Sec. 5, [40]) — reused for

  1. MoE expert placement: experts ordered along the curve, partitioned onto
     devices by measured token load (`expert_placement`).
  2. Token/document packing: variable-length documents assigned to data-
     parallel ranks with balanced token counts (`document_partition`).
  3. KV-page layout: paged-attention block tables laid out in SFC order so
     consecutive pages of one request stay local (`page_order`).

All functions are pure jnp and jittable with fixed shapes, so they run
*inside* pjit-ed programs on the production mesh (prefix sums lower to
efficient scans/collectives under GSPMD).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "target_ranks",
    "target_ranks_np",
    "partition_offsets",
    "expert_placement",
    "document_partition",
    "page_order",
    "imbalance",
]


def target_ranks(weights: jax.Array, num_ranks: int) -> jax.Array:
    """Paper's Partition rule, vectorized: item i (in curve order) goes to rank
    floor(P * (W_{<i} + w_i/2) / W_total), clipped and made monotone.

    weights: (n,) nonnegative. Returns int32 (n,) target ranks, ascending.
    """
    w = jnp.asarray(weights, jnp.float32)
    cum = jnp.cumsum(w) - w / 2.0
    total = jnp.maximum(jnp.sum(w), 1e-30)
    t = jnp.floor(cum * (num_ranks / total)).astype(jnp.int32)
    t = jnp.clip(t, 0, num_ranks - 1)
    # cumulative max keeps assignment contiguous under zero-weight runs
    return jax.lax.associative_scan(jnp.maximum, t)


def target_ranks_np(cum_mid: np.ndarray, num_ranks: int,
                    total: float) -> np.ndarray:
    """The same Partition rule in its SPMD host-numpy form, over *global*
    midpoint prefix sums: `cum_mid[i] = W_{<i} + w_i/2` where `W_{<i}`
    counts every element before i on ANY rank (the caller adds its rank's
    global weight prefix) and `total` is the world weight sum.

    Every rank evaluating its own slice of `cum_mid` against the same
    `total` reproduces exactly the assignment a single rank would compute
    over the concatenated weights — this is what `forest.repartition` and
    the weighted checkpoint restore route through.  float64, and the
    trailing cumulative max keeps targets monotone so each destination
    rank's elements form one contiguous run.  A rank whose weight share
    rounds to zero elements simply never appears in the output (the
    empty-rank case `forest.partition_markers` fills in).

    Returns int64 (n,) ascending target ranks in [0, num_ranks).
    """
    cum = np.asarray(cum_mid, np.float64)
    t = np.minimum((cum * num_ranks / max(total, 1e-300)).astype(np.int64),
                   num_ranks - 1)
    t = np.maximum(t, 0)
    return np.maximum.accumulate(t)


def partition_offsets(weights: jax.Array, num_ranks: int) -> jax.Array:
    """(P+1,) split offsets such that rank p owns items [off[p], off[p+1])."""
    t = target_ranks(weights, num_ranks)
    counts = jnp.bincount(t, length=num_ranks)
    return jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])


def imbalance(weights: jax.Array, t: jax.Array, num_ranks: int) -> jax.Array:
    """max rank load / mean rank load (1.0 = perfect)."""
    w = jnp.asarray(weights, jnp.float32)
    loads = jax.ops.segment_sum(w, t, num_segments=num_ranks)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-30)


def expert_placement(expert_load: jax.Array, num_devices: int):
    """Contiguous expert->device map balancing measured token load.

    expert_load: (E,) tokens routed to each expert over a window.
    Returns (device_of_expert (E,), imbalance scalar).  Contiguity along the
    expert order keeps the all-to-all pattern block-structured (each device
    sends to a contiguous device range), exactly the property the SFC
    partition gives mesh elements in the paper.
    """
    t = target_ranks(expert_load, num_devices)
    return t, imbalance(expert_load, t, num_devices)


def document_partition(doc_tokens: jax.Array, num_ranks: int):
    """Assign documents (in corpus order) to DP ranks with balanced tokens.

    Returns (rank_of_doc, imbalance).  Linear time, order preserving —
    the data-pipeline analogue of partitioning mesh elements by weight.
    """
    t = target_ranks(doc_tokens, num_ranks)
    return t, imbalance(doc_tokens, t, num_ranks)


def _interleave_bits_2d(x: jax.Array, y: jax.Array, bits: int) -> jax.Array:
    out = jnp.zeros_like(x)
    for i in range(bits):
        out = out | (((x >> i) & 1) << (2 * i)) | (((y >> i) & 1) << (2 * i + 1))
    return out


def page_order(num_pages_per_req: int, num_requests: int) -> jax.Array:
    """SFC (Morton) traversal order of the (request, page) grid for paged-KV
    block tables: consecutive pages of one request map to nearby physical
    blocks, and co-scheduled requests stay clustered.

    Returns int32 (num_requests, num_pages_per_req) physical order ranks.
    """
    r = jnp.arange(num_requests, dtype=jnp.int32)[:, None]
    p = jnp.arange(num_pages_per_req, dtype=jnp.int32)[None, :]
    bits = max(int(np.ceil(np.log2(max(num_requests, 2)))),
               int(np.ceil(np.log2(max(num_pages_per_req, 2)))))
    key = _interleave_bits_2d(
        jnp.broadcast_to(p, (num_requests, num_pages_per_req)),
        jnp.broadcast_to(r, (num_requests, num_pages_per_req)),
        bits,
    )
    flat = key.reshape(-1)
    rank = jnp.argsort(jnp.argsort(flat)).astype(jnp.int32)
    return rank.reshape(num_requests, num_pages_per_req)
