"""Forest-of-trees AMR on the tetrahedral SFC (paper Section 5).

Implements the top-level algorithms New, Adapt, Partition, Balance, Ghost and
Iterate over a *forest*: a coarse mesh of K root simplices ("trees"), each
adaptively refined, with leaves totally ordered by (tree, TM-index) and
partitioned across P ranks by contiguous SFC ranges.

This module is the distributed-algorithm layer.  It is written in SPMD style:
every function computes the view of the ranks resident in this process
(`comm.local_ranks` — all P under the in-process `SimComm`, exactly one
under `DistComm`/MPI), and every cross-rank exchange goes through the
`repro.core.comm.Comm` surface (allgather / alltoallv with per-phase byte
metering).  Balance and Ghost are *message based*: ranks allgather only the
P partition markers, route packed (tree, key, level) key-range queries to
owner ranks via `alltoallv`, answer them from their local sorted leaf
arrays, and iterate Balance exchanging only the boundary layer that changed
each round (the ripple scheme of Isaac-Burstedde-Ghattas).  The former
global-leaf-table implementations are retained as `balance_oracle` /
`ghost_oracle` — the simulator-era baseline the message path must match
element for element (and the wire-volume baseline in the benchmarks).

The heavy per-element math goes through the batched dispatch layer
`repro.core.batch` (reference / jnp / pallas backends over `Simplex`
batches — gathers + integer ALU, TPU/SIMD friendly), including the
marker-table `owner_rank` searchsorted that routes every query; the
variable-size bookkeeping stays in numpy on the host, matching how meshing
layers sit next to accelerator compute in production frameworks.

Inter-tree face connectivity — the paper's stated open extension (Balance and
Ghost "require additional theoretical work" across root simplices) — is
provided by the coarse-mesh layer `repro.core.cmesh`: a forest built with a
`Cmesh` follows face neighbors across tree faces (transforming elements into
the neighbor tree's frame with the per-connection gluing tables) and treats
only the Cmesh's unconnected faces as domain boundary.  A forest without a
Cmesh (`cmesh=None`) keeps the paper's single-tree semantics: every tree
face is a boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax.numpy as jnp

from . import u64 as u64m
from .batch import BatchedOps, count_dispatch as batch_count_dispatch, get_batch_ops
from .cmesh import Cmesh, wrap_i32
from .comm import Comm, CommHandle, DistComm, LatencyComm, LocalComm, SimComm
from .ops import ElementOps, get_ops
from .placement import target_ranks_np
from .tables import face_plane
from .types import ECLASS_SIMPLEX, Simplex, pack_wire, unpack_wire

__all__ = [
    "Forest",
    "Comm",
    "CommHandle",
    "SimComm",
    "LocalComm",
    "LatencyComm",
    "DistComm",
    "new_uniform",
    "adapt",
    "partition",
    "repartition",
    "load_imbalance",
    "partition_markers",
    "balance",
    "balance_oracle",
    "BalanceNonConvergence",
    "ghost",
    "ghost_oracle",
    "iterate",
    "validate",
    "count_global",
    "face_kind",
    "face_kinds",
    "face_sweep_layer",
    "FaceSweepLayer",
    "FACE_INTERIOR",
    "FACE_INTER_TREE",
    "FACE_DOMAIN_BOUNDARY",
]


# ------------------------------------------------------------------- forest
@dataclasses.dataclass
class Forest:
    """One rank's portion of a partitioned forest.

    Elements are stored SoA (anchor/level/type + owning tree) in ascending
    (tree, TM-index) order — the paper's linear storage along the SFC.
    """

    d: int
    num_trees: int
    rank: int
    num_ranks: int
    anchor: np.ndarray        # (n, d) int32
    level: np.ndarray         # (n,)  int32
    stype: np.ndarray         # (n,)  int32
    tree: np.ndarray          # (n,)  int32
    keys: np.ndarray          # (n,)  uint64 morton keys (level-padded ids)
    # coarse-mesh connectivity; None = isolated trees (paper's single-tree
    # semantics: every tree face is a domain boundary)
    cmesh: Cmesh | None = None

    @property
    def eclasses(self) -> tuple:
        """Element classes present in the coarse mesh (every leaf of a tree
        shares the tree's class; no cmesh means the paper's simplex-only
        setting)."""
        return (ECLASS_SIMPLEX,) if self.cmesh is None else self.cmesh.eclasses

    @property
    def eclass(self) -> int:
        """The single element class of this forest's leaves.  For a forest
        over a mixed-class cmesh this is the class of the locally present
        trees; a rank holding leaves of MORE than one class has no single
        class — group by class first (`_class_groups`)."""
        ecs = self.eclasses
        if len(ecs) == 1:
            return ecs[0]
        present = np.unique(self.cmesh.tree_eclass[self.tree])
        if len(present) > 1:
            raise ValueError(
                "forest holds leaves of multiple element classes; "
                "group by class before using per-class ops")
        return int(present[0]) if len(present) else ECLASS_SIMPLEX

    @property
    def ops(self) -> ElementOps:
        return get_ops(self.d, self.eclass)

    @property
    def bops(self) -> BatchedOps:
        """Batched element ops under the globally selected backend."""
        return get_batch_ops(self.d, eclass=self.eclass)

    @property
    def num_local(self) -> int:
        return len(self.level)

    def simplices(self) -> Simplex:
        # memoized device view: element arrays are immutable (adapt &c.
        # return NEW Forests), so the upload happens once per Forest
        s = self.__dict__.get("_simplices_cache")
        if s is None:
            s = Simplex(jnp.asarray(self.anchor), jnp.asarray(self.level),
                        jnp.asarray(self.stype))
            self.__dict__["_simplices_cache"] = s
        return s

    def replace_elements(self, anchor, level, stype, tree) -> "Forest":
        anchor = np.asarray(anchor, np.int32)
        level = np.asarray(level, np.int32)
        stype = np.asarray(stype, np.int32)
        tree = np.asarray(tree, np.int32)
        ecs = self.eclasses
        if len(ecs) == 1:
            s = Simplex(jnp.asarray(anchor), jnp.asarray(level), jnp.asarray(stype))
            keys = get_batch_ops(self.d, eclass=ecs[0]).morton_key_np(s)
        else:
            # mixed-class mesh: every tree's leaves encode with the tree's
            # class — one batched key dispatch per class present
            keys = np.zeros(len(level), np.uint64)
            te = self.cmesh.tree_eclass[tree]
            for ec in ecs:
                m = te == ec
                if m.any():
                    s = Simplex(jnp.asarray(anchor[m]), jnp.asarray(level[m]),
                                jnp.asarray(stype[m]))
                    keys[m] = get_batch_ops(self.d, eclass=ec).morton_key_np(s)
        return dataclasses.replace(
            self,
            anchor=anchor, level=level, stype=stype, tree=tree, keys=keys,
        )

    def global_first_desc_key(self):
        """(tree, key) of this rank's first element; used as partition marker."""
        if self.num_local == 0:
            return (self.num_trees, np.uint64(0))
        return (int(self.tree[0]), self.keys[0])

    def repartition(self, comm: Comm, weights: np.ndarray | None = None,
                    overlap: bool = True) -> "Forest":
        """Single-local-rank convenience over the module-level `repartition`
        (the DistComm hosting, one rank per process): migrate this rank's
        elements per the global weight distribution and return the new
        local forest.  Under a multi-rank hosting (`SimComm`) call the
        module-level form with all local forests instead."""
        assert len(comm.local_ranks) == 1, (
            "Forest.repartition is the one-rank-per-process form; pass all "
            "local forests to forest.repartition under SimComm")
        return repartition(
            [self], comm, None if weights is None else [weights],
            overlap=overlap)[0]


def _empty(d, num_trees, rank, num_ranks, cmesh=None) -> Forest:
    return Forest(
        d, num_trees, rank, num_ranks,
        np.zeros((0, d), np.int32), np.zeros(0, np.int32), np.zeros(0, np.int32),
        np.zeros(0, np.int32), np.zeros(0, np.uint64), cmesh,
    )


# ---------------------------------------------------------- element classes
# The element class is a per-TREE property of the cmesh (classes are unions
# of whole trees, and cross-class faces are domain boundaries), so a forest
# over a mixed mesh splits into independent per-class groups: the collective
# drivers below run the existing single-class pipeline once per class (in
# the deterministic sorted class order, so all ranks agree) and merge the
# per-rank results back into stored (tree, key) order.  Single-class meshes
# — every pre-existing caller — take the direct path, dispatch for dispatch.


def _forest_classes(forests) -> tuple:
    f = forests[0] if isinstance(forests, (list, tuple)) else forests
    return (ECLASS_SIMPLEX,) if f.cmesh is None else f.cmesh.eclasses


def _class_groups(f: Forest):
    """[(eclass, local element indices)] for the classes locally present,
    in ascending class order."""
    ecs = _forest_classes(f)
    if len(ecs) == 1:
        return [(ecs[0], np.arange(f.num_local, dtype=np.int64))]
    te = f.cmesh.tree_eclass[f.tree]
    return [(ec, np.nonzero(te == ec)[0].astype(np.int64))
            for ec in ecs if (te == ec).any()] or [
        (ECLASS_SIMPLEX, np.arange(0, dtype=np.int64))]


def _subforest(f: Forest, idx: np.ndarray) -> Forest:
    """The forest restricted to local elements `idx` (same cmesh / ranks /
    tree ids — only the leaf arrays shrink).  Derived caches do not carry
    over: dataclasses.replace builds a fresh object."""
    return dataclasses.replace(
        f, anchor=f.anchor[idx], level=f.level[idx], stype=f.stype[idx],
        tree=f.tree[idx], keys=f.keys[idx])


def _class_subforests(forests, ec: int):
    cm = forests[0].cmesh
    return [_subforest(f, np.nonzero(cm.tree_eclass[f.tree] == ec)[0])
            for f in forests]


def _merge_class_groups(base: Forest, parts) -> Forest:
    """Concatenate per-class forests back into one rank forest in stored
    (tree, key) lex order.  Keys are already correct per part — no key
    dispatch needed."""
    tree = np.concatenate([p.tree for p in parts])
    keys = np.concatenate([p.keys for p in parts])
    order = np.lexsort((keys, tree))
    return dataclasses.replace(
        base,
        anchor=np.concatenate([p.anchor for p in parts])[order],
        level=np.concatenate([p.level for p in parts])[order],
        stype=np.concatenate([p.stype for p in parts])[order],
        tree=tree[order], keys=keys[order])


def _layer_eclass(f: Forest, tree_ids) -> int:
    """Element class of a layer batch (all its trees must share one — the
    per-class drivers guarantee it)."""
    ecs = _forest_classes(f)
    if len(ecs) == 1:
        return ecs[0]
    tid = np.asarray(tree_ids)
    if tid.size == 0:
        return ECLASS_SIMPLEX
    present = np.unique(f.cmesh.tree_eclass[tid])
    if len(present) > 1:
        raise ValueError("face_sweep_layer needs a single-class element layer")
    return int(present[0])


# ---------------------------------------------------------------------- new
def new_uniform(d: int, num_trees: int, level: int, comm: Comm,
                method: str = "decode", cmesh: Cmesh | None = None) -> list[Forest]:
    """Paper Algorithm 5.1 (New): partitioned uniform level-`level` forest.

    Returns one `Forest` per rank resident in this process (all P under
    `SimComm`, one under `DistComm`).  With `cmesh`, the trees are glued per
    its face tables and the forest's Balance/Ghost/Iterate follow neighbors
    across tree faces."""
    return [
        new_uniform_rank(d, num_trees, level, p, comm.size, method=method, cmesh=cmesh)
        for p in comm.local_ranks
    ]


def new_uniform_rank(d: int, num_trees: int, level: int, rank: int, num_ranks: int,
                     method: str = "decode", cmesh: Cmesh | None = None) -> Forest:
    """One rank's portion of a uniform refinement — communication free.

    method="decode":    vectorized Algorithm 4.8 over the index range (O(n L)
                        work but a single fused gather pipeline; the default).
    method="successor": first element via Algorithm 4.8, remainder via the
                        level-independent batch expansion (paper's New uses
                        Successor to achieve O(n); our batch analogue expands
                        whole subtrees level by level, also O(n) total work).
    """
    if cmesh is not None:
        assert cmesh.d == d and cmesh.num_trees == num_trees, (
            f"cmesh ({cmesh.d}D, {cmesh.num_trees} trees) does not match "
            f"forest ({d}D, {num_trees} trees)"
        )
    o = get_ops(d)
    # nc = 2^d for BOTH element classes, so n_per_tree and the partition
    # arithmetic are class-independent; only the per-tree decode below
    # dispatches on the tree's class.
    n_per_tree = o.num_elements(level)
    N = n_per_tree * num_trees
    g_first = (N * rank) // num_ranks
    g_last = (N * (rank + 1)) // num_ranks  # exclusive
    f = _empty(d, num_trees, rank, num_ranks, cmesh)
    if g_last <= g_first:
        return f

    trees = np.arange(g_first // n_per_tree, (g_last - 1) // n_per_tree + 1)
    anchors, levels, stypes, tree_ids = [], [], [], []
    for t in trees:
        ec = ECLASS_SIMPLEX if cmesh is None else cmesh.eclass_of(int(t))
        o_t = get_ops(d, ec)
        e_first = g_first - t * n_per_tree if t == trees[0] else 0
        e_last = g_last - t * n_per_tree if t == trees[-1] else n_per_tree
        ids = np.arange(e_first, e_last, dtype=np.uint64)
        if method == "decode":
            keys = ids << np.uint64(o_t.d * (o_t.L - level))
            s = get_batch_ops(d, eclass=ec).decode(
                u64m.from_int(keys), jnp.full(len(ids), level, jnp.int32)
            )
        elif method == "successor":
            s = _range_by_expansion(o_t, int(e_first), int(e_last), level)
        else:
            raise ValueError(method)
        anchors.append(np.asarray(s.anchor))
        levels.append(np.asarray(s.level))
        stypes.append(np.asarray(s.stype))
        tree_ids.append(np.full(len(ids), t, np.int32))
    return f.replace_elements(
        np.concatenate(anchors), np.concatenate(levels),
        np.concatenate(stypes), np.concatenate(tree_ids),
    )


def _range_by_expansion(o: ElementOps, e_first: int, e_last: int, level: int) -> Simplex:
    """Create the SFC range [e_first, e_last) at `level` with O(n) total work.

    Level-independent per element: start from the coarsest subtree roots that
    tile the range and expand children level by level (geometric series).
    This is the vectorized counterpart of the paper's Successor-based New.
    """
    nc = o.nc
    # Coarsest covering: walk levels, at each level emit subtrees fully inside
    # the remaining range.
    roots = []  # (id, lvl)
    lo, hi = e_first, e_last
    for lv in range(level + 1):
        span = nc ** (level - lv)
        lo_aligned = (lo + span - 1) // span * span
        hi_aligned = hi // span * span
        if lo_aligned > hi_aligned:
            continue
        # emit subtrees of this level covering [lo_aligned, hi_aligned) that
        # are NOT covered by a coarser subtree already emitted
        if not roots:
            ids = np.arange(lo_aligned // span, hi_aligned // span, dtype=np.uint64)
            if len(ids):
                roots.append((ids, lv))
                lo2, hi2 = lo_aligned, hi_aligned
        else:
            break
    if not roots:  # range shorter than one finest element span
        ids = np.arange(lo, hi, dtype=np.uint64)
        s = o.from_linear_id(u64m.from_int(ids), jnp.full(len(ids), level, jnp.int32))
        return s
    ids, lv = roots[0]
    head = np.arange(lo, lo2, dtype=np.uint64)
    tail = np.arange(hi2, hi, dtype=np.uint64)
    mid = o.from_linear_id(u64m.from_int(ids), jnp.full(len(ids), lv, jnp.int32))
    while lv < level:
        kids = o.children_tm(mid)
        mid = Simplex(
            kids.anchor.reshape(-1, o.d), kids.level.reshape(-1), kids.stype.reshape(-1)
        )
        lv += 1
    parts = []
    if len(head):
        parts.append(o.from_linear_id(u64m.from_int(head), jnp.full(len(head), level, jnp.int32)))
    parts.append(mid)
    if len(tail):
        parts.append(o.from_linear_id(u64m.from_int(tail), jnp.full(len(tail), level, jnp.int32)))
    return Simplex(
        jnp.concatenate([p.anchor for p in parts]),
        jnp.concatenate([p.level for p in parts]),
        jnp.concatenate([p.stype for p in parts]),
    )


# -------------------------------------------------------------------- adapt
AdaptCallback = Callable[[np.ndarray, Simplex], np.ndarray]
# callback(tree_ids, elements) -> int flags: >0 refine, 0 keep, <0 coarsen.


def _family_heads(f: Forest) -> np.ndarray:
    """Boolean mask: element i starts a complete family of 2^d siblings.

    One batched parent/local-index/key sweep over all local elements."""
    b, n, nc = f.bops, f.num_local, f.ops.nc
    heads = np.zeros(n, bool)
    if n < nc:
        return heads
    s = f.simplices()
    parent, iloc = b.parent_and_local_index(s)
    iloc = np.asarray(iloc)
    pkey = b.morton_key_np(parent)
    cand = np.nonzero((iloc[: n - nc + 1] == 0) & (f.level[: n - nc + 1] > 0))[0]
    ok = np.ones(len(cand), bool)
    for k in range(1, nc):
        ok &= (
            (iloc[cand + k] == k)
            & (pkey[cand + k] == pkey[cand])
            & (f.level[cand + k] == f.level[cand])
            & (f.tree[cand + k] == f.tree[cand])
        )
    heads[cand[ok]] = True
    return heads


def adapt(f: Forest, callback: AdaptCallback, recursive: bool = False,
          max_passes: int = 64) -> Forest:
    """Paper Section 5.2 (Adapt): refine/coarsen local elements by callback.

    Honors the paper's recursion assumptions: elements created by refinement
    are not coarsened within the same call, and vice versa.
    Note: like the paper's Adapt, this is process-local; families straddling
    a partition boundary are not coarsened (call `partition` first if needed).

    On a mixed-class mesh the leaves are grouped by tree element class and
    adapted per class group (the callback sees each group's (tree_ids,
    elements) separately); sibling families never straddle classes because
    classes are unions of whole trees.
    """
    groups = _class_groups(f)
    if len(groups) > 1:
        parts = [_adapt_impl(_subforest(f, idx), callback, recursive, max_passes)
                 for _, idx in groups]
        return _merge_class_groups(f, parts)
    return _adapt_impl(f, callback, recursive, max_passes)


def _adapt_impl(f: Forest, callback: AdaptCallback, recursive: bool,
                max_passes: int) -> Forest:
    o = f.ops
    nc = o.nc
    bops = f.bops
    refined_origin = np.zeros(f.num_local, bool)   # created by refine this call
    coarsened_origin = np.zeros(f.num_local, bool)
    for _ in range(max_passes):
        n = f.num_local
        if n == 0:
            return f
        s = f.simplices()
        flags = np.asarray(callback(f.tree, s)).astype(np.int32)
        assert flags.shape == (n,)
        # never coarsen refine-children / never refine coarsen-parents
        flags = np.where(refined_origin & (flags < 0), 0, flags)
        flags = np.where(coarsened_origin & (flags > 0), 0, flags)
        heads = _family_heads(f)
        coarsen_head = heads.copy()
        for k in range(nc):
            idx = np.nonzero(heads)[0] + k
            coarsen_head[np.nonzero(heads)[0]] &= flags[idx] < 0
        # members of a coarsened family
        member = np.zeros(n, bool)
        hidx = np.nonzero(coarsen_head)[0]
        for k in range(nc):
            member[hidx + k] = True
        refine = (flags > 0) & ~member & (f.level < o.L)
        if not refine.any() and not coarsen_head.any():
            break
        keep = ~refine & ~member

        out_anchor, out_level, out_stype, out_tree = [], [], [], []
        origin_r, origin_c = [], []
        # sizes: keep->1, refine->nc, family head->1 (others 0)
        counts = keep.astype(np.int64) + refine.astype(np.int64) * nc + coarsen_head.astype(np.int64)
        total = int(counts.sum())
        offs = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=offs[1:])
        A = np.zeros((total, o.d), np.int32)
        L = np.zeros(total, np.int32)
        B = np.zeros(total, np.int32)
        T = np.zeros(total, np.int32)
        OR = np.zeros(total, bool)
        OC = np.zeros(total, bool)
        # keeps
        kidx = np.nonzero(keep)[0]
        A[offs[kidx]] = f.anchor[kidx]
        L[offs[kidx]] = f.level[kidx]
        B[offs[kidx]] = f.stype[kidx]
        T[offs[kidx]] = f.tree[kidx]
        OR[offs[kidx]] = refined_origin[kidx]
        OC[offs[kidx]] = coarsened_origin[kidx]
        # refines
        ridx = np.nonzero(refine)[0]
        if len(ridx):
            rs = Simplex(jnp.asarray(f.anchor[ridx]), jnp.asarray(f.level[ridx]),
                         jnp.asarray(f.stype[ridx]))
            kids = bops.children(rs)
            ka = np.asarray(kids.anchor)      # (m, nc, d)
            kl = np.asarray(kids.level)
            kb = np.asarray(kids.stype)
            pos = offs[ridx][:, None] + np.arange(nc)[None, :]
            A[pos.reshape(-1)] = ka.reshape(-1, o.d)
            L[pos.reshape(-1)] = kl.reshape(-1)
            B[pos.reshape(-1)] = kb.reshape(-1)
            T[pos.reshape(-1)] = np.repeat(f.tree[ridx], nc)
            OR[pos.reshape(-1)] = True
        # coarsens
        if len(hidx):
            hs = Simplex(jnp.asarray(f.anchor[hidx]), jnp.asarray(f.level[hidx]),
                         jnp.asarray(f.stype[hidx]))
            par = bops.parent(hs)
            A[offs[hidx]] = np.asarray(par.anchor)
            L[offs[hidx]] = np.asarray(par.level)
            B[offs[hidx]] = np.asarray(par.stype)
            T[offs[hidx]] = f.tree[hidx]
            OC[offs[hidx]] = True
        f = f.replace_elements(A, L, B, T)
        refined_origin, coarsened_origin = OR, OC
        if not recursive:
            break
    return f


# ---------------------------------------------------------------- partition
# Resilience hooks: callables `hook(event, forests, comm)` fired at the
# entry and exit of the phase-changing drivers — events "balance:begin"/
# "balance:end", "repartition:begin"/"repartition:end" (and "partition:*"
# via the construction-time wrapper).  `repro.core.resilience.Autosaver`
# installs here to checkpoint the pre-phase state, so a rank crash inside
# a collective always has a consistent snapshot behind it.  Hooks fire
# OUTSIDE the phase's comm context: checkpoint traffic meters under its
# own phase, never polluting balance/repartition byte attribution.
RESILIENCE_HOOKS: list = []


def _fire_hooks(event: str, forests: list, comm: Comm) -> None:
    for hook in list(RESILIENCE_HOOKS):
        hook(event, forests, comm)


def partition(forests: list[Forest], comm: Comm,
              weights: list[np.ndarray] | None = None) -> list[Forest]:
    """Paper Section 5 (Partition): weighted SFC repartitioning, linear time.

    A thin wrapper over `repartition` — the packed-wire migration engine —
    kept for the construction-time call sites and metered under its own
    "partition" phase."""
    return repartition(forests, comm, weights=weights, _phase="partition")


def repartition(forests: list[Forest], comm: Comm,
                weights: list[np.ndarray] | None = None,
                overlap: bool = True, _phase: str = "repartition") -> list[Forest]:
    """Dynamic repartition with element migration (see `_repartition_impl`
    for the algorithm); fires the `RESILIENCE_HOOKS` begin/end events
    around the migration."""
    _fire_hooks(f"{_phase}:begin", forests, comm)
    out = _repartition_impl(forests, comm, weights=weights,
                            overlap=overlap, _phase=_phase)
    _fire_hooks(f"{_phase}:end", out, comm)
    return out


def _repartition_impl(forests: list[Forest], comm: Comm,
                      weights: list[np.ndarray] | None = None,
                      overlap: bool = True,
                      _phase: str = "repartition") -> list[Forest]:
    """Dynamic repartition with element migration — the post-adapt rebalance
    step (Holke's dissertation; p4est's `p4est_partition` between refine and
    balance).

    Every rank derives the paper's weighted Partition targets from the
    GLOBAL weight prefix sums (`placement.target_ranks_np`: midpoint rule,
    monotone), so the targets are ascending and each destination's elements
    form one contiguous run of the local SFC order.  Migrating runs ship as
    the Remark-20 wire triples (`types.pack_wire`, 13 bytes/element — the
    same blobs Balance/Ghost move) in STORED order, over one nonblocking
    `ialltoallv`; receivers recover (anchor, stype) with a single batched
    Algorithm-4.8 `decode`.  The collectives are double buffered the same
    way `balance()` hides its flights: the weight-total allgather flies
    while the local midpoint prefix sums compute, and the migration
    alltoallv flies while the kept slice is assembled (`overlap=False`
    completes each collective at its post site — bit-identical, benchmark
    baseline).

    Merging needs no sort: old ranks own ascending contiguous global
    intervals, so sender p's contribution precedes sender p+1's, and the
    kept slice slots in at p == rank.  The stored SFC order of every output
    forest is revalidated (strictly ascending (tree, key)) before return.

    Returns NEW `Forest` objects — derived structures (ghost layers, face
    sweeps, partition markers) refer to the old ownership and must be
    recomputed from the result; the weight list, when given, is one
    nonnegative float per LOCAL element in stored order.
    """
    P = comm.size
    nloc = len(forests)
    d = forests[0].d
    cm = forests[0].cmesh
    classes = _forest_classes(forests)
    if weights is None:
        weights = [np.ones(f.num_local, np.float64) for f in forests]
    weights = [np.asarray(w, np.float64) for w in weights]
    for f, w in zip(forests, weights):
        if w.shape != (f.num_local,):
            raise ValueError(
                f"need one weight per local element: {w.shape} vs "
                f"{f.num_local} elements")
        if len(w) and float(w.min()) < 0:
            raise ValueError("element weights must be nonnegative")

    def post(h: CommHandle) -> CommHandle:
        return h if overlap else CommHandle.ready(h.wait())

    with comm.phase(_phase):
        # the weight-total allgather flies while every local rank computes
        # its midpoint prefix sums (the overlap window of merge point 1)
        h_tot = post(comm.iallgather([float(w.sum()) for w in weights]))
        cums = [np.cumsum(w) - w / 2.0 for w in weights]
        tots = h_tot.wait()
        prefix = np.concatenate([[0.0], np.cumsum(tots)])
        W = float(prefix[-1])
        send, keep_off = [], []
        for i, f in enumerate(forests):
            g = comm.local_ranks[i]
            t = target_ranks_np(prefix[g] + cums[i], P, W)
            # monotone targets => destination q's elements are the stored
            # run [offs[q], offs[q+1]) — found by searchsorted, no masks
            offs = np.searchsorted(t, np.arange(P + 1))
            row = [np.zeros(0, np.uint8)] * P
            for q in range(P):
                a, b = int(offs[q]), int(offs[q + 1])
                if q != g and b > a:
                    # stored order IS SFC order: pack without sorting; the
                    # wire triples carry each element's tree class in the
                    # level byte's class bits (zeros — byte-identical to the
                    # legacy format — on a single-class simplex mesh)
                    ec_col = (0 if cm is None
                              else cm.tree_eclass[f.tree[a:b]])
                    row[q] = pack_wire(f.tree[a:b], f.keys[a:b],
                                       f.level[a:b], eclass=ec_col)
            keep_off.append((int(offs[g]), int(offs[g + 1])))
            send.append(row)
        h_mig = post(comm.ialltoallv(send))
        # overlap window of merge point 2: slice out the kept runs while
        # the migration blobs are on the wire
        kept = []
        for i, f in enumerate(forests):
            a, b = keep_off[i]
            kept.append((f.anchor[a:b], f.level[a:b], f.stype[a:b],
                         f.tree[a:b]))
        recv = h_mig.wait()
    out = []
    for i, f in enumerate(forests):
        g = comm.local_ranks[i]
        segs = []  # (src rank, tree, key, level) in ascending sender order
        for p in range(P):
            buf = recv[i][p] if p != g else None
            if buf is not None and len(buf):
                segs.append((p, *unpack_wire(buf)))
        if segs:
            rt = np.concatenate([s[1] for s in segs])
            rk = np.concatenate([s[2] for s in segs])
            rl = np.concatenate([s[3] for s in segs])
            # ONE batched Algorithm-4.8 decode per element class recovers
            # (anchor, stype) for everything this rank received, across all
            # senders (single-class meshes: exactly one dispatch, as before)
            if len(classes) == 1:
                dec = get_batch_ops(d, eclass=classes[0]).decode(
                    u64m.from_int(rk), jnp.asarray(rl, jnp.int32))
                ra, rs = np.asarray(dec.anchor), np.asarray(dec.stype)
            else:
                te = cm.tree_eclass[rt]
                ra = np.zeros((len(rt), d), np.int32)
                rs = np.zeros(len(rt), np.int32)
                for ec in classes:
                    m = te == ec
                    if m.any():
                        dec = get_batch_ops(d, eclass=ec).decode(
                            u64m.from_int(rk[m]), jnp.asarray(rl[m], jnp.int32))
                        ra[m] = np.asarray(dec.anchor)
                        rs[m] = np.asarray(dec.stype)
        # each sender's run is SFC-contiguous and senders cover ascending
        # global intervals, so concatenating in sender order (the kept
        # slice at p == g) restores the stored order without a sort
        blocks, pos, si = [], 0, 0
        for p in range(P):
            if p == g:
                blocks.append(kept[i])
            elif si < len(segs) and segs[si][0] == p:
                n = len(segs[si][3])
                blocks.append((ra[pos:pos + n], rl[pos:pos + n],
                               rs[pos:pos + n], rt[pos:pos + n]))
                pos += n
                si += 1
        f2 = f.replace_elements(
            np.concatenate([b[0] for b in blocks]),
            np.concatenate([b[1] for b in blocks]),
            np.concatenate([b[2] for b in blocks]),
            np.concatenate([b[3] for b in blocks]))
        # stored-order revalidation: migration must hand every rank one
        # strictly ascending (tree, key) run
        tt = f2.tree.astype(np.int64)
        ok = (tt[1:] > tt[:-1]) | ((tt[1:] == tt[:-1])
                                   & (f2.keys[1:] > f2.keys[:-1]))
        if not bool(ok.all()):
            raise RuntimeError(
                f"repartition broke stored SFC order on rank {g}")
        out.append(f2)
    return out


def load_imbalance(forests: list[Forest], comm: Comm,
                   weights: list[np.ndarray] | None = None) -> float:
    """max rank load / mean rank load over the world (1.0 = perfect), with
    unit weights (element counts) by default — the quantity `repartition`
    drives toward 1 and the acceptance gate the benchmarks record."""
    if weights is None:
        weights = [np.ones(f.num_local, np.float64) for f in forests]
    loads = np.asarray(
        comm.allgather([float(np.sum(w)) for w in weights]), np.float64)
    return float(loads.max() / max(float(loads.mean()), 1e-300))


def _marker_pairs(forests: list[Forest]) -> list:
    """Per local rank, the (tree, key) of its first element — the payload of
    the marker allgather (split out so `balance` can post it nonblocking)."""
    return [tuple(map(int, f.global_first_desc_key())) for f in forests]


def _markers_from_pairs(K: int, P: int, pairs) -> tuple[np.ndarray, np.ndarray]:
    """Allgathered first-element pairs -> the lex-sorted marker table.
    Empty ranks inherit the next non-empty rank's marker (trailing empties
    keep the (num_trees, 0) sentinel), so runs of duplicates route keys to
    the LAST duplicate — the non-empty rank (`owner_rank` resolves to the
    last marker lex-<= the key).  Monotonicity is a correctness invariant
    of every downstream searchsorted, so it is checked, not assumed."""
    mt = np.empty(P, np.int32)
    mk = np.empty(P, np.uint64)
    nxt = (K, 0)
    for r in range(P - 1, -1, -1):
        t, k = pairs[r]
        if t >= K:  # empty rank: route to the next non-empty range
            t, k = nxt
        mt[r], mk[r] = t, np.uint64(k)
        nxt = (t, k)
    lex = list(zip(mt.tolist(), mk.tolist()))
    if lex != sorted(lex):
        raise RuntimeError(
            f"partition markers are not lex-sorted: {lex} — the rank "
            "first-element keys disagree with the stored SFC order")
    return mt, mk


def partition_markers(forests: list[Forest], comm: Comm):
    """Allgather the partition-marker table: per rank the (tree, key) of its
    first local element (`global_first_desc_key`).  Empty ranks inherit the
    next non-empty rank's marker (trailing empties keep the (num_trees, 0)
    sentinel), so the table is lex-sorted and `owner_rank` — a vectorized
    searchsorted on the batch backends — resolves any (tree, key) to the
    rank whose contiguous SFC range holds it.  This P-entry exchange is the
    ONLY global metadata Balance/Ghost need: everything else travels as
    boundary-local key-range messages."""
    K = forests[0].num_trees
    pairs = comm.allgather(_marker_pairs(forests))
    return _markers_from_pairs(K, comm.size, pairs)


# ------------------------------------------------------- cross-tree lookups
FACE_INTERIOR = 0          # neighbor in the same tree
FACE_INTER_TREE = 1        # neighbor across a glued tree face (via Cmesh)
FACE_DOMAIN_BOUNDARY = 2   # no neighbor: true domain boundary


@dataclasses.dataclass
class FaceSweepLayer:
    """Host-side result of ONE fused `face_sweep` dispatch over an element
    layer, with the cross-tree fixup already applied: for every face of
    every element, where its neighbor region lives.  Arrays carry a leading
    face axis of length nf (d+1 for simplices, 2d for hexes); `level` is
    shared (same-level neighbors).

      tgt     (nf, n) tree whose leaf table holds the neighbor region
      nkey    (nf, n) uint64 neighbor morton key *in that tree's frame*
              (garbage where ~valid — never read it there)
      valid   (nf, n) False at the domain boundary
      anchor  (nf, n, d) / stype (nf, n): the neighbor, re-expressed in the
              target tree's frame where the face crosses into another tree
      dual    (nf, n) neighbor's face index back to us, renumbered through
              the connection's face map for cross-tree faces
      kind    (nf, n) FACE_INTERIOR / FACE_INTER_TREE / FACE_DOMAIN_BOUNDARY

    The Balance/Ghost/Iterate hot loops compute one sweep per eval layer and
    slice per-face views from it (`face`), instead of re-dispatching
    face_neighbor + is_inside_root + morton_key for every face."""

    tgt: np.ndarray
    nkey: np.ndarray
    valid: np.ndarray
    anchor: np.ndarray
    level: np.ndarray
    stype: np.ndarray
    dual: np.ndarray
    kind: np.ndarray

    def face(self, f: int):
        """The (tgt, nkey, valid, nb, dual, kind) view of one face — what the
        per-face `_face_lookup` used to return."""
        nb = Simplex(
            jnp.asarray(self.anchor[f]), jnp.asarray(self.level),
            jnp.asarray(self.stype[f]),
        )
        return (self.tgt[f], self.nkey[f], self.valid[f], nb,
                self.dual[f], self.kind[f])


def face_sweep_layer(f: Forest, tree_ids: np.ndarray, s: Simplex) -> FaceSweepLayer:
    """Fused neighbor lookup for ALL faces of the elements in `s` (any subset
    of local elements; `tree_ids` is their owning-tree column — the
    boundary-only Balance rounds pass just the changed layer here).

    One batched `face_sweep` dispatch computes every face's same-level
    neighbor, inside-root mask, and morton key; the results are materialized
    to the host once.  Faces that leave the root are then re-expressed in the
    neighbor tree's frame via `f.cmesh`: every crossing gathers its
    connection's (M, c, type/face maps) rows and ALL crossings get one
    batched transform + key recompute — no per-connection Python loop.

    This is the single seam where the old is_root_boundary notion splits
    into "interior", "inter-tree face" (followed through `f.cmesh`), and
    "domain boundary" (no Cmesh connection).

    The layer must be single-class (the per-class drivers guarantee it);
    the class is derived from `tree_ids` and selects the fused sweep's
    (d, eclass)-keyed program — one dispatch per class per eval layer."""
    ec = _layer_eclass(f, tree_ids)
    bops = get_batch_ops(f.d, eclass=ec)
    d = f.d
    nf = bops.nf
    sw = bops.face_sweep(s)
    # one host materialization per field; all later bookkeeping is numpy
    anchor = np.asarray(sw.neighbor.anchor)
    stype = np.asarray(sw.neighbor.stype)
    level = np.asarray(s.level)
    inside = np.asarray(sw.inside)
    dual = np.asarray(sw.dual)
    nkey = u64m.to_np(sw.key)
    tree_ids = np.asarray(tree_ids)
    n = level.shape[0]
    tgt = np.broadcast_to(tree_ids, (nf, n)).copy()
    valid = inside.copy()
    kind = np.where(inside, FACE_INTERIOR, FACE_DOMAIN_BOUNDARY).astype(np.int32)
    cm = f.cmesh
    if cm is not None and not inside.all():
        anchor = anchor.copy()
        stype = stype.copy()
        dual = dual.copy()
        fidx, eidx = np.nonzero(~inside)
        s_anchor = np.asarray(s.anchor)
        s_stype = np.asarray(s.stype)
        src = Simplex(
            jnp.asarray(s_anchor[eidx]), jnp.asarray(level[eidx]),
            jnp.asarray(s_stype[eidx]),
        )
        rf = cm.root_face_of(src, fidx, eclass=ec)
        t1 = tree_ids[eidx]
        conn = (rf >= 0) & (cm.face_tree[t1, np.maximum(rf, 0)] >= 0)
        keep = np.nonzero(conn)[0]
        if len(keep):
            # ONE batched transform for ALL crossings, whatever connection
            # they use: gather each crossing's per-connection (M, c, maps)
            # rows and apply anchor' = M @ anchor + c (+ the reflected-axis
            # -h shift) in int64, wrapping to int32 once at the end — int32
            # ring arithmetic wraps mod 2^32, so the single final wrap is
            # bit-identical to the per-connection int32 path.
            fk, ek, rfk, t1k = fidx[keep], eidx[keep], rf[keep], t1[keep]
            Mv = cm.face_M[t1k, rfk].astype(np.int64)      # (c, d, d)
            cv = cm.face_c[t1k, rfk].astype(np.int64)      # (c, d)
            av = anchor[fk, ek].astype(np.int64)           # (c, d)
            h = np.int64(1) << (np.int64(cm.L) - level[ek].astype(np.int64))
            neg = np.minimum(Mv.sum(axis=-1), 0)           # -1 on reflected rows
            a2 = (av[:, None, :] * Mv).sum(axis=-1) + cv + h[:, None] * neg
            old_stype = stype[fk, ek]
            anchor[fk, ek] = wrap_i32(a2)
            stype[fk, ek] = cm.face_typemap[t1k, rfk, old_stype]
            dual[fk, ek] = cm.face_facemap[t1k, rfk, old_stype, dual[fk, ek]]
            tgt[fk, ek] = cm.face_tree[t1k, rfk]
            valid[fk, ek] = True
            kind[fk, ek] = FACE_INTER_TREE
            # only the crossed entries changed anchors: recompute just their
            # keys, in one batched call (the sweep's keys stand elsewhere)
            crossed = Simplex(
                jnp.asarray(anchor[fk, ek]), jnp.asarray(level[ek]),
                jnp.asarray(stype[fk, ek]),
            )
            nkey[fk, ek] = bops.morton_key_np(crossed)
    return FaceSweepLayer(tgt, nkey, valid, anchor, level, stype, dual, kind)


def _face_lookup(f: Forest, tree_ids: np.ndarray, s: Simplex, face: int):
    """Single-face view of `face_sweep_layer` (kept for callers that really
    want one face, e.g. `face_kind`); the hot loops slice the full sweep."""
    return face_sweep_layer(f, tree_ids, s).face(face)


def face_kinds(f: Forest, s: Simplex) -> np.ndarray:
    """Classify every face of every element in one fused sweep: (nf, n)
    matrix of FACE_INTERIOR (0) / FACE_INTER_TREE (1) /
    FACE_DOMAIN_BOUNDARY (2) — the split of the old single is-root-boundary
    test under the coarse mesh.  Prefer this over looping `face_kind` per
    face: the whole matrix costs one sweep dispatch."""
    return face_sweep_layer(f, f.tree, s).kind


def face_kind(f: Forest, s: Simplex, face: int) -> np.ndarray:
    """One face's row of `face_kinds`.  NOTE: every call runs the full
    all-faces sweep and keeps one row — never loop this over faces; call
    `face_kinds` once instead."""
    return face_kinds(f, s)[face]


# ------------------------------------------------------------------ balance
class BalanceNonConvergence(RuntimeError):
    """Balance hit `max_rounds` before reaching the 2:1 fixpoint.

    Carries the diagnostic context: `rounds` (how many refine/exchange
    rounds ran) and `dirty_per_rank` (per rank, how many local elements
    still violated the 2:1 condition when the budget ran out)."""

    def __init__(self, rounds: int, dirty_per_rank):
        self.rounds = rounds
        self.dirty_per_rank = [int(c) for c in dirty_per_rank]
        super().__init__(
            f"balance did not converge after {rounds} rounds; per-rank "
            f"still-dirty element counts: {self.dirty_per_rank}"
        )


def _elem_spans(d: int, L: int, level: np.ndarray) -> np.ndarray:
    """Key-interval width 2^(d*(L-level)) of each element, as uint64."""
    return np.uint64(1) << (np.uint64(d) * (np.uint64(L) - level.astype(np.uint64)))


def _range_max(values: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Per-slice max(values[lo:hi]) (or -1 for empty slices), vectorized via
    maximum.reduceat over independent [lo, hi) segment pairs."""
    out = np.full(len(lo), -1, np.int32)
    m = hi > lo
    if not m.any():
        return out
    ext = np.append(np.asarray(values, np.int32), np.int32(-1))  # allow hi == len
    idx = np.nonzero(m)[0]
    pairs = np.stack([lo[idx], hi[idx]], axis=1).reshape(-1)
    out[idx] = np.maximum.reduceat(ext, pairs)[::2]
    return out


def _resident_sweep(f: Forest, bops: BatchedOps):
    """The resident face sweep of ALL of a rank's local elements, memoized
    per (Forest object, backend, element class): leaf arrays are immutable,
    so repeated Balance rounds over an unchanged rank — and a Ghost
    following a Balance — reuse the device-resident sweep instead of
    re-padding and re-dispatching it.  A cache hit still charges one
    `face_sweep` dispatch so the meters keep their evals-per-round
    semantics."""
    if f.num_local == 0:
        return None
    cache = f.__dict__.setdefault("_sweep_cache", {})
    h = cache.get((bops.backend, bops.eclass))
    if h is not None:
        batch_count_dispatch("face_sweep")
        return h
    if f.cmesh is None:
        h = bops.sweep_full(f.simplices(), f.tree)
    else:
        sw = face_sweep_layer(f, f.tree, f.simplices())
        h = bops.sweep_from_host(sw.tgt, sw.nkey, sw.valid, sw.dual, sw.level)
    cache[(bops.backend, bops.eclass)] = h
    return h


def _pack_triples(triples, eclass: int = ECLASS_SIMPLEX) -> np.ndarray:
    """(tree, key, level) triples -> deterministic 13-byte/entry wire buffer,
    lex-ordered by (tree, key, level) via np.lexsort over the column arrays
    (bit-identical to sorting the Python tuples, without the tuple churn).
    The class-group exchanges tag every entry with the group's element
    class (zero — byte-identical to the legacy format — for simplices)."""
    tl = list(triples)
    if not tl:
        return np.zeros(0, np.uint8)
    t = np.array([x[0] for x in tl], np.int32)
    k = np.array([x[1] for x in tl], np.uint64)
    lv = np.array([x[2] for x in tl], np.int32)
    order = np.lexsort((lv, k, t))
    return pack_wire(t[order], k[order], lv[order], eclass=eclass)


def balance(forests: list[Forest], comm: Comm, max_rounds: int = 64,
            overlap: bool = True) -> list[Forest]:
    """2:1 balance across faces (see `_balance_impl` for the full ripple
    algorithm); fires the `RESILIENCE_HOOKS` begin/end events around it.

    On a mixed-class mesh the ripple runs once per element class (classes
    are unions of whole trees and cross-class faces are domain boundaries,
    so the class groups are independent); every rank iterates the classes
    in the same sorted order, and the per-rank results merge back into
    stored (tree, key) order.  Single-class meshes take the direct path —
    dispatch for dispatch the pre-eclass pipeline."""
    _fire_hooks("balance:begin", forests, comm)
    classes = _forest_classes(forests)
    if len(classes) == 1:
        out = _balance_impl(forests, comm, max_rounds=max_rounds,
                            overlap=overlap, eclass=classes[0])
    else:
        parts: list[list] = [[] for _ in forests]
        for ec in classes:
            res = _balance_impl(_class_subforests(forests, ec), comm,
                                max_rounds=max_rounds, overlap=overlap,
                                eclass=ec)
            for i, r in enumerate(res):
                parts[i].append(r)
        out = [_merge_class_groups(forests[i], ps)
               for i, ps in enumerate(parts)]
    _fire_hooks("balance:end", out, comm)
    return out


def _balance_impl(forests: list[Forest], comm: Comm, max_rounds: int = 64,
                  overlap: bool = True, eclass: int = ECLASS_SIMPLEX) -> list[Forest]:
    """2:1 balance across faces (ripple algorithm), across tree faces when
    the forest carries a Cmesh (intra-tree otherwise) — message based, with
    the boundary exchange overlapped behind interior compute.

    A leaf is refined when some face-neighbor key interval contains a leaf
    more than one level finer; neighbor regions behind a glued tree face are
    queried in the neighbor tree's frame.  No rank ever materializes the
    global leaf table: routing uses only the allgathered P partition markers
    (`partition_markers` + the fused `eval_route` owner-range program), and
    the wire carries

      * key-range queries — packed (tree, key, level) triples an element
        sends to every remote owner rank of its neighbor interval (issued
        once per element, when it is created);
      * replies — for each query whose local slice holds a leaf finer than
        the querier tolerates, one (tree, key, level) witness triple; and
      * boundary-layer notifications — after a refinement round, the NEW
        leaves are pushed only to the ranks whose registered query
        intervals they fall into (the Isaac-Burstedde-Ghattas ripple:
        each round exchanges only the boundary layer that changed).

    Received witnesses/notifications accumulate in a per-rank cache of
    remote leaves, so each round's refine decision is a purely local sweep
    (local sorted arrays + cache).

    The per-round evaluation is *device resident* on the jnp/pallas
    backends: the face sweep stays on device as a `SweepHandle`, the local
    leaves and the remote cache upload as `LeafTable`s, and three fused
    programs (`BatchedOps.eval_2to1` / `eval_cache` / `eval_route`) compute
    the 2:1 need-masks, the boundary-adjacent mask, and the compacted query
    candidates without materializing sweep fields to numpy — the host only
    slices the compacted routing rows to build wire triples.  All buffers
    are padded to power-of-two buckets so jit never retraces across rounds
    at a fixed bucket (`batch.trace_counts()`); the reference backend runs
    the same algorithms eagerly and is the bit-identical oracle.

    The round loop is *double buffered* (p4est-style overlap): round r's
    queries and notifications are posted nonblocking (`Comm.ialltoallv`) as
    soon as round r-1's refinement produced them, and the next round's
    fused face sweep runs while they are on the wire.  The first merge
    point waits them, answers the received queries, and immediately posts
    the replies — which then hide behind the interior 2:1 eval against the
    LOCAL sorted arrays (complete for every interior element, whose
    neighbor intervals lie inside this rank's marker range).  Only after
    the second merge point folds the replies do the boundary-adjacent
    elements finish against the refreshed remote-leaf cache; the
    convergence vote hides behind the refinement, and the initial marker
    allgather behind the first sweep (which double-duties as the initial
    query builder).  The split changes scheduling only: the refine
    decisions, the message bytes, and the least fixpoint are bit-identical
    to the serialized loop (`overlap=False` completes every collective at
    its post site — the benchmark baseline) and to `balance_oracle`,
    element for element.  Raises `BalanceNonConvergence` with per-rank
    diagnostics on round exhaustion.
    """
    if max_rounds < 1:
        raise ValueError("max_rounds must be >= 1")
    d = forests[0].d
    o = get_ops(d, eclass)
    L, nc = o.L, o.nc
    bops = get_batch_ops(d, eclass=eclass)
    P = comm.size
    nloc = len(forests)
    forests = list(forests)

    def post(h: CommHandle) -> CommHandle:
        # serialized mode: complete every collective where it was posted
        return h if overlap else CommHandle.ready(h.wait())

    with comm.phase("balance"):
        # markers are posted nonblocking; the first face sweep hides the wire
        K = forests[0].num_trees
        h_mk = post(comm.iallgather(_marker_pairs(forests)))
        mt = mk = None  # assigned at the marker merge point below
        # answering side: (tree, span_exp) -> {k0: (min queried level, ranks)}
        registries: list[dict] = [{} for _ in range(nloc)]
        # requesting side: remote leaves learned from replies/notifications,
        # recompiled into a lex-sorted LeafTable for the fused cache eval
        cache_entries: list[set] = [set() for _ in range(nloc)]
        cache_tables: list = [None] * nloc

        def recompile_cache(i: int) -> None:
            ents = cache_entries[i]
            if not ents:
                cache_tables[i] = None
                return
            t = np.fromiter((e[0] for e in ents), np.int32, len(ents))
            k = np.fromiter((e[1] for e in ents), np.uint64, len(ents))
            lv = np.fromiter((e[2] for e in ents), np.int32, len(ents))
            order = np.lexsort((lv, k, t))
            cache_tables[i] = bops.upload_table(t[order], k[order], lv[order])

        def sweep_handle(i: int, sel: np.ndarray | None = None):
            """The round's resident face sweep of rank i's elements (or the
            `sel` subset): ONE batched dispatch whose results stay where the
            backend computes — the fused eval programs consume the handle
            and only compacted routing rows return to the host.  The cmesh
            cross-tree path sweeps through `face_sweep_layer` (one host
            fixup) and re-uploads.  Full layers memoize on the Forest
            (`_resident_sweep`); subset layers are round-specific."""
            f = forests[i]
            if sel is None:
                return _resident_sweep(f, bops)
            if len(sel) == 0:
                return None
            s = Simplex(jnp.asarray(f.anchor[sel]), jnp.asarray(f.level[sel]),
                        jnp.asarray(f.stype[sel]))
            tree_ids = f.tree[sel]
            if f.cmesh is None:
                return bops.sweep_full(s, tree_ids)
            sw = face_sweep_layer(f, tree_ids, s)
            return bops.sweep_from_host(sw.tgt, sw.nkey, sw.valid, sw.dual,
                                        sw.level)

        def upload_tables() -> list:
            # leaf arrays are immutable per Forest: upload once per
            # (forest, backend) and reuse across rounds / repeated balances
            out = []
            for f in forests:
                if not f.num_local:
                    out.append(None)
                    continue
                cache = f.__dict__.setdefault("_leaf_table_cache", {})
                tbl = cache.get(bops.backend)
                if tbl is None:
                    tbl = bops.upload_table(f.tree, f.keys, f.level)
                    cache[bops.backend] = tbl
                out.append(tbl)
            return out

        def route_to_dests(i: int, rp) -> dict:
            """RoutePairs rows -> dest rank -> {(t, k0, l)} query sets."""
            g = comm.local_ranks[i]
            dest: dict[int, set] = {}
            for j in range(len(rp.tree)):
                q = (int(rp.tree[j]), int(rp.key[j]), int(rp.level[j]))
                for r in range(int(rp.first[j]), int(rp.last[j]) + 1):
                    if r != g:
                        dest.setdefault(r, set()).add(q)
            return dest

        def build_queries(i: int, sel: np.ndarray) -> dict:
            """Queries for an element subset (the per-round child layers):
            one fused sweep + the fused routing eval."""
            h = sweep_handle(i, sel)
            if h is None:
                return {}
            return route_to_dests(
                i, bops.eval_route(h, mt, mk, comm.local_ranks[i]))

        def answer(i: int, src: int, buf: np.ndarray) -> set:
            """Register one rank's queries and answer them from the local
            sorted arrays: witness triples for every query whose local slice
            holds a leaf finer than the querier tolerates.  The interval
            search is vectorized (grouped by target tree + one reduceat for
            the slice maxima); only the dict-shaped registry update and the
            few actual witnesses stay per entry."""
            f = forests[i]
            qt, qk, ql = unpack_wire(buf)
            reply: set = set()
            reg = registries[i]
            for t, k0, l in zip(qt.tolist(), qk.tolist(), ql.tolist()):
                ent = reg.setdefault((t, d * (L - l)), {})
                prev = ent.get(k0)
                ent[k0] = ((l, {src}) if prev is None
                           else (min(prev[0], l), prev[1] | {src}))
            span = _elem_spans(d, L, ql)
            starts = np.zeros(len(qt), np.int64)
            ends = np.zeros(len(qt), np.int64)
            for t in np.unique(qt):
                m = qt == t
                a0, b0 = np.searchsorted(f.tree, [t, t + 1])
                keys_t = f.keys[a0:b0]
                starts[m] = a0 + np.searchsorted(keys_t, qk[m])
                ends[m] = a0 + np.searchsorted(keys_t, qk[m] + span[m])
            mx = _range_max(f.level, starts, ends)
            for q in np.nonzero(mx > ql + 1)[0].tolist():
                a = int(starts[q])
                j = a + int(np.argmax(f.level[a:int(ends[q])]))
                reply.add((int(qt[q]), int(f.keys[j]), int(mx[q])))
            return reply

        def post_exchange(dests: list[dict], notifs: list[dict] | None) -> CommHandle:
            """Ship (notifications, queries) per destination — nonblocking;
            the next `eval_round` waits it at the round's first merge point."""
            send = []
            for i in range(nloc):
                row = []
                for q in range(P):
                    nt = notifs[i].get(q, ()) if notifs is not None else ()
                    row.append((_pack_triples(nt, eclass),
                                _pack_triples(dests[i].get(q, ()), eclass)))
                send.append(row)
            return comm.ialltoallv(send)

        def eval_round(pending: CommHandle, pre=None) -> list[np.ndarray]:
            """One double-buffered round evaluation.  Timeline:

              sweep faces + upload   <- the device sweep programs and the
                local leaf tables       round's leaf tables dispatch here
                                        and compute while the in-flight
                                        `pending` queries/notifications
                                        (posted at the END of the previous
                                        round) are on the wire
              merge 1: wait pending; answer queries; POST replies
              fold notifications; fused interior 2:1 eval (`eval_2to1`,
                local leaf table only) <- hides the in-flight replies
              merge 2: wait replies; fold; recompile caches
              fused boundary eval (`eval_cache`) against the refreshed
                remote-leaf cache

            The initial round passes the handles it already computed (they
            hid the marker allgather and built the first queries)."""
            if pre is None:
                handles = [sweep_handle(i) for i in range(nloc)]
                tables = upload_tables()
            else:
                handles, tables = pre
            recv = pending.wait()
            reply_rows, notif_bufs = [], []
            for i in range(nloc):
                g = comm.local_ranks[i]
                row = [np.zeros(0, np.uint8)] * P
                nbufs = []
                for p in range(P):
                    if p == g or recv[i][p] is None:
                        continue
                    nbuf, qbuf = recv[i][p]
                    if len(nbuf):
                        nbufs.append(nbuf)
                    if len(qbuf):
                        row[p] = _pack_triples(answer(i, p, qbuf), eclass)
                reply_rows.append(row)
                notif_bufs.append(nbufs)
            hr = post(comm.ialltoallv(reply_rows))
            # everything below merge 1 overlaps the reply flight: fold the
            # received notifications, then the interior (local-only) eval
            for i in range(nloc):
                for nbuf in notif_bufs[i]:
                    t_, k_, l_ = unpack_wire(nbuf)
                    cache_entries[i].update(
                        zip(t_.tolist(), k_.tolist(), l_.tolist()))
            needs = []
            for i in range(nloc):
                if handles[i] is None:
                    needs.append(np.zeros(forests[i].num_local, bool))
                else:
                    nd, _bm = bops.eval_2to1(
                        handles[i], tables[i], mt, mk, comm.local_ranks[i])
                    needs.append(nd)
            rrecv = hr.wait()
            for i in range(nloc):
                g = comm.local_ranks[i]
                for p in range(P):
                    buf = rrecv[i][p]
                    if p == g or buf is None or not len(buf):
                        continue
                    t_, k_, l_ = unpack_wire(buf)
                    cache_entries[i].update(zip(t_.tolist(), k_.tolist(), l_.tolist()))
                recompile_cache(i)
            for i in range(nloc):
                if handles[i] is not None and cache_tables[i] is not None:
                    needs[i] |= bops.eval_cache(
                        handles[i], cache_tables[i], mt, mk, comm.local_ranks[i])
            return needs

        def refine_and_build(needs: list[np.ndarray]):
            """Refine this round's violators and build the NEXT round's
            queries and notifications (runs while the convergence flag is
            on the wire)."""
            new_dests: list[dict] = [{} for _ in range(nloc)]
            new_notifs: list[dict] = [{} for _ in range(nloc)]
            for i in range(nloc):
                nd = needs[i]
                if not nd.any():
                    continue
                f = forests[i]
                # the changed boundary layer: all children created this round
                child_triples = []
                for e in np.nonzero(nd)[0].tolist():
                    t, k, l = int(f.tree[e]), int(f.keys[e]), int(f.level[e])
                    cspan = 1 << (d * (L - l - 1))
                    child_triples.extend(
                        (t, k + j * cspan, l + 1) for j in range(nc))
                flags = nd.astype(np.int32)
                f2 = adapt(f, lambda tree, elems, fl=flags: fl, recursive=False)
                forests[i] = f2
                # new children re-enter the protocol: locate them ...
                sel = []
                for (t, k, l) in child_triples:
                    gsel = np.searchsorted(f2.tree, [t, t + 1])
                    sel.append(gsel[0] + int(np.searchsorted(
                        f2.keys[gsel[0]:gsel[1]], np.uint64(k))))
                new_dests[i] = build_queries(i, np.asarray(sorted(sel), np.int64))
                # ... and are pushed to every rank whose registered query
                # interval they fall into (and whom they could make refine)
                reg = registries[i]
                if reg:
                    exps_by_tree: dict[int, list] = {}
                    for (t, se) in reg:
                        exps_by_tree.setdefault(t, []).append(se)
                    for (t, k, l) in child_triples:
                        for se in exps_by_tree.get(t, ()):
                            ent = reg[(t, se)].get((k >> se) << se)
                            if ent is not None and l > ent[0] + 1:
                                for r in ent[1]:
                                    new_notifs[i].setdefault(r, set()).add((t, k, l))
            return new_dests, new_notifs

        # initial round: the device sweeps + table uploads dispatch while
        # the marker allgather flies, then double-duty as both the first
        # query builder and the first eval layer; the initial halo (every
        # element registers + queries its remote intervals) is itself
        # posted nonblocking
        handles0 = [sweep_handle(i) for i in range(nloc)]
        tables0 = upload_tables()
        mt, mk = _markers_from_pairs(K, P, h_mk.wait())
        pending = post(post_exchange(
            [route_to_dests(i, bops.eval_route(handles0[i], mt, mk,
                                               comm.local_ranks[i]))
             if handles0[i] is not None else {}
             for i in range(nloc)], None))
        needs = eval_round(pending, (handles0, tables0))
        for _ in range(max_rounds):
            # post the convergence vote, then refine + build the next
            # round's messages while it is on the wire (a no-op when the
            # vote comes back all-clear: nothing was dirty anywhere)
            h_conv = post(comm.iallgather([int(nd.any()) for nd in needs]))
            new_dests, new_notifs = refine_and_build(needs)
            if not any(h_conv.wait()):
                return forests
            pending = post(post_exchange(new_dests, new_notifs))
            needs = eval_round(pending)
        # budget exhausted: the last eval (which completed the last round's
        # exchange) decides converged-on-last-round vs genuinely dirty
        counts = comm.allgather([int(nd.sum()) for nd in needs])
        if not any(counts):
            return forests
    raise BalanceNonConvergence(max_rounds, counts)


def balance_oracle(forests: list[Forest], comm: Comm,
                   max_rounds: int = 64) -> list[Forest]:
    """The seed's global-leaf-table Balance, retained as the test oracle and
    wire-volume baseline: every round allgathers the full (tree, key, level)
    leaf table of every rank.  The message-based `balance` must match its
    result element for element; the benchmarks record how far its per-round
    O(N) exchange exceeds the boundary-only path's.  Mixed-class meshes run
    once per class group, like `balance`."""
    classes = _forest_classes(forests)
    if len(classes) == 1:
        return _balance_oracle_impl(forests, comm, max_rounds)
    parts: list[list] = [[] for _ in forests]
    for ec in classes:
        res = _balance_oracle_impl(_class_subforests(forests, ec), comm,
                                   max_rounds)
        for i, r in enumerate(res):
            parts[i].append(r)
    return [_merge_class_groups(forests[i], ps)
            for i, ps in enumerate(parts)]


def _balance_oracle_impl(forests: list[Forest], comm: Comm,
                         max_rounds: int) -> list[Forest]:
    if max_rounds < 1:
        raise ValueError("max_rounds must be >= 1")
    d = forests[0].d
    o = get_ops(d)
    forests = list(forests)
    nloc = len(forests)
    with comm.phase("balance_oracle"):
        for rnd in range(max_rounds):
            # Global sorted (tree, key, level) table — the simulator shortcut.
            tables = comm.allgather(
                [(f.tree, f.keys, f.level) for f in forests])
            all_tree = np.concatenate([t[0] for t in tables])
            all_keys = np.concatenate([t[1] for t in tables])
            all_level = np.concatenate([t[2] for t in tables])
            order = np.lexsort((all_keys, all_tree))
            g_tree, g_keys, g_level = all_tree[order], all_keys[order], all_level[order]
            changed = False
            last_dirty = [0] * nloc
            for fi in range(nloc):
                f = forests[fi]
                if f.num_local == 0:
                    continue
                s = f.simplices()
                need = np.zeros(f.num_local, bool)
                span = _elem_spans(d, o.L, f.level)
                sweep = face_sweep_layer(f, f.tree, s)  # one dispatch, all faces
                for face in range(sweep.tgt.shape[0]):
                    tgt, nkey, valid = sweep.tgt[face], sweep.nkey[face], sweep.valid[face]
                    # per-target-tree slices of the global sorted leaf table
                    for t in np.unique(tgt[valid]):
                        sel = np.nonzero(valid & (tgt == t))[0]
                        gsel = slice(*np.searchsorted(g_tree, [t, t + 1]))
                        keys_t, level_t = g_keys[gsel], g_level[gsel]
                        lo_t = np.searchsorted(keys_t, nkey[sel], side="left")
                        hi_t = np.searchsorted(keys_t, nkey[sel] + span[sel], side="left")
                        # any leaf in the neighbor interval finer than level+1?
                        for i, (a, b) in enumerate(zip(lo_t, hi_t)):
                            if level_t[a:b].max(initial=-1) > f.level[sel[i]] + 1:
                                need[sel[i]] = True
                if need.any():
                    changed = True
                    last_dirty[fi] = int(need.sum())
                    flags = need.astype(np.int32)
                    forests[fi] = adapt(
                        f, lambda tree, elems, fl=flags: fl, recursive=False)
            if not any(comm.allgather([int(changed)] * nloc)):
                return forests
        # per-rank counts of the last round's violators — the ripple front
        # that was still moving when the budget ran out
        counts = comm.allgather(last_dirty)
    raise BalanceNonConvergence(max_rounds, counts)


# -------------------------------------------------------------------- ghost
def _empty_ghost(d: int) -> dict:
    return {"anchor": np.zeros((0, d), np.int32), "level": np.zeros(0, np.int32),
            "stype": np.zeros(0, np.int32), "tree": np.zeros(0, np.int32),
            "owner": np.zeros(0, np.int32)}


def _ghost_from_candidates(d: int, cmesh: Cmesh | None, cand: set) -> dict:
    """Sorted-deduped (tree, key, level, owner) candidates -> ghost arrays
    (anchors/types recovered by batch decode, Remark 20) — one decode
    dispatch per element class present among the candidate trees."""
    if not cand:
        return _empty_ghost(d)
    uniq = sorted(cand)
    trees = np.array([c[0] for c in uniq], np.int32)
    keys = np.array([c[1] for c in uniq], np.uint64)
    levels = np.array([c[2] for c in uniq], np.int32)
    owners = np.array([c[3] for c in uniq], np.int32)
    anchors = np.zeros((len(uniq), d), np.int32)
    stypes = np.zeros(len(uniq), np.int32)
    te = (np.zeros(len(uniq), np.int32) if cmesh is None
          else cmesh.tree_eclass[trees])
    for ec in np.unique(te):
        m = te == ec
        gs = get_batch_ops(d, eclass=int(ec)).decode(
            u64m.from_int(keys[m]), jnp.asarray(levels[m]))
        anchors[m] = np.asarray(gs.anchor)
        stypes[m] = np.asarray(gs.stype)
    return {"anchor": anchors, "level": levels,
            "stype": stypes, "tree": trees, "owner": owners}


def ghost(forests: list[Forest], comm: Comm, overlap: bool = True) -> list[dict]:
    """Face-ghost layer: for each rank, the remote leaves touching its
    elements across faces — following glued tree faces through the Cmesh
    when the forest carries one.  Returns per-local-rank dicts with ghost
    element arrays (in the *owning tree's* frame) and their owner ranks.

    On a mixed-class mesh the exchange runs once per element class (the
    class groups are independent: cross-class faces are domain boundaries)
    and the per-rank candidate sets union before assembly — the ghost dicts
    come out in one (tree, key, level, owner)-sorted block either way.

    Message based: each element's neighbor key interval is routed by the
    allgathered partition markers to its remote owner ranks as a packed
    (tree, key, level, dual-face) query; owners answer from their local
    sorted arrays — the plane filter runs on the *answering* side, which
    reconstructs the neighbor simplex by decoding the queried key (the wire
    stays 14 bytes per query, Remark 20) — and reply with the matching leaf
    triples.  No global leaf table is ever built (`ghost_oracle` keeps the
    old one for the tests).

    The routing pass is *device resident*: one fused face sweep per
    non-empty rank stays on the backend as a `SweepHandle` and the fused
    `BatchedOps.eval_route` program compacts the remote-reaching (face,
    element) pairs with their [first, last] owner-rank ranges — the host
    slices exactly ONE (count, rows) materialization per rank and packs
    wire quads from it.

    Both alltoallv stages are double buffered (`overlap=False` completes
    every collective at its post site — the serialized baseline): the
    marker allgather hides behind the device sweeps, and the query flight
    behind the answering-side prep (per-tree offsets into the local sorted
    arrays).  The reply flight has no independent work left to hide —
    assembly needs the payload — so it is waited where it is posted in
    both modes.  Scheduling only: payload bytes and the resulting ghost
    layers are bit-identical across overlap modes."""
    d = forests[0].d
    cm = forests[0].cmesh
    classes = _forest_classes(forests)
    if len(classes) == 1:
        cands = _ghost_impl(forests, comm, overlap, classes[0])
    else:
        cands = [set() for _ in forests]
        for ec in classes:
            res = _ghost_impl(_class_subforests(forests, ec), comm,
                              overlap, ec)
            for i, c in enumerate(res):
                cands[i] |= c
    return [_ghost_from_candidates(d, cm, c) for c in cands]


def _ghost_impl(forests: list[Forest], comm: Comm, overlap: bool,
                eclass: int) -> list[set]:
    """One class group's ghost exchange; returns per-local-rank candidate
    sets of (tree, key, level, owner) — see `ghost` for the algorithm."""
    d = forests[0].d
    o = get_ops(d, eclass)
    L = o.L
    bops = get_batch_ops(d, eclass=eclass)
    # face corner geometry of THIS class: the plane filter needs the dual
    # facet's corners (any d of them span the plane) and how many of a
    # touching leaf's corners must lie on it (d for a simplex, 2^(d-1) for
    # a hex — a whole facet either way)
    fci = np.asarray(o.face_corner_indices)
    cpf = fci.shape[1]
    P = comm.size
    nloc = len(forests)

    def post(h: CommHandle) -> CommHandle:
        # serialized mode: complete every collective where it was posted
        return h if overlap else CommHandle.ready(h.wait())

    with comm.phase("ghost"):
        # markers fly while the device routing sweeps dispatch
        K = forests[0].num_trees
        h_mk = post(comm.iallgather(_marker_pairs(forests)))
        handles = [_resident_sweep(f, bops) for f in forests]
        mt, mk = _markers_from_pairs(K, P, h_mk.wait())

        # ---- route queries: the fused eval compacts the remote-reaching
        # (face, element) pairs; the host only packs wire quads from them
        send = []
        for i in range(nloc):
            g = comm.local_ranks[i]
            dest: dict[int, set] = {}
            if handles[i] is not None:
                rp = bops.eval_route(handles[i], mt, mk, g)
                for j in range(len(rp.tree)):
                    q = (int(rp.tree[j]), int(rp.key[j]), int(rp.level[j]),
                         int(rp.dual[j]))
                    for r in range(int(rp.first[j]), int(rp.last[j]) + 1):
                        if r != g:
                            dest.setdefault(r, set()).add(q)
            row = []
            for q in range(P):
                qs = sorted(dest.get(q, ()))
                row.append(pack_wire(
                    np.array([x[0] for x in qs], np.int32),
                    np.array([x[1] for x in qs], np.uint64),
                    np.array([x[2] for x in qs], np.int32),
                    extra=np.array([x[3] for x in qs], np.int32),
                    eclass=eclass,
                ) if qs else np.zeros(0, np.uint8))
            send.append(row)
        h_q = post(comm.ialltoallv(send))
        # answering-side prep hides the query flight: per-tree offsets into
        # each rank's sorted leaf arrays replace per-query searchsorted
        tree_offs = [np.searchsorted(f.tree, np.arange(K + 1))
                     for f in forests]
        recv = h_q.wait()

        # ---- answer from the local sorted arrays
        reply_rows = []
        for i, f in enumerate(forests):
            g = comm.local_ranks[i]
            row = [np.zeros(0, np.uint8)] * P
            entries = []  # (src, tree, k0, level, dual)
            for p in range(P):
                buf = recv[i][p]
                if p == g or buf is None or not len(buf):
                    continue
                qt, qk, ql, qd = unpack_wire(buf, with_extra=True)
                entries.extend(
                    (p, t, k, l, du) for t, k, l, du in
                    zip(qt.tolist(), qk.tolist(), ql.tolist(), qd.tolist()))
            replies: dict[int, set] = {}
            if entries and f.num_local:
                offs = tree_offs[i]
                pend = []       # (entry idx, local leaf idx) same-or-finer
                pred_hits = []  # (entry idx, local leaf idx) coarser containing
                for ei, (p, t, k0, l, du) in enumerate(entries):
                    t0 = int(offs[t])
                    keys_t = f.keys[t0:int(offs[t + 1])]
                    span_q = np.uint64(1) << np.uint64(d * (L - l))
                    a = int(np.searchsorted(keys_t, np.uint64(k0)))
                    b = int(np.searchsorted(keys_t, np.uint64(k0) + span_q))
                    if b > a:
                        pend.extend((ei, t0 + j) for j in range(a, b))
                    elif a > 0:
                        # coarser containing leaf: dyadic nesting makes the
                        # interval globally empty, and the leaf lives on the
                        # owner rank of k0 — answer only there (owner via one
                        # numpy compare-sum on the marker table, no dispatch)
                        own = max(int(((mt < t) | ((mt == t) & (
                            mk <= np.uint64(k0)))).sum()) - 1, 0)
                        jj = t0 + a - 1
                        span_p = np.uint64(1) << np.uint64(d * (L - int(f.level[jj])))
                        if own == g and np.uint64(f.keys[jj]) + span_p > np.uint64(k0):
                            pred_hits.append((ei, jj))
                if pend:
                    # same-or-finer leaves must TOUCH the shared face: a
                    # whole facet's worth of their corners on the plane of
                    # the neighbor element's dual facet (the neighbor is
                    # decoded from the query key)
                    eis = sorted({ei for ei, _ in pend})
                    emap = {ei: k for k, ei in enumerate(eis)}
                    ent_k = np.array([entries[ei][2] for ei in eis], np.uint64)
                    ent_l = np.array([entries[ei][3] for ei in eis], np.int32)
                    nbs = bops.decode(u64m.from_int(ent_k), jnp.asarray(ent_l))
                    nbc = np.asarray(o.coordinates(nbs), np.int64)
                    js = sorted({j for _, j in pend})
                    jmap = {j: k for k, j in enumerate(js)}
                    jarr = np.asarray(js, np.int64)
                    cs = Simplex(jnp.asarray(f.anchor[jarr]),
                                 jnp.asarray(f.level[jarr]),
                                 jnp.asarray(f.stype[jarr]))
                    ccoords = np.asarray(o.coordinates(cs), np.int64)
                    planes: dict[int, tuple] = {}
                    for ei, j in pend:
                        if ei not in planes:
                            planes[ei] = face_plane(
                                nbc[emap[ei]][fci[int(entries[ei][4])][:d]])
                        nrm, rhs = planes[ei]
                        if (ccoords[jmap[j]] @ nrm == rhs).sum() == cpf:
                            replies.setdefault(entries[ei][0], set()).add(
                                (int(f.tree[j]), int(f.keys[j]), int(f.level[j])))
                for ei, j in pred_hits:
                    replies.setdefault(entries[ei][0], set()).add(
                        (int(f.tree[j]), int(f.keys[j]), int(f.level[j])))
            for p, rs in replies.items():
                row[p] = _pack_triples(rs, eclass)
            reply_rows.append(row)
        rrecv = post(comm.ialltoallv(reply_rows)).wait()

        # ---- collect candidates: replies from rank p are leaves owned by p
        out = []
        for i, f in enumerate(forests):
            g = comm.local_ranks[i]
            cand: set = set()
            for p in range(P):
                buf = rrecv[i][p]
                if p == g or buf is None or not len(buf):
                    continue
                t_, k_, l_ = unpack_wire(buf)
                cand.update((t, k, l, p) for t, k, l in
                            zip(t_.tolist(), k_.tolist(), l_.tolist()))
            out.append(cand)
        return out


def ghost_oracle(forests: list[Forest], comm: Comm) -> list[dict]:
    """The seed's global-leaf-table Ghost, retained as the test oracle and
    wire-volume baseline: allgathers every rank's full (tree, key, level)
    arrays and searches them directly.  The message-based `ghost` must
    produce identical ghost layers.  Mixed-class meshes run once per class
    group, like `ghost`."""
    d = forests[0].d
    cm = forests[0].cmesh
    classes = _forest_classes(forests)
    if len(classes) == 1:
        cands = _ghost_oracle_impl(forests, comm, classes[0])
    else:
        cands = [set() for _ in forests]
        for ec in classes:
            res = _ghost_oracle_impl(_class_subforests(forests, ec), comm, ec)
            for i, c in enumerate(res):
                cands[i] |= c
    return [_ghost_from_candidates(d, cm, c) for c in cands]


def _ghost_oracle_impl(forests: list[Forest], comm: Comm,
                       eclass: int) -> list[set]:
    d = forests[0].d
    o = get_ops(d, eclass)
    bops = get_batch_ops(d, eclass=eclass)
    fci = np.asarray(o.face_corner_indices)
    cpf = fci.shape[1]
    nloc = len(forests)
    with comm.phase("ghost_oracle"):
        tables = comm.allgather([(f.tree, f.keys, f.level) for f in forests])
    all_tree = np.concatenate([t[0] for t in tables])
    all_keys = np.concatenate([t[1] for t in tables])
    all_level = np.concatenate([t[2] for t in tables])
    all_owner = np.concatenate(
        [np.full(len(t[0]), p) for p, t in enumerate(tables)])
    order = np.lexsort((all_keys, all_tree))
    g_tree, g_keys, g_level, g_owner = (
        all_tree[order], all_keys[order], all_level[order], all_owner[order],
    )

    out = []
    for i in range(nloc):
        f = forests[i]
        p_me = comm.local_ranks[i]
        if f.num_local == 0:
            out.append(set())
            continue
        s = f.simplices()
        cand = []
        sweep = face_sweep_layer(f, f.tree, s)  # one dispatch, all faces
        for face in range(sweep.tgt.shape[0]):
            tgt, nkey, valid, nb, dual, _ = sweep.face(face)
            nbc = None  # (n, corners, d), computed only when candidates exist
            for t in np.unique(tgt[valid]):
                sel = np.nonzero(valid & (tgt == t))[0]
                gsel = slice(*np.searchsorted(g_tree, [t, t + 1]))
                keys_t, level_t, owner_t = g_keys[gsel], g_level[gsel], g_owner[gsel]
                span = _elem_spans(d, o.L, f.level[sel])
                lo = np.searchsorted(keys_t, nkey[sel], side="left")
                hi = np.searchsorted(keys_t, nkey[sel] + span, side="left")
                # same-or-finer leaves inside the neighbor region that TOUCH
                # the shared face: a descendant of the neighbor shares our
                # face iff a whole facet's worth of its corners (d for a
                # simplex, 2^(d-1) for a hex) lie on the shared face's plane
                # (inside the region, plane membership implies face overlap).
                # Collect candidates first, then decode their coordinates in
                # one batch — only boundary-interval leaves pay for geometry.
                pend = []
                for i2, (a, b) in enumerate(zip(lo, hi)):
                    for j in range(a, b):
                        if owner_t[j] != p_me:
                            pend.append((i2, j))
                if pend:
                    if nbc is None:
                        nbc = np.asarray(o.coordinates(nb), np.int64)
                    js = sorted({j for _, j in pend})
                    jmap = {j: k for k, j in enumerate(js)}
                    cs = bops.decode(
                        u64m.from_int(keys_t[js]), jnp.asarray(level_t[js])
                    )
                    ccoords = np.asarray(o.coordinates(cs), np.int64)
                    planes = {}
                    for i2, j in pend:
                        if i2 not in planes:
                            planes[i2] = face_plane(
                                nbc[sel[i2]][fci[int(dual[sel[i2]])][:d]]
                            )
                        nrm, rhs = planes[i2]
                        if (ccoords[jmap[j]] @ nrm == rhs).sum() == cpf:
                            cand.append((t, keys_t[j], level_t[j], owner_t[j]))
                # coarser leaf containing the neighbor: predecessor check
                pred = np.maximum(lo - 1, 0)
                for i2, pj in enumerate(pred):
                    if len(keys_t) == 0:
                        continue
                    span_pred = np.uint64(1) << (
                        np.uint64(d) * (np.uint64(o.L) - np.uint64(level_t[pj]))
                    )
                    if (keys_t[pj] <= nkey[sel][i2] < keys_t[pj] + span_pred
                            and owner_t[pj] != p_me and lo[i2] == hi[i2]):
                        cand.append((t, keys_t[pj], level_t[pj], owner_t[pj]))
        out.append({(int(t), int(k), int(l), int(w)) for t, k, l, w in cand})
    return out


# ------------------------------------------------------------------ iterate
def iterate(f: Forest, elem_fn=None, face_fn=None):
    """Paper's Iterate: run callbacks over local elements and interior local
    face pairs, including pairs straddling glued tree faces when the forest
    carries a Cmesh.

    Each pair row is (i, j, face_i, face_j).  Same-level pairs are delivered
    once (i < j in storage order); hanging faces are delivered once per fine
    sub-face as a (fine i, coarse j) pair, discovered from the fine side —
    the coarser leaf is found by walking the neighbor's ancestor keys (pure
    prefix masking), and face_j is the coarse facet containing the shared
    face.

    On a mixed-class mesh the pair discovery runs per element class (one
    fused sweep per class; cross-class faces are domain boundaries, so no
    pair straddles classes) and `face_fn` is called ONCE with all pairs,
    whose indices are in the forest's local element indexing throughout."""
    results = []
    if elem_fn is not None:
        results.append(elem_fn(f.tree, f.simplices()))
    if face_fn is not None:
        groups = _class_groups(f)
        if len(groups) == 1:
            pairs = _iterate_pairs(f, None, groups[0][0])
        else:
            pairs = []
            for ec, idx in groups:
                pairs.extend(_iterate_pairs(f, idx, ec))
        results.append(face_fn(f, np.array(pairs, np.int64).reshape(-1, 4)))
    return results


def _iterate_pairs(f: Forest, idx: np.ndarray | None, eclass: int) -> list:
    """Local face pairs of one class group (`idx` — None means all local
    elements), reported in the forest's local indexing."""
    o = get_ops(f.d, eclass)
    d, L = f.d, o.L
    fci = np.asarray(o.face_corner_indices)
    if idx is None:
        s = f.simplices()
        tree_ids = f.tree
        gid = np.arange(f.num_local, dtype=np.int64)
    else:
        s = Simplex(jnp.asarray(f.anchor[idx]), jnp.asarray(f.level[idx]),
                    jnp.asarray(f.stype[idx]))
        tree_ids = f.tree[idx]
        gid = np.asarray(idx, np.int64)
    # neighbors never leave the class (classes are unions of whole trees),
    # so the subset's own (tree, key, level) index resolves every lookup
    key_index = {}
    pos = {}  # local index -> subset row, for coordinate lookups
    for k, g in enumerate(gid.tolist()):
        key_index[(int(f.tree[g]), int(f.keys[g]), int(f.level[g]))] = g
        pos[g] = k
    own_coords = None  # lazy: only adapted meshes have hanging faces
    pairs = []
    sweep = face_sweep_layer(f, tree_ids, s)  # one dispatch per class
    for face in range(sweep.tgt.shape[0]):
        tgt, nkey, valid, nb, dual, _ = sweep.face(face)
        nlvl = np.asarray(nb.level)
        nbc = None
        for i in np.nonzero(valid)[0]:
            gi = int(gid[i])
            j = key_index.get((int(tgt[i]), int(nkey[i]), int(nlvl[i])))
            if j is not None:
                # same-level pairs are discovered from both sides: keep
                # one (self-pairs across periodic gluings keep face<dual)
                if gi < j or (gi == j and face < int(dual[i])):
                    pairs.append((gi, j, face, int(dual[i])))
                continue
            # hanging face: the neighbor region may be covered by one
            # COARSER leaf — its key is an ancestor prefix of nkey
            for lc in range(int(nlvl[i]) - 1, -1, -1):
                mkey = int(nkey[i]) & ~((1 << (d * (L - lc))) - 1)
                j = key_index.get((int(tgt[i]), mkey, lc))
                if j is None:
                    continue
                if nbc is None:
                    nbc = np.asarray(o.coordinates(nb), np.int64)
                if own_coords is None:
                    own_coords = np.asarray(o.coordinates(s), np.int64)
                shared = nbc[i][fci[int(dual[i])]]
                jc = own_coords[pos[j]]
                # the coarse facet whose plane contains the shared face
                for fc in range(o.nf):
                    nrm, rhs = face_plane(jc[fci[fc][:d]])
                    if (shared @ nrm == rhs).all():
                        pairs.append((gi, j, face, fc))
                        break
                else:
                    raise AssertionError("hanging face without coarse facet")
                break
    return pairs


# ----------------------------------------------------------------- validate
def validate(forests: list[Forest], ghosts: list[dict] | None = None) -> bool:
    """Forest invariants: *globally* ascending (tree, TM-index) leaf order in
    stored rank-major order (not merely sortable), leaves pairwise
    non-overlapping (no ancestor relations), all inside their root, complete
    volume coverage per tree — and, when `ghosts` is given, ghost-layer
    consistency: every ghost entry is an actual remote leaf on its claimed
    owner rank (including entries reached across glued tree faces)."""
    d = forests[0].d
    o = get_ops(d)
    all_tree = np.concatenate([f.tree for f in forests])
    all_keys = np.concatenate([f.keys for f in forests])
    all_level = np.concatenate([f.level for f in forests])
    # global (tree, key) order must hold as stored across ranks — the SFC
    # partition invariant the markers rely on
    t, k, l = all_tree, all_keys, all_level
    if len(t) > 1:
        same = t[1:] == t[:-1]
        if not np.all((t[1:] > t[:-1]) | same):
            return False
        if not np.all(k[1:][same] > k[:-1][same]):
            return False
        # non-overlap: successor key must be >= current key + span
        span = np.uint64(1) << (np.uint64(d) * (np.uint64(o.L) - l.astype(np.uint64)))
        if not np.all(k[1:][same] >= (k[:-1] + span[:-1])[same]):
            return False
    # inside root (per element class: the containment test is class-keyed)
    for f in forests:
        for ec, idx in _class_groups(f):
            if len(idx) == 0:
                continue
            sub = Simplex(jnp.asarray(f.anchor[idx]), jnp.asarray(f.level[idx]),
                          jnp.asarray(f.stype[idx]))
            if not np.asarray(
                    get_batch_ops(d, eclass=ec).is_inside_root(sub)).all():
                return False
    # coverage: sum of 2^{-d*level} == num_trees
    vol = (1.0 / (1 << d) ** all_level.astype(np.float64)).sum()
    K = forests[0].num_trees
    if not abs(vol - K) < 1e-9 * max(K, 1):
        return False
    # ghost consistency across ranks (and tree faces)
    if ghosts is not None:
        owner_of = {}
        for p, f in enumerate(forests):
            for i in range(f.num_local):
                owner_of[(int(f.tree[i]), int(f.keys[i]), int(f.level[i]))] = p
        cm = forests[0].cmesh
        for p, g in enumerate(ghosts):
            if len(g["level"]) == 0:
                continue
            te = (np.zeros(len(g["level"]), np.int32) if cm is None
                  else cm.tree_eclass[g["tree"]])
            gkeys = np.zeros(len(g["level"]), np.uint64)
            for ec in np.unique(te):
                m = te == ec
                gs = Simplex(jnp.asarray(g["anchor"][m]),
                             jnp.asarray(g["level"][m]),
                             jnp.asarray(g["stype"][m]))
                gkeys[m] = get_batch_ops(d, eclass=int(ec)).morton_key_np(gs)
            for j in range(len(gkeys)):
                q = int(g["owner"][j])
                if q == p:
                    return False
                if owner_of.get((int(g["tree"][j]), int(gkeys[j]), int(g["level"][j]))) != q:
                    return False
    return True


def count_global(forests: list[Forest], comm: Comm | None = None) -> int:
    """Total element count.  Without `comm` this sums the given (local)
    forests — the full global count under `SimComm` hosting, where every
    rank is local.  With `comm`, the local sums are allgathered, so the call
    is correct under distributed hosting too."""
    if comm is None:
        return int(sum(f.num_local for f in forests))
    return int(sum(comm.allgather([int(f.num_local) for f in forests])))
