"""Derivation of all lookup tables for the tetrahedral Morton (TM) space-filling curve.

Reference: C. Burstedde, J. Holke, "A tetrahedral space-filling curve for
non-conforming adaptive meshes" (2015/2016), the t8code SFC.

Rather than transcribing the paper's printed tables (1, 2, 6, 7, 8 and the
face-neighbor tables 3/4), we *derive* every table from first principles:

  * The reference simplices S_0 .. S_{d!-1} are defined exactly by the
    paper's Algorithm 4.1 (Coordinates): S_b = [0, e_i, e_i + e_j, (1,..,1)]
    with i = b // 2 (3D) resp. i = b (2D) and j = (i+2)%3 for even b,
    j = (i+1)%3 for odd b.
  * Bey's red-refinement rule (paper eq. (2)) produces the 2^d ordered
    children of a simplex from its corner midpoints.
  * The type of any sub-simplex is found by normalising its vertex set to
    its associated cube and matching against {S_b} (Property 4 guarantees
    a unique match).
  * Face-neighbor tables are found by brute-force search in a local uniform
    Kuhn lattice (they are translation- and level-invariant).
  * The "is outside / ancestor" boundary-type sets of Proposition 23 are
    fitted against an exact descendant oracle.

The unit tests cross-check the derived tables against every legible entry
of the paper's printed tables.

All tables are small (<= 6 x 8) int8/int32 numpy arrays; the jittable ops in
``repro.core.ops`` embed them as constants (they live in VMEM on TPU).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from functools import lru_cache

import numpy as np

__all__ = [
    "SFCTables",
    "get_tables",
    "MAXLEVEL",
    "face_plane",
    "root_face_planes",
]

# Maximum refinement level per dimension.  Chosen so (a) the consecutive index
# (d * level bits) fits in an emulated uint64 (two uint32 words), which is the
# widest integer we allow on the TPU path (no 64-bit ints in Pallas/TPU), and
# (b) the root cube side 2^MAXLEVEL stays below 2^31 (anchor coords are int32).
MAXLEVEL = {2: 30, 3: 21}


def _ref_simplex_vertices(d: int, b: int) -> np.ndarray:
    """Vertices of reference simplex S_b at scale 1, per Algorithm 4.1 (paper).

    Returns (d+1, d) int array; row 0 is the anchor node (origin).
    """
    v = np.zeros((d + 1, d), dtype=np.int64)
    if d == 2:
        i = b
        j = 1 - i
        e = np.eye(2, dtype=np.int64)
        v[1] = v[0] + e[i]
        v[2] = (1, 1)
    elif d == 3:
        i = b // 2
        j = (i + 2) % 3 if b % 2 == 0 else (i + 1) % 3
        e = np.eye(3, dtype=np.int64)
        v[1] = v[0] + e[i]
        v[2] = v[1] + e[j]
        v[3] = (1, 1, 1)
    else:
        raise ValueError(f"d must be 2 or 3, got {d}")
    return v


def _bey_children_vertices(d: int, verts: np.ndarray) -> list[np.ndarray]:
    """The 2^d ordered Bey children of a simplex given by `verts` (scale even).

    Vertex coordinates must be even integers so midpoints stay integral.
    Ordering follows Bey's numbering, paper eq. (2).
    """
    x = [verts[i] for i in range(d + 1)]

    def m(i, j):
        return (x[i] + x[j]) // 2

    if d == 2:
        return [
            np.stack([x[0], m(0, 1), m(0, 2)]),
            np.stack([m(0, 1), x[1], m(1, 2)]),
            np.stack([m(0, 2), m(1, 2), x[2]]),
            np.stack([m(0, 1), m(0, 2), m(1, 2)]),
        ]
    return [
        np.stack([x[0], m(0, 1), m(0, 2), m(0, 3)]),
        np.stack([m(0, 1), x[1], m(1, 2), m(1, 3)]),
        np.stack([m(0, 2), m(1, 2), x[2], m(2, 3)]),
        np.stack([m(0, 3), m(1, 3), m(2, 3), x[3]]),
        np.stack([m(0, 1), m(0, 2), m(0, 3), m(1, 3)]),
        np.stack([m(0, 1), m(0, 2), m(1, 2), m(1, 3)]),
        np.stack([m(0, 2), m(0, 3), m(1, 3), m(2, 3)]),
        np.stack([m(0, 2), m(1, 2), m(1, 3), m(2, 3)]),
    ]


def _type_of(d: int, verts: np.ndarray, h: int, anchor: np.ndarray) -> int:
    """Match a simplex (vertex set) against the reference types.

    `h` is the side length of its associated cube, `anchor` the cube anchor.
    """
    rel = verts - anchor[None, :]
    assert np.all(rel >= 0) and np.all(rel <= h), (verts, anchor, h)
    key = frozenset(map(tuple, (rel // (h // 1)).tolist())) if h == 1 else frozenset(
        map(tuple, (rel / h).astype(np.float64).tolist())
    )
    # Compare as exact rational grids: rel must be multiples of h.
    assert np.all(rel % h == 0)
    key = frozenset(map(tuple, (rel // h).tolist()))
    for b in range(math.factorial(d)):
        sb = frozenset(map(tuple, _ref_simplex_vertices(d, b).tolist()))
        if key == sb:
            return b
    raise AssertionError(f"no reference simplex matches {verts} (anchor {anchor}, h {h})")


def _cube_id(offset: np.ndarray) -> int:
    """cube-id from an anchor offset in {0,1}^d: x + 2y (+ 4z)."""
    return int(sum(int(offset[k]) << k for k in range(len(offset))))


@dataclasses.dataclass(frozen=True)
class SFCTables:
    """All derived lookup tables for dimension `d`."""

    d: int
    num_types: int                      # d!
    num_children: int                   # 2^d
    maxlevel: int
    # (d!, d+1, d) vertex offsets of S_b in units of h (Algorithm 4.1).
    ref_verts: np.ndarray
    # (d!, 2^d) child type, Bey order  (paper Table 1, "Ct").
    child_type: np.ndarray
    # (d!, 2^d, d) child anchor offset in units of h/2, Bey order.
    child_anchor: np.ndarray
    # (d!, 2^d) cube-id of Bey-child i of a type-b parent.
    child_cube_id: np.ndarray
    # (2^d, d!) parent type from (cube-id, own type)  (paper Fig. 8, "Pt").
    parent_type: np.ndarray
    # (d!, 2^d) sigma_b: Bey index -> TM local index  (paper Table 2).
    bey_to_local: np.ndarray
    # (d!, 2^d) sigma_b^{-1}: TM local index -> Bey index.
    local_to_bey: np.ndarray
    # (2^d, d!) local index from (own cube-id, own type)  (paper Table 6).
    local_index: np.ndarray
    # (d!, 2^d) cube-id of the TM-child `iloc` of a type-b parent (Table 7).
    cube_id_of_local: np.ndarray
    # (d!, 2^d) type of the TM-child `iloc` of a type-b parent (Table 8).
    type_of_local: np.ndarray
    # (d!, d+1) face-neighbor type            (paper Tables 3/4).
    neighbor_type: np.ndarray
    # (d!, d+1, d) face-neighbor anchor offset in units of h.
    neighbor_offset: np.ndarray
    # (d!, d+1) dual face number f~ of the neighbor.
    neighbor_face: np.ndarray
    # (d!, d) axis permutation (x_i, x_j, x_k) of Prop. 23 / Table 5.
    # perm[b] = (axis of x_i, axis of x_j, axis of x_k); for 2D only (i, j).
    outside_perm: np.ndarray
    # Boundary type sets for the ancestor test (derived, cf. Prop 23 (51d),
    # (52e)-(52g)).  outside_types_*[b, t] == 1 iff a candidate of type t whose
    # anchor lies on the respective boundary plane of a type-b simplex is
    # OUTSIDE.  "ik": plane x_i == x_k (3D only); "kj": plane x_k == x_j
    # (2D: the diagonal x_i == x_j); "diag": x_i == x_k == x_j (3D only).
    outside_types_ik: np.ndarray
    outside_types_kj: np.ndarray
    outside_types_diag: np.ndarray


def _derive_child_tables(d: int):
    nt, nc = math.factorial(d), 2 ** d
    child_type = np.zeros((nt, nc), dtype=np.int8)
    child_anchor = np.zeros((nt, nc, d), dtype=np.int8)
    child_cube_id = np.zeros((nt, nc), dtype=np.int8)
    for b in range(nt):
        verts = _ref_simplex_vertices(d, b) * 2  # scale 2 so midpoints are ints
        for i, cv in enumerate(_bey_children_vertices(d, verts)):
            anchor = cv.min(axis=0)
            # The anchor of every Kuhn simplex is a vertex (all types share the
            # cube's main diagonal), and equals its associated cube's anchor.
            assert any(np.array_equal(anchor, v) for v in cv)
            child_type[b, i] = _type_of(d, cv, 1, anchor)
            child_anchor[b, i] = anchor  # units of h/2 given parent scale 2
            child_cube_id[b, i] = _cube_id(anchor)
    return child_type, child_anchor, child_cube_id


def _derive_parent_type(d, child_type, child_cube_id):
    nt, nc = math.factorial(d), 2 ** d
    parent_type = -np.ones((nc, nt), dtype=np.int8)
    for b in range(nt):
        for i in range(nc):
            c, t = child_cube_id[b, i], child_type[b, i]
            if parent_type[c, t] >= 0:
                assert parent_type[c, t] == b, "Pt would be ambiguous"
            parent_type[c, t] = b
    assert np.all(parent_type >= 0), "Pt not total"
    return parent_type


def _derive_tm_order(d, child_type, child_cube_id):
    """TM order of children = lexicographic by (cube-id, type), paper eq. (17)."""
    nt, nc = math.factorial(d), 2 ** d
    bey_to_local = np.zeros((nt, nc), dtype=np.int8)
    local_to_bey = np.zeros((nt, nc), dtype=np.int8)
    for b in range(nt):
        keys = [(int(child_cube_id[b, i]), int(child_type[b, i])) for i in range(nc)]
        order = sorted(range(nc), key=lambda i: keys[i])  # order[r] = bey index of rank r
        for rank, i in enumerate(order):
            bey_to_local[b, i] = rank
            local_to_bey[b, rank] = i
    return bey_to_local, local_to_bey


def _derive_local_index(d, child_type, child_cube_id, parent_type, bey_to_local):
    nt, nc = math.factorial(d), 2 ** d
    local_index = -np.ones((nc, nt), dtype=np.int8)
    for b in range(nt):  # parent type
        for i in range(nc):
            c, t = child_cube_id[b, i], child_type[b, i]
            local_index[c, t] = bey_to_local[b, i]
    assert np.all(local_index >= 0)
    return local_index


def _derive_local_lookup(d, child_type, child_cube_id, local_to_bey):
    nt, nc = math.factorial(d), 2 ** d
    cube_id_of_local = np.zeros((nt, nc), dtype=np.int8)
    type_of_local = np.zeros((nt, nc), dtype=np.int8)
    for b in range(nt):
        for rank in range(nc):
            i = local_to_bey[b, rank]
            cube_id_of_local[b, rank] = child_cube_id[b, i]
            type_of_local[b, rank] = child_type[b, i]
    return cube_id_of_local, type_of_local


def _derive_face_neighbors(d: int):
    """Brute-force the same-level face-neighbor tables in a local Kuhn lattice.

    Tables are translation invariant, so one interior sample per type suffices.
    Face f_i of T = [x_0..x_d] is the face NOT containing x_i.
    """
    nt = math.factorial(d)
    neighbor_type = np.zeros((nt, d + 1), dtype=np.int8)
    neighbor_offset = np.zeros((nt, d + 1, d), dtype=np.int8)
    neighbor_face = np.zeros((nt, d + 1), dtype=np.int8)

    # Build all simplices of the uniform Kuhn mesh in cubes with anchors in
    # {-1,0,1,2}^d (side 1), around the sample simplex at cube anchor 0.
    cells = []
    for a in itertools.product(range(-1, 3), repeat=d):
        for b in range(nt):
            verts = _ref_simplex_vertices(d, b) + np.array(a, dtype=np.int64)
            cells.append((np.array(a), b, verts))

    face_map: dict[frozenset, list[int]] = {}
    for idx, (_, _, verts) in enumerate(cells):
        for f in range(d + 1):
            fv = frozenset(tuple(verts[k]) for k in range(d + 1) if k != f)
            face_map.setdefault(fv, []).append(idx)

    for b in range(nt):
        verts = _ref_simplex_vertices(d, b)
        for f in range(d + 1):
            fv = frozenset(tuple(verts[k]) for k in range(d + 1) if k != f)
            owners = face_map[fv]
            others = [
                i for i in owners
                if not (np.array_equal(cells[i][0], np.zeros(d)) and cells[i][1] == b)
            ]
            assert len(others) == 1, f"face {f} of type {b}: owners {owners}"
            a2, b2, v2 = cells[others[0]]
            neighbor_type[b, f] = b2
            neighbor_offset[b, f] = a2
            # dual face: index of the vertex of the neighbor not on the face
            nf = [k for k in range(d + 1) if tuple(v2[k]) not in fv]
            assert len(nf) == 1
            neighbor_face[b, f] = nf[0]
    return neighbor_type, neighbor_offset, neighbor_face


def _derive_outside_perm(d: int):
    """Axis permutation (i, j, k) of Prop. 23 / Table 5, derived from S_b.

    S_b = {0 <= a_{x_j} <= a_{x_k} <= a_{x_i} <= 1} (3D)
    resp. {0 <= a_{x_j} <= a_{x_i} <= 1} (2D).
    The axes are recovered from the reference vertices: x_i is the axis of the
    first edge (largest coordinate), x_k the second edge axis, x_j the rest.
    """
    nt = math.factorial(d)
    perm = np.zeros((nt, d), dtype=np.int8)
    for b in range(nt):
        v = _ref_simplex_vertices(d, b)
        i_ax = int(np.argmax(v[1]))
        if d == 2:
            perm[b] = (i_ax, 1 - i_ax)
        else:
            k_ax = int(np.argmax(v[2] - v[1]))
            j_ax = 3 - i_ax - k_ax
            perm[b] = (i_ax, j_ax, k_ax)
    return perm


@lru_cache(maxsize=None)
def _descendant_sets(d: int, level: int):
    """All descendants of the root simplex down to `level` at vertex scale 2^level.

    Returns dict level -> list of (anchor tuple, type, verts).  Used only for
    table fitting/testing (exponential; keep level small).
    """
    scale = 2 ** level
    root = _ref_simplex_vertices(d, 0) * scale
    out = {0: [(tuple([0] * d), 0, root)]}
    for lv in range(1, level + 1):
        cur = []
        h = scale >> lv
        for _, b, verts in out[lv - 1]:
            for cv in _bey_children_vertices(d, verts):
                anchor = cv.min(axis=0)
                t = _type_of(d, cv, h, anchor)
                cur.append((tuple(int(a) for a in anchor), t, cv))
        out[lv] = cur
    return out


def _derive_outside_type_sets(d: int, perm, child_type, child_cube_id, parent_type):
    """Fit the boundary type sets of the constant-time ancestor test.

    For a simplex T of type b (take T = root, type 0..d!-1 via relabeling:
    instead we test against actual descendants of sub-simplices) a candidate N
    with relative anchor a (a = N.anchor - T.anchor) and level > T.level is a
    descendant iff
        0 <= a_{xj} <= a_{xk} <= a_{xi} < h(T)      (3D; 2D drops x_k)
    AND the type of N is admissible on the boundary planes:
        - a_{xj} == a_{xk}  (< a_{xi})        -> N.b in KJ_inside[b]
        - a_{xk} == a_{xi}  (> a_{xj})        -> N.b in IK_inside[b]
        - a_{xj} == a_{xk} == a_{xi}          -> N.b in DIAG_inside[b]
    We *fit* the inside sets with an exact oracle: enumerate all descendants of
    a level-1 simplex of each type within a level-3 refinement of the root.
    Returns OUTSIDE (complement) boolean arrays of shape (d!, d!).
    """
    nt = math.factorial(d)
    rel_levels = 2          # candidate level relative to T
    h_T = 2 ** rel_levels   # T's cube side at candidate vertex scale 1

    # Oracle: recursively enumerate the (anchor, type) of all relative-level-2
    # descendants of T = S_b scaled by h_T.  The descendant relation is
    # translation/scale invariant (Property 4), so placing T at the origin is
    # fully general.
    def descendants_of(verts_T):
        acc = set()
        stack = [(verts_T, 0)]
        while stack:
            v, lv = stack.pop()
            if lv == rel_levels:
                a = v.min(axis=0)
                acc.add((tuple(int(x) for x in a), _type_of(d, v, 1, a)))
            else:
                stack.extend((cv, lv + 1) for cv in _bey_children_vertices(d, v))
        return acc

    on_ik = -np.ones((nt, nt), dtype=np.int8)
    on_kj = -np.ones((nt, nt), dtype=np.int8)
    on_diag = -np.ones((nt, nt), dtype=np.int8)

    for bT in range(nt):
        desc = descendants_of(_ref_simplex_vertices(d, bT) * h_T)
        p = perm[bT]
        for aN in itertools.product(range(-1, h_T + 1), repeat=d):
            for bN in range(nt):
                rel = np.array(aN)
                ai = rel[p[0]]
                aj = rel[p[1]]
                ak = rel[p[2]] if d == 3 else aj  # 2D: treat x_k := x_j
                inside_open = (0 <= aj <= ak <= ai < h_T) if d == 3 else (0 <= aj <= ai < h_T)
                is_desc = (tuple(aN), bN) in desc
                if not inside_open:
                    assert not is_desc, "oracle violates anchor-ordering condition"
                    continue
                if d == 3:
                    eq_kj, eq_ik = (aj == ak), (ak == ai)
                else:
                    eq_kj, eq_ik = (aj == ai), False
                if not eq_kj and not eq_ik:
                    assert is_desc, "strict interior must be a descendant"
                    continue
                tgt = on_diag if (eq_kj and eq_ik and d == 3) else (on_ik if eq_ik else on_kj)
                val = 0 if is_desc else 1  # 1 = outside
                if tgt[bT, bN] >= 0:
                    assert tgt[bT, bN] == val, "boundary type set not well-defined"
                tgt[bT, bN] = val

    # every combination must have been observed
    assert np.all(on_kj >= 0)
    if d == 3:
        assert np.all(on_ik >= 0) and np.all(on_diag >= 0)
    else:
        on_ik = np.zeros_like(on_kj)
        on_diag = np.zeros_like(on_kj)
    return on_ik.astype(np.int8), on_kj.astype(np.int8), on_diag.astype(np.int8)


def face_plane(V) -> tuple[np.ndarray, int]:
    """Primitive integer plane equation through the d points `V` ((d, d)
    int array): returns (normal, offset) with the plane {x : n @ x == r}."""
    V = np.asarray(V, np.int64)
    if V.shape[1] == 2:
        e = V[1] - V[0]
        n = np.array([-e[1], e[0]], np.int64)
    else:
        n = np.cross(V[1] - V[0], V[2] - V[0])
    g = int(np.gcd.reduce(np.abs(n)))
    n = n // max(g, 1)
    return n, int(n @ V[0])


@lru_cache(maxsize=None)
def root_face_planes(d: int) -> tuple:
    """Integer plane equations of the d+1 facets of the root simplex S_0 at
    unit scale: entry f is (normal, offset) with face f in {x : n @ x == r}.

    Derived from the reference vertices; the coarse-mesh layer classifies
    which root facet a boundary element's face lies on by testing these
    planes at scale 2^MAXLEVEL.
    """
    rv = _ref_simplex_vertices(d, 0)
    planes = []
    for f in range(d + 1):
        n, r = face_plane(np.delete(rv, f, axis=0))
        planes.append((tuple(int(v) for v in n), r))
    return tuple(planes)


@lru_cache(maxsize=None)
def hex_root_face_planes(d: int) -> tuple:
    """Integer plane equations of the 2d facets of the root cube [0, 1)^d at
    unit scale, in face order f = 2*axis + dir: the lower (x_axis = 0) and
    upper (x_axis = 1) face per axis — same (normal, offset) convention as
    `root_face_planes`, tested at scale 2^MAXLEVEL by the coarse-mesh layer."""
    planes = []
    for f in range(2 * d):
        n = tuple(int(k == f // 2) for k in range(d))
        planes.append((n, f % 2))
    return tuple(planes)


@lru_cache(maxsize=None)
def get_tables(d: int) -> SFCTables:
    if d not in (2, 3):
        raise ValueError(f"d must be 2 or 3, got {d}")
    nt, nc = math.factorial(d), 2 ** d
    ref_verts = np.stack([_ref_simplex_vertices(d, b) for b in range(nt)]).astype(np.int8)
    child_type, child_anchor, child_cube_id = _derive_child_tables(d)
    parent_type = _derive_parent_type(d, child_type, child_cube_id)
    bey_to_local, local_to_bey = _derive_tm_order(d, child_type, child_cube_id)
    local_index = _derive_local_index(d, child_type, child_cube_id, parent_type, bey_to_local)
    cube_id_of_local, type_of_local = _derive_local_lookup(d, child_type, child_cube_id, local_to_bey)
    neighbor_type, neighbor_offset, neighbor_face = _derive_face_neighbors(d)
    outside_perm = _derive_outside_perm(d)
    o_ik, o_kj, o_diag = _derive_outside_type_sets(
        d, outside_perm, child_type, child_cube_id, parent_type
    )
    return SFCTables(
        d=d,
        num_types=nt,
        num_children=nc,
        maxlevel=MAXLEVEL[d],
        ref_verts=ref_verts,
        child_type=child_type,
        child_anchor=child_anchor,
        child_cube_id=child_cube_id,
        parent_type=parent_type,
        bey_to_local=bey_to_local,
        local_to_bey=local_to_bey,
        local_index=local_index,
        cube_id_of_local=cube_id_of_local,
        type_of_local=type_of_local,
        neighbor_type=neighbor_type,
        neighbor_offset=neighbor_offset,
        neighbor_face=neighbor_face,
        outside_perm=outside_perm,
        outside_types_ik=o_ik,
        outside_types_kj=o_kj,
        outside_types_diag=o_diag,
    )


if __name__ == "__main__":
    for d in (2, 3):
        t = get_tables(d)
        print(f"== d={d} ==")
        print("child_type (Table 1):\n", t.child_type)
        print("bey_to_local (Table 2):\n", t.bey_to_local)
        print("parent_type (Fig 8):\n", t.parent_type)
        print("local_index (Table 6):\n", t.local_index)
        print("cube_id_of_local (Table 7):\n", t.cube_id_of_local)
        print("type_of_local (Table 8):\n", t.type_of_local)
        print("neighbor_type (Tables 3/4):\n", t.neighbor_type)
        print("neighbor_offset:\n", t.neighbor_offset.reshape(t.num_types, -1))
        print("neighbor_face:\n", t.neighbor_face)
        print("outside_perm (Table 5):\n", t.outside_perm)
        print("outside ik/kj/diag:\n", t.outside_types_ik, "\n", t.outside_types_kj, "\n", t.outside_types_diag)
