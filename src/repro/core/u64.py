"""Emulated 64-bit unsigned integers as pairs of uint32 words.

TPU vector units (and Pallas TPU kernels) do not support 64-bit integers, so
the consecutive SFC index — up to d * MAXLEVEL = 63 bits (3D, level 21) resp.
60 bits (2D, level 30) — is carried as (hi, lo) uint32 pairs.  This is the
central hardware adaptation of the paper's uint64 `linear id`: every
arithmetic operation below lowers to plain 32-bit ALU ops that vectorise on
the VPU (8x128 lanes).

All shift amounts are static Python ints (the level loops in `ops.py` are
unrolled), which keeps the lowering branch-free.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_U32 = jnp.uint32
_MASK = np.uint32(0xFFFFFFFF)


class U64(NamedTuple):
    hi: jax.Array  # uint32
    lo: jax.Array  # uint32


def zeros(shape=()) -> U64:
    z = jnp.zeros(shape, _U32)
    return U64(z, z)


def from_int(value, shape=()) -> U64:
    """Build from a Python int (or array of ints) — host-side convenience."""
    v = np.asarray(value, np.uint64)
    hi = jnp.broadcast_to(jnp.asarray((v >> np.uint64(32)).astype(np.uint32)), shape or v.shape)
    lo = jnp.broadcast_to(jnp.asarray((v & np.uint64(_MASK)).astype(np.uint32)), shape or v.shape)
    return U64(hi, lo)


def from_u32(x) -> U64:
    x = jnp.asarray(x, _U32)
    return U64(jnp.zeros_like(x), x)


def to_np(a: U64) -> np.ndarray:
    """To numpy uint64 (host-side)."""
    return (np.asarray(a.hi, np.uint64) << np.uint64(32)) | np.asarray(a.lo, np.uint64)


def add(a: U64, b: U64) -> U64:
    lo = a.lo + b.lo
    carry = (lo < a.lo).astype(_U32)
    return U64(a.hi + b.hi + carry, lo)


def add_u32(a: U64, k) -> U64:
    k = jnp.asarray(k, _U32)
    lo = a.lo + k
    carry = (lo < a.lo).astype(_U32)
    return U64(a.hi + carry, lo)


def sub(a: U64, b: U64) -> U64:
    lo = a.lo - b.lo
    borrow = (a.lo < b.lo).astype(_U32)
    return U64(a.hi - b.hi - borrow, lo)


def sub_u32(a: U64, k) -> U64:
    k = jnp.asarray(k, _U32)
    lo = a.lo - k
    borrow = (a.lo < k).astype(_U32)
    return U64(a.hi - borrow, lo)


def inc(a: U64) -> U64:
    return add_u32(a, 1)


def dec(a: U64) -> U64:
    return sub_u32(a, 1)


def shl(a: U64, k: int) -> U64:
    """Static left shift by k in [0, 64)."""
    assert 0 <= k < 64
    if k == 0:
        return a
    if k < 32:
        return U64((a.hi << k) | (a.lo >> (32 - k)), a.lo << k)
    return U64(a.lo << (k - 32), jnp.zeros_like(a.lo))


def shr(a: U64, k: int) -> U64:
    """Static (logical) right shift by k in [0, 64)."""
    assert 0 <= k < 64
    if k == 0:
        return a
    if k < 32:
        return U64(a.hi >> k, (a.lo >> k) | (a.hi << (32 - k)))
    return U64(jnp.zeros_like(a.hi), a.hi >> (k - 32))


def or_(a: U64, b: U64) -> U64:
    return U64(a.hi | b.hi, a.lo | b.lo)


def and_mask(a: U64, mask: int) -> U64:
    m_hi = np.uint32(mask >> 32)
    m_lo = np.uint32(mask & int(_MASK))
    return U64(a.hi & m_hi, a.lo & m_lo)


def bits(a: U64, pos: int, width: int):
    """Extract `width` (<32) bits at static position `pos` as uint32."""
    assert width < 32
    sh = shr(a, pos)
    return sh.lo & np.uint32((1 << width) - 1)


def eq(a: U64, b: U64):
    return (a.hi == b.hi) & (a.lo == b.lo)


def lt(a: U64, b: U64):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo < b.lo))


def le(a: U64, b: U64):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo <= b.lo))


def where(pred, a: U64, b: U64) -> U64:
    return U64(jnp.where(pred, a.hi, b.hi), jnp.where(pred, a.lo, b.lo))


def select_shl(a: U64, k, max_k: int) -> U64:
    """Dynamic left shift: k is a traced int32 in [0, max_k]. O(log) selects."""
    out = a
    bit = 1
    while bit <= max_k:
        out = where((jnp.asarray(k) & bit) != 0, shl(out, bit), out)
        bit <<= 1
    return out


def select_shr(a: U64, k, max_k: int) -> U64:
    """Dynamic right shift: k is a traced int32 in [0, max_k]."""
    out = a
    bit = 1
    while bit <= max_k:
        out = where((jnp.asarray(k) & bit) != 0, shr(out, bit), out)
        bit <<= 1
    return out
