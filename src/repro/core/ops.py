"""Vectorized, jit-compatible element algorithms on the tetrahedral SFC.

Implements the paper's Section 4 algorithms over *batches* of simplices:

  coordinates        Algorithm 4.1  (corner nodes from the Tet-id)
  cube_id            Algorithm 4.2
  parent             Algorithm 4.3
  child_bey          Algorithm 4.4  (Bey order)
  child_tm           Algorithm 4.5  (TM order)
  face_neighbor      Algorithm 4.6
  linear_id          Algorithm 4.7  (consecutive index, emulated uint64)
  from_linear_id     Algorithm 4.8
  successor / predecessor            (batch form of Algorithm 4.10)
  is_ancestor        Proposition 23 (constant-time outside/descendant test)
  morton_key         level-padded linear id for mixed-level SFC comparisons

Hardware adaptation (see DESIGN.md): the paper's per-element sequential
O(1)/O(L) routines become branch-free table-gather pipelines over int32
lanes.  Level loops are unrolled to MAXLEVEL (21 in 3D) so every shift is
static; the 64-bit consecutive index is carried as uint32 pairs (`u64.py`).
Lookup tables are tiny (<= 8x6) constants that live in VMEM/SMEM on TPU.
"""

from __future__ import annotations

from functools import cached_property, partial

import jax
import jax.numpy as jnp
import numpy as np

from . import u64 as u64m
from .tables import MAXLEVEL, get_tables
from .types import ECLASS_HEX, ECLASS_SIMPLEX, Simplex

__all__ = ["ElementOps", "SimplexOps", "HexOps", "ops2d", "ops3d", "get_ops"]


class ElementOps:
    """Element algorithms bound to (dimension, element class) — the abstract
    protocol every class implements.  Stateless & jit-safe.

    A concrete class supplies the per-class constants

      eclass         the types.ECLASS_* tag (a static dispatch key)
      nt             number of element types (d! simplices, 1 hex)
      nc             children per element (2^d for both shipped classes)
      nf             faces per element (d+1 simplex, 2d hex)
      num_corners    corners per element (d+1 simplex, 2^d hex)
      face_corner_indices   (nf, corners-per-face) int — which element
                            corners span each face, in `coordinates` order

    and the primitive algorithms (coordinates, parent, child_tm,
    local_index, face_neighbor, ancestor_at_level, is_ancestor,
    is_inside_root, morton_key, from_linear_id, nearest_common_ancestor).
    Everything level/key-generic — the shared 2^d-children key arithmetic
    that makes partition markers and `validate` class-agnostic — lives
    here."""

    d: int
    L: int
    eclass: int
    nt: int
    nc: int
    nf: int
    num_corners: int

    # ------------------------------------------------------------------ utils
    def h(self, level):
        """Cube side length at `level`."""
        return jnp.int32(1) << (jnp.int32(self.L) - jnp.asarray(level, jnp.int32))

    def cube_id(self, s: Simplex, level=None):
        """Algorithm 4.2: cube-id of the level-`level` ancestor's cube."""
        level = s.level if level is None else level
        bits = (s.anchor >> (self.L - jnp.asarray(level, jnp.int32))[..., None]) & 1
        weights = jnp.asarray([1 << k for k in range(self.d)], jnp.int32)
        return jnp.sum(bits * weights, axis=-1)

    # ------------------------------------------------------------- hierarchy
    def children_tm(self, s: Simplex) -> Simplex:
        """All 2^d children in SFC order: batch shape (..., 2^d)."""
        kids = [self.child_tm(s, i) for i in range(self.nc)]
        return Simplex(
            jnp.stack([k.anchor for k in kids], axis=-2),
            jnp.stack([k.level for k in kids], axis=-1),
            jnp.stack([k.stype for k in kids], axis=-1),
        )

    def sibling_tm(self, s: Simplex, iloc) -> Simplex:
        return self.child_tm(self.parent(s), iloc)

    def tree_transform(self, s: Simplex, M, c, typemap) -> Simplex:
        """Affine lattice isometry (the cmesh gluing map): anchor' =
        M @ anchor + c, shifted by -h on reflected axes so the anchor stays
        the min corner of the image cube; the type moves through the
        per-connection `typemap` (d! entries for simplices, the trivial
        1-entry map for hexes).  `M` is a signed permutation, `c` a multiple
        of the element's cube side — both per-connection constants."""
        M = jnp.asarray(M, jnp.int32)
        c = jnp.asarray(c, jnp.int32)
        tm = jnp.asarray(typemap, jnp.int32)
        h = self.h(s.level)
        neg = jnp.minimum(jnp.sum(M, axis=-1), 0)  # -1 on reflected rows
        anchor = (
            jnp.sum(s.anchor[..., None, :] * M, axis=-1) + c + h[..., None] * neg
        )
        return Simplex(anchor.astype(jnp.int32), s.level, tm[s.stype])

    # ------------------------------------------------------------ linear ids
    def linear_id(self, s: Simplex) -> u64m.U64:
        """Algorithm 4.7: consecutive index of s at its own level."""
        shift = (jnp.asarray(self.L, jnp.int32) - s.level) * self.d
        return u64m.select_shr(self.morton_key(s), shift, self.d * self.L)

    def decode_key(self, key: u64m.U64, level) -> Simplex:
        """Inverse of `morton_key` at a given level: drop the level padding
        and run the per-class decode.  This is the decode entry point the
        batched backends share (the Pallas decode kernel consumes padded
        keys too)."""
        level = jnp.asarray(level, jnp.int32)
        lid = u64m.select_shr(
            key, (jnp.asarray(self.L, jnp.int32) - level) * self.d, self.d * self.L
        )
        return self.from_linear_id(lid, level)

    def successor(self, s: Simplex) -> Simplex:
        """Next same-level element in SFC order (batch Algorithm 4.10)."""
        return self.from_linear_id(u64m.inc(self.linear_id(s)), s.level)

    def predecessor(self, s: Simplex) -> Simplex:
        return self.from_linear_id(u64m.dec(self.linear_id(s)), s.level)

    def num_elements(self, level) -> int:
        """Elements in a uniform refinement of one tree: 2^(d*level)."""
        return 1 << (self.d * int(level))

    # ------------------------------------------------------------- SFC order
    def sfc_less(self, a: Simplex, b: Simplex):
        """Strict SFC order across mixed levels: ancestors precede
        descendants (Theorem 16 (i))."""
        ka, kb = self.morton_key(a), self.morton_key(b)
        return u64m.lt(ka, kb) | (u64m.eq(ka, kb) & (a.level < b.level))


class SimplexOps(ElementOps):
    """The paper's tetrahedral-Morton algorithms for d-simplices (d = 2, 3)."""

    eclass = ECLASS_SIMPLEX

    def __init__(self, d: int):
        self.d = d
        self.t = get_tables(d)
        self.L = MAXLEVEL[d]
        self.nt = self.t.num_types          # d!
        self.nc = self.t.num_children       # 2^d
        self.nf = d + 1                     # faces per simplex
        self.num_corners = d + 1
        # face f is the face opposite corner f
        self.face_corner_indices = np.asarray(
            [[a for a in range(d + 1) if a != f] for f in range(d + 1)], np.int32
        )
        # jnp constants (int32 for gather friendliness)
        self.REF_VERTS = jnp.asarray(self.t.ref_verts, jnp.int32)
        self.CHILD_TYPE = jnp.asarray(self.t.child_type, jnp.int32)
        self.CHILD_ANCHOR = jnp.asarray(self.t.child_anchor, jnp.int32)
        self.CHILD_CUBE_ID = jnp.asarray(self.t.child_cube_id, jnp.int32)
        self.PARENT_TYPE = jnp.asarray(self.t.parent_type, jnp.int32)
        self.BEY_TO_LOCAL = jnp.asarray(self.t.bey_to_local, jnp.int32)
        self.LOCAL_TO_BEY = jnp.asarray(self.t.local_to_bey, jnp.int32)
        self.LOCAL_INDEX = jnp.asarray(self.t.local_index, jnp.int32)
        self.CID_OF_LOCAL = jnp.asarray(self.t.cube_id_of_local, jnp.int32)
        self.TYPE_OF_LOCAL = jnp.asarray(self.t.type_of_local, jnp.int32)
        self.NEIGH_TYPE = jnp.asarray(self.t.neighbor_type, jnp.int32)
        self.NEIGH_OFFSET = jnp.asarray(self.t.neighbor_offset, jnp.int32)
        self.NEIGH_FACE = jnp.asarray(self.t.neighbor_face, jnp.int32)
        self.PERM = jnp.asarray(self.t.outside_perm, jnp.int32)
        self.OUT_IK = jnp.asarray(self.t.outside_types_ik, jnp.int32)
        self.OUT_KJ = jnp.asarray(self.t.outside_types_kj, jnp.int32)
        self.OUT_DIAG = jnp.asarray(self.t.outside_types_diag, jnp.int32)

    def coordinates(self, s: Simplex):
        """Algorithm 4.1: (..., d+1, d) corner nodes."""
        h = self.h(s.level)
        return s.anchor[..., None, :] + h[..., None, None] * self.REF_VERTS[s.stype]

    # ------------------------------------------------------------- hierarchy
    def parent(self, s: Simplex) -> Simplex:
        """Algorithm 4.3."""
        h = self.h(s.level)
        cid = self.cube_id(s)
        anchor = s.anchor & ~h[..., None]
        return Simplex(anchor, s.level - 1, self.PARENT_TYPE[cid, s.stype])

    def child_bey(self, s: Simplex, i) -> Simplex:
        """Algorithm 4.4: the i-th child in Bey's order (eq. 2)."""
        i = jnp.asarray(i, jnp.int32)
        h2 = self.h(s.level) >> 1
        anchor = s.anchor + h2[..., None] * self.CHILD_ANCHOR[s.stype, i]
        return Simplex(anchor, s.level + 1, self.CHILD_TYPE[s.stype, i])

    def child_tm(self, s: Simplex, iloc) -> Simplex:
        """Algorithm 4.5: the iloc-th child in TM (SFC) order."""
        iloc = jnp.asarray(iloc, jnp.int32)
        h2 = self.h(s.level) >> 1
        cid = self.CID_OF_LOCAL[s.stype, iloc]
        bits = jnp.stack([(cid >> k) & 1 for k in range(self.d)], axis=-1)
        anchor = s.anchor + h2[..., None] * bits
        return Simplex(anchor, s.level + 1, self.TYPE_OF_LOCAL[s.stype, iloc])

    def local_index(self, s: Simplex):
        """Paper Table 6: the TM child index of s within its parent."""
        return self.LOCAL_INDEX[self.cube_id(s), s.stype]

    # ------------------------------------------------------------- neighbors
    def face_neighbor(self, s: Simplex, f):
        """Algorithm 4.6: same-level neighbor across face f, plus dual face.

        Returns (neighbor, dual_face).  The neighbor may lie outside the root
        simplex; check with `is_inside_root`.
        """
        f = jnp.asarray(f, jnp.int32)
        h = self.h(s.level)
        anchor = s.anchor + h[..., None] * self.NEIGH_OFFSET[s.stype, f]
        return (
            Simplex(anchor, s.level, self.NEIGH_TYPE[s.stype, f]),
            self.NEIGH_FACE[s.stype, f],
        )

    # ------------------------------------------------- ancestors / containment
    def ancestor_at_level(self, s: Simplex, level) -> Simplex:
        """The (unique) ancestor of s at `level` (<= s.level). O(MAXLEVEL) walk."""
        level = jnp.broadcast_to(jnp.asarray(level, jnp.int32), s.level.shape)
        b = s.stype
        out_type = jnp.where(level == s.level, s.stype, 0)
        # Walk up from MAXLEVEL using the T_0-chain trick: below s.level the
        # anchor bits are zero => cube-id 0, and Pt(0, b) = b keeps the type.
        for i in range(self.L, 0, -1):
            cid = self.cube_id(s, i)
            b = jnp.where(i > s.level, b, self.PARENT_TYPE[cid, b])
            out_type = jnp.where(jnp.int32(i - 1) == level, b, out_type)
        mask = ~((self.h(level)) - 1)
        anchor = s.anchor & mask[..., None]
        return Simplex(anchor, level, out_type)

    def is_ancestor(self, t: Simplex, n: Simplex):
        """Proposition 23 (constant time): True where t is an ancestor of n
        (incl. t == n).  Shapes must broadcast."""
        ht = self.h(t.level)
        rel = n.anchor - t.anchor
        p = self.PERM[t.stype]  # (..., d)
        a = jnp.take_along_axis(
            jnp.broadcast_to(rel, jnp.broadcast_shapes(rel.shape, p.shape)),
            jnp.broadcast_to(p, jnp.broadcast_shapes(rel.shape, p.shape)),
            axis=-1,
        )
        ai = a[..., 0]
        aj = a[..., 1]
        same = (t.level == n.level) & (ai == 0) & (aj == 0)
        if self.d == 3:
            ak = a[..., 2]
            same = same & (ak == 0)
        same = same & (t.stype == n.stype)
        deeper = n.level > t.level

        if self.d == 2:
            inside = (aj >= 0) & (ai < ht) & (aj <= ai)
            on_diag = aj == ai
            ok_diag = self.OUT_KJ[t.stype, n.stype] == 0
            inside = inside & (~on_diag | ok_diag)
        else:
            ak = a[..., 2]
            inside = (aj >= 0) & (ai < ht) & (ak <= ai) & (aj <= ak)
            eq_ik = ak == ai
            eq_kj = aj == ak
            both = eq_ik & eq_kj
            ok_ik = self.OUT_IK[t.stype, n.stype] == 0
            ok_kj = self.OUT_KJ[t.stype, n.stype] == 0
            ok_diag = self.OUT_DIAG[t.stype, n.stype] == 0
            ok = jnp.where(
                both, ok_diag, jnp.where(eq_ik, ok_ik, jnp.where(eq_kj, ok_kj, True))
            )
            inside = inside & ok
        return same | (deeper & inside)

    def is_inside_root(self, s: Simplex):
        """Section 4.4: does s lie inside the root simplex T_d^0?"""
        anchor = jnp.zeros_like(s.anchor)
        level = jnp.zeros_like(s.level)
        stype = jnp.zeros_like(s.stype)
        return self.is_ancestor(Simplex(anchor, level, stype), s) & (s.level >= 0)

    # ------------------------------------------------------------ linear ids
    def _type_chain(self, s: Simplex):
        """cube-ids and types of all ancestors T^i, i = 1..MAXLEVEL (T_0-chain
        padded below s.level).  Returns two lists of length MAXLEVEL, coarse
        first."""
        cids = [None] * (self.L + 1)
        types = [None] * (self.L + 1)
        b = s.stype
        for i in range(self.L, 0, -1):
            cid = self.cube_id(s, i)
            cids[i] = cid
            types[i] = b
            b = jnp.where(i > s.level, b, self.PARENT_TYPE[cid, b])
        return cids, types

    def morton_key(self, s: Simplex) -> u64m.U64:
        """Level-padded consecutive index: I(s) << d*(MAXLEVEL - level).

        Defines the total SFC order across mixed levels (ancestors first when
        combined with the level as a tiebreaker)."""
        cids, types = self._type_chain(s)
        key = u64m.zeros(s.level.shape)
        for i in range(1, self.L + 1):
            iloc = self.LOCAL_INDEX[cids[i], types[i]]
            key = u64m.or_(
                key, u64m.shl(u64m.from_u32(iloc.astype(jnp.uint32)), self.d * (self.L - i))
            )
        return key

    def from_linear_id(self, index: u64m.U64, level, d_batch_shape=None) -> Simplex:
        """Algorithm 4.8: build the simplex from a consecutive index + level."""
        level = jnp.asarray(level, jnp.int32)
        shape = jnp.broadcast_shapes(index.hi.shape, level.shape)
        level = jnp.broadcast_to(level, shape)
        index = u64m.U64(jnp.broadcast_to(index.hi, shape), jnp.broadcast_to(index.lo, shape))
        key = u64m.select_shl(index, (self.L - level) * self.d, self.d * self.L)
        anchor = jnp.zeros(shape + (self.d,), jnp.int32)
        b = jnp.zeros(shape, jnp.int32)
        for i in range(1, self.L + 1):
            iloc = u64m.bits(key, self.d * (self.L - i), self.d).astype(jnp.int32)
            cid = self.CID_OF_LOCAL[b, iloc]
            bits = jnp.stack([(cid >> k) & 1 for k in range(self.d)], axis=-1)
            anchor = anchor | (bits << (self.L - i))
            b = self.TYPE_OF_LOCAL[b, iloc]
        return Simplex(anchor, level, b)

    def nearest_common_ancestor(self, a: Simplex, b: Simplex) -> Simplex:
        """NCA via the embedding Phi (Prop. 17): deepest common prefix of the
        (cube-id, type) chains."""
        ca, ta = self._type_chain(a)
        cb, tb = self._type_chain(b)
        # deepest level i such that chains agree for all j <= i and i <= both levels
        agree = jnp.ones(jnp.broadcast_shapes(a.level.shape, b.level.shape), bool)
        nca_level = jnp.zeros_like(a.level)
        for i in range(1, self.L + 1):
            ok = (ca[i] == cb[i]) & (ta[i] == tb[i]) & (i <= a.level) & (i <= b.level)
            agree = agree & ok
            nca_level = jnp.where(agree, i, nca_level)
        return self.ancestor_at_level(Simplex(a.anchor, a.level, a.stype), nca_level)


class HexOps(ElementOps):
    """Quads/hexahedra on the plain Morton curve — the second element class.

    Hexes have no type bits: every element IS its cube, so the `stype` lane
    of the shared `Simplex` container is identically 0, the SFC key is the
    plain bit interleave of the anchor (reusing the u64 pair arithmetic),
    children come in Morton order, and face f = 2*axis + dir is the
    lower (dir = 0) / upper (dir = 1) face along `axis` with dual f ^ 1.
    MAXLEVEL matches the simplex class, so key spans (2^(d*(L-l)) per
    subtree) and `num_elements` are identical — what keeps partition
    markers, repartition, and `validate` class-agnostic."""

    eclass = ECLASS_HEX

    def __init__(self, d: int):
        self.d = d
        self.L = MAXLEVEL[d]
        self.nt = 1                         # no types
        self.nc = 1 << d                    # 2^d children
        self.nf = 2 * d                     # cube faces
        self.num_corners = 1 << d
        corners = np.asarray(
            [[(j >> k) & 1 for k in range(d)] for j in range(1 << d)], np.int32
        )
        self.CORNERS = jnp.asarray(corners)
        # face f = 2*axis + dir holds the 2^(d-1) corners whose `axis` bit
        # is `dir`; the first d of them (0, e_i scaled...) are affinely
        # independent, which `cmesh`/ghost rely on for plane equations.
        self.face_corner_indices = np.asarray(
            [[j for j in range(1 << d) if ((j >> (f // 2)) & 1) == (f % 2)]
             for f in range(2 * d)], np.int32
        )
        off = np.zeros((2 * d, d), np.int32)
        for f in range(2 * d):
            off[f, f // 2] = 2 * (f % 2) - 1
        self.NEIGH_OFFSET = jnp.asarray(off)

    def coordinates(self, s: Simplex):
        """(..., 2^d, d) corner nodes in Morton corner order."""
        h = self.h(s.level)
        return s.anchor[..., None, :] + h[..., None, None] * self.CORNERS

    # ------------------------------------------------------------- hierarchy
    def parent(self, s: Simplex) -> Simplex:
        h = self.h(s.level)
        return Simplex(s.anchor & ~h[..., None], s.level - 1,
                       jnp.zeros_like(s.stype))

    def child_tm(self, s: Simplex, iloc) -> Simplex:
        """The iloc-th child in SFC (= Morton) order."""
        iloc = jnp.asarray(iloc, jnp.int32)
        h2 = self.h(s.level) >> 1
        bits = jnp.stack([(iloc >> k) & 1 for k in range(self.d)], axis=-1)
        anchor = s.anchor + h2[..., None] * bits
        return Simplex(anchor, s.level + 1, jnp.zeros_like(s.stype))

    def local_index(self, s: Simplex):
        """The Morton child index of s within its parent = its cube id."""
        return self.cube_id(s)

    # ------------------------------------------------------------- neighbors
    def face_neighbor(self, s: Simplex, f):
        """Same-level neighbor across face f (axis f//2, direction f%2),
        plus the dual face f ^ 1.  May lie outside the root cube."""
        f = jnp.asarray(f, jnp.int32)
        h = self.h(s.level)
        anchor = s.anchor + h[..., None] * self.NEIGH_OFFSET[f]
        dual = jnp.broadcast_to(f ^ 1, s.level.shape)
        return Simplex(anchor, s.level, jnp.zeros_like(s.stype)), dual

    # ------------------------------------------------- ancestors / containment
    def ancestor_at_level(self, s: Simplex, level) -> Simplex:
        level = jnp.broadcast_to(jnp.asarray(level, jnp.int32), s.level.shape)
        mask = ~(self.h(level) - 1)
        return Simplex(s.anchor & mask[..., None], level, jnp.zeros_like(s.stype))

    def is_ancestor(self, t: Simplex, n: Simplex):
        """True where t's cube contains n's cube (incl. t == n)."""
        ht = self.h(t.level)
        rel = n.anchor - t.anchor
        inside = ((rel >= 0) & (rel < ht[..., None])).all(axis=-1)
        return (n.level >= t.level) & inside

    def is_inside_root(self, s: Simplex):
        """Does s lie inside the root cube [0, 2^L)^d?  (anchor <= 2^L - h
        avoids the int32 overflow of anchor + h at level 0)."""
        lim = jnp.int32(1 << self.L) - self.h(s.level)
        ok = ((s.anchor >= 0) & (s.anchor <= lim[..., None])).all(axis=-1)
        return ok & (s.level >= 0)

    # ------------------------------------------------------------ linear ids
    def morton_key(self, s: Simplex) -> u64m.U64:
        """Level-padded plain Morton key: interleave(anchor) — anchors are
        h-aligned, so the full-resolution interleave IS the level-shifted
        consecutive index."""
        key = u64m.zeros(s.level.shape)
        for i in range(1, self.L + 1):
            cid = self.cube_id(s, i)
            key = u64m.or_(
                key, u64m.shl(u64m.from_u32(cid.astype(jnp.uint32)), self.d * (self.L - i))
            )
        return key

    def from_linear_id(self, index: u64m.U64, level, d_batch_shape=None) -> Simplex:
        """Deinterleave a consecutive index + level back into the element."""
        level = jnp.asarray(level, jnp.int32)
        shape = jnp.broadcast_shapes(index.hi.shape, level.shape)
        level = jnp.broadcast_to(level, shape)
        index = u64m.U64(jnp.broadcast_to(index.hi, shape), jnp.broadcast_to(index.lo, shape))
        key = u64m.select_shl(index, (self.L - level) * self.d, self.d * self.L)
        anchor = jnp.zeros(shape + (self.d,), jnp.int32)
        for i in range(1, self.L + 1):
            cid = u64m.bits(key, self.d * (self.L - i), self.d).astype(jnp.int32)
            bits = jnp.stack([(cid >> k) & 1 for k in range(self.d)], axis=-1)
            anchor = anchor | (bits << (self.L - i))
        return Simplex(anchor, level, jnp.zeros(shape, jnp.int32))

    def nearest_common_ancestor(self, a: Simplex, b: Simplex) -> Simplex:
        """Deepest common cube: longest shared anchor-bit prefix."""
        agree = jnp.ones(jnp.broadcast_shapes(a.level.shape, b.level.shape), bool)
        nca_level = jnp.zeros_like(a.level)
        for i in range(1, self.L + 1):
            ok = (self.cube_id(a, i) == self.cube_id(b, i)) \
                & (i <= a.level) & (i <= b.level)
            agree = agree & ok
            nca_level = jnp.where(agree, i, nca_level)
        return self.ancestor_at_level(Simplex(a.anchor, a.level, a.stype), nca_level)


# Singletons
ops2d = SimplexOps(2)
ops3d = SimplexOps(3)
hexops2d = HexOps(2)
hexops3d = HexOps(3)

_OPS = {
    (2, ECLASS_SIMPLEX): ops2d,
    (3, ECLASS_SIMPLEX): ops3d,
    (2, ECLASS_HEX): hexops2d,
    (3, ECLASS_HEX): hexops3d,
}


def get_ops(d: int, eclass: int = ECLASS_SIMPLEX) -> ElementOps:
    try:
        return _OPS[(int(d), int(eclass))]
    except KeyError:
        raise ValueError(f"no element ops for d={d}, eclass={eclass}") from None
