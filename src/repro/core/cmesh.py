"""Coarse-mesh inter-tree connectivity for the forest (paper's stated extension).

The paper restricts Balance and Ghost to a single root simplex and names
multi-tree face connectivity as the open extension ("additional theoretical
work"); Holke's dissertation and t8code supply the missing layer: a *coarse
mesh* (cmesh) of K root simplices with per-face gluing data, plus an
element-level transform that re-expresses a boundary element's outside
face-neighbor in the neighbor tree's coordinate system.

Every tree's local frame is the reference root simplex ``S_0`` at scale
``2^MAXLEVEL``.  A gluing between two trees is an affine automorphism of the
Freudenthal (Kuhn) complex

    x  ->  M @ x + c,

where ``M`` is a *global-sign signed permutation* (``M = sigma * P`` with
``P`` a permutation matrix and ``sigma`` in {+1, -1}) and ``c`` an integer
translation.  Signed permutations with mixed signs do NOT preserve the Kuhn
triangulation (they flip the cube diagonal the types share), so they are
rejected; the global-sign family is exactly the lattice-isometry stabilizer
of the complex.  As everywhere in this repo the per-connection tables
(type map, vertex/face map) are *derived* from first principles — by
transforming the reference simplices and re-matching them — not transcribed.

Constructors for canonical domains:

  cmesh_single          one tree, all faces domain boundary
  cmesh_disconnected    K isolated trees (the legacy forest behaviour)
  cmesh_unit_cube       the d!-simplex Kuhn decomposition of one cube
                        (2 triangles in 2D, 6 tetrahedra in 3D)
  cmesh_brick           an n1 x n2 (x n3) array of Kuhn cubes, optionally
                        periodic per axis (wrap gluings are translations)
  cmesh_rotated_pair    2D: a triangle and its point-reflected copy glued
                        into a parallelogram (exercises sigma = -1)

The element-level entry point is ``transform_across_face(s, tree, face)``;
the batched backends reach the same math through
``BatchedOps.tree_transform`` so the forest hot loops stay bit-identical
across reference / jnp / pallas.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from .ops import get_ops
from .tables import MAXLEVEL, get_tables, hex_root_face_planes, root_face_planes
from .types import ECLASS_HEX, ECLASS_SIMPLEX, Simplex

__all__ = [
    "Cmesh",
    "cmesh_single",
    "cmesh_disconnected",
    "cmesh_unit_cube",
    "cmesh_brick",
    "cmesh_rotated_pair",
    "cmesh_hex_brick",
    "cmesh_hybrid_pair",
    "signed_perm_maps",
    "wrap_i32",
]


def wrap_i32(a) -> np.ndarray:
    """Two's-complement int32 wrap of an int64 array.

    Gluing translations can reach 2*2^MAXLEVEL (= 2^31 in 2D), one past the
    int32 range; since every *valid* transformed anchor lands back in
    [0, 2^MAXLEVEL), doing the transform arithmetic modulo 2^32 is exact —
    all integer backends (numpy, jnp, Pallas) wrap identically."""
    a = np.asarray(a, np.int64)
    return ((a + 2**31) % 2**32 - 2**31).astype(np.int32)


# ------------------------------------------------------------ derived pieces
@lru_cache(maxsize=None)
def _signed_perm_maps_cached(d: int, M_key: tuple) -> tuple:
    t = get_tables(d)
    nt = t.num_types
    M = np.asarray(M_key, np.int64)
    typemap = np.zeros(nt, np.int32)
    vertmap = np.zeros((nt, d + 1), np.int32)
    refs = [
        [tuple(r) for r in t.ref_verts[b].astype(np.int64).tolist()] for b in range(nt)
    ]
    for b in range(nt):
        W = t.ref_verts[b].astype(np.int64) @ M.T
        # The image cube's min corner: every type contains the cube's main
        # diagonal, so the min over image vertices is the image anchor.
        rel = [tuple(r) for r in (W - W.min(axis=0)).tolist()]
        for b2 in range(nt):
            if set(rel) == set(refs[b2]):
                typemap[b] = b2
                for a in range(d + 1):
                    vertmap[b, a] = refs[b2].index(rel[a])
                break
        else:
            raise ValueError(
                f"linear part {M.tolist()} is not an automorphism of the "
                f"Freudenthal complex (d={d}); only global-sign signed "
                "permutations are admissible"
            )
    return typemap, vertmap


def signed_perm_maps(d: int, M) -> tuple[np.ndarray, np.ndarray]:
    """(typemap, vertmap) of the complex automorphism with linear part `M`.

    typemap[b]    = type of the image of a type-b simplex.
    vertmap[b, a] = vertex index (in the image's reference numbering) that
                    vertex `a` of a type-b simplex maps to; since face f is
                    the face opposite vertex f, this is also the face map.
    Raises ValueError when `M` does not preserve the Kuhn triangulation.
    """
    M = np.asarray(M, np.int64)
    tm, vm = _signed_perm_maps_cached(d, tuple(map(tuple, M.tolist())))
    return tm.copy(), vm.copy()


def _is_signed_perm(d: int, M: np.ndarray) -> bool:
    """Signed permutation test (the full symmetry group of the cube lattice
    — hex trees admit every signed permutation, not just the global-sign
    family the Kuhn complex requires)."""
    M = np.asarray(M, np.int64)
    return (
        M.shape == (d, d)
        and np.array_equal(np.abs(M).sum(axis=0), np.ones(d, np.int64))
        and np.array_equal(np.abs(M).sum(axis=1), np.ones(d, np.int64))
        and bool(np.isin(M, (-1, 0, 1)).all())
    )


def _hex_face_map(d: int, M: np.ndarray) -> np.ndarray:
    """Face map of a hex tree under linear part `M`: face f = (axis f//2,
    dir f%2) maps along the image of its normal axis, with the direction
    flipped on reflected axes."""
    M = np.asarray(M, np.int64)
    fm = np.zeros(2 * d, np.int32)
    for f in range(2 * d):
        a, sdir = f // 2, f % 2
        a2 = int(np.nonzero(M[:, a])[0][0])
        fm[f] = 2 * a2 + (sdir if int(M[a2, a]) > 0 else 1 - sdir)
    return fm


def _perm_matrix_for_type(d: int, b: int) -> np.ndarray:
    """The unique permutation matrix mapping S_0 onto S_b (brute-forced;
    permutations act simply transitively on the Kuhn simplices of a cube)."""
    t = get_tables(d)
    target = set(map(tuple, t.ref_verts[b].astype(np.int64).tolist()))
    for perm in itertools.permutations(range(d)):
        P = np.zeros((d, d), np.int64)
        for a, pa in enumerate(perm):
            P[pa, a] = 1
        img = set(tuple(v) for v in (t.ref_verts[0].astype(np.int64) @ P.T).tolist())
        if img == target:
            return P
    raise AssertionError(f"no permutation maps S_0 to S_{b} (d={d})")


# ------------------------------------------------------------------- Cmesh
_Conn = dataclasses.make_dataclass(
    "Connection", ["tree", "face", "M", "c", "typemap", "facemap"]
)


@dataclasses.dataclass(eq=False)
class Cmesh:
    """K root simplices with per-face (neighbor tree, neighbor face,
    gluing transform) tables, all in each tree's local frame (root = S_0
    at scale 2^MAXLEVEL).

    face_tree[t, f] is -1 where face f of tree t is a *domain boundary*;
    otherwise the face is an *inter-tree face* and (face_M, face_c) map
    tree-t coordinates into the neighbor tree's frame.

    `tree_eclass[t]` is the element class of tree t (ECLASS_SIMPLEX /
    ECLASS_HEX).  The per-face tables' second axis is sized for the widest
    class present (d+1 simplex faces, 2d hex faces) — a pure-simplex mesh
    keeps the historical (K, d+1, ...) shapes exactly.  Classes are unions
    of whole trees; a face shared between trees of different classes stays
    a domain boundary (conforming hex|tet gluing is out of scope), so each
    class group is independently connected.
    """

    d: int
    num_trees: int
    face_tree: np.ndarray      # (K, nf_max) int32, -1 = domain boundary
    face_face: np.ndarray      # (K, nf_max) int32, neighbor's face index
    face_M: np.ndarray         # (K, nf_max, d, d) int32 gluing linear part
    face_c: np.ndarray         # (K, nf_max, d) int64 gluing translation (scale 2^L)
    face_typemap: np.ndarray   # (K, nf_max, d!) int32 type map under face_M
    face_facemap: np.ndarray   # (K, nf_max, d!, nf_max) int32 vertex/face map
    tree_embed_M: np.ndarray   # (K, d, d) int32 world embedding linear part
    tree_embed_o: np.ndarray   # (K, d) int64 world cube offset (unit scale)
    tree_eclass: np.ndarray = None  # (K,) int32 element class per tree

    def __post_init__(self):
        if self.tree_eclass is None:
            self.tree_eclass = np.zeros(self.num_trees, np.int32)
        else:
            self.tree_eclass = np.asarray(self.tree_eclass, np.int32)

    @property
    def L(self) -> int:
        return MAXLEVEL[self.d]

    def eclass_of(self, tree: int) -> int:
        """Element class of `tree` (every leaf of the tree shares it)."""
        return int(self.tree_eclass[tree])

    @property
    def eclasses(self) -> tuple:
        """Sorted distinct element classes present in the mesh."""
        return tuple(sorted(int(e) for e in np.unique(self.tree_eclass)))

    def is_connected(self, tree: int, root_face: int) -> bool:
        """True where `root_face` of `tree` is an inter-tree face (False =
        domain boundary) — the split of the old is_root_boundary notion."""
        return bool(self.face_tree[tree, root_face] >= 0)

    def connection(self, tree: int, root_face: int):
        """The gluing record of an inter-tree face (None at the boundary)."""
        if not self.is_connected(tree, root_face):
            return None
        return _Conn(
            int(self.face_tree[tree, root_face]),
            int(self.face_face[tree, root_face]),
            self.face_M[tree, root_face],
            self.face_c[tree, root_face],
            self.face_typemap[tree, root_face],
            self.face_facemap[tree, root_face],
        )

    # ------------------------------------------------------------ geometry
    def root_face_of(self, s: Simplex, face, eclass: int = ECLASS_SIMPLEX) -> np.ndarray:
        """Which root facet contains face `face` of each element (vectorized
        plane tests against the derived facet equations); -1 when the face
        is interior.  `face` is a scalar or (n,) element-face index."""
        o = get_ops(self.d, eclass)
        coords = np.asarray(o.coordinates(s), np.int64)  # (n, num_corners, d)
        face = np.broadcast_to(np.asarray(face, np.int32), coords.shape[:1])
        fci = np.asarray(o.face_corner_indices)  # (nf, corners per face)
        V = coords[np.arange(len(face))[:, None], fci[face]]
        planes = (hex_root_face_planes(self.d) if eclass == ECLASS_HEX
                  else root_face_planes(self.d))
        out = np.full(V.shape[0], -1, np.int32)
        for rf, (n_, r_) in enumerate(planes):
            on = (V @ np.asarray(n_, np.int64) == (r_ << self.L)).all(axis=1)
            out[on] = rf
        return out

    # ----------------------------------------------------------- transform
    def transform_across_face(self, s: Simplex, tree: int, root_face: int,
                              bops=None) -> tuple[Simplex, int]:
        """Map elements `s` (in `tree`'s frame, lying just OUTSIDE its root
        across `root_face`) into the neighbor tree's frame: (s', tree').

        With `bops` (a BatchedOps), the batched backend does the math —
        reference / jnp / pallas stay bit-identical; otherwise the eager
        SimplexOps path runs."""
        tree, root_face = int(tree), int(root_face)
        t2 = int(self.face_tree[tree, root_face])
        if t2 < 0:
            raise ValueError(f"tree {tree} face {root_face} is a domain boundary")
        M = self.face_M[tree, root_face]
        c = self.face_c[tree, root_face]
        tm = self.face_typemap[tree, root_face]
        if bops is not None:
            return bops.tree_transform(s, M, c, tm), t2
        o = get_ops(self.d, self.eclass_of(tree))
        return o.tree_transform(s, M, wrap_i32(c), tm), t2

    def world_vertices(self, tree: int, s: Simplex) -> np.ndarray:
        """(n, d+1, d) int64 vertex coordinates in the global world lattice
        (scale 2^L per unit cube) — the frame the brute-force test oracles
        match in."""
        o = get_ops(self.d, self.eclass_of(tree))
        coords = np.asarray(o.coordinates(s), np.int64)
        M = self.tree_embed_M[tree].astype(np.int64)
        off = self.tree_embed_o[tree].astype(np.int64) << self.L
        return coords @ M.T + off


# ------------------------------------------------------------- construction
def _from_embeddings(d: int, embeds, box=None, periodic=None, eclasses=None) -> Cmesh:
    """Derive the full connectivity from per-tree world embeddings
    ``world = M_t @ local + o_t * 2^L`` (unit-scale integer offsets `o_t`),
    by brute-force face matching in world coordinates — the same
    derive-don't-transcribe approach as `tables.py`.

    `eclasses` is the per-tree element class (default all-simplex).  A face
    whose two sides belong to trees of *different* classes is left a domain
    boundary: classes glue only within themselves, so each class group is an
    independently conforming sub-mesh (the mixed-class contract)."""
    t = get_tables(d)
    L = MAXLEVEL[d]
    nt = t.num_types
    K = len(embeds)
    periodic = tuple(periodic) if periodic is not None else (False,) * d
    eclasses = ([ECLASS_SIMPLEX] * K if eclasses is None
                else [int(e) for e in eclasses])
    rv0 = t.ref_verts[0].astype(np.int64)
    # hex corner j sits at bit pattern ((j >> k) & 1 along axis k) — the
    # same numbering as HexOps.CORNERS
    hex_rv = np.array(
        [[(j >> k) & 1 for k in range(d)] for j in range(1 << d)], np.int64)
    nf_of = {ECLASS_SIMPLEX: d + 1, ECLASS_HEX: 2 * d}
    nf_max = max(nf_of[e] for e in eclasses)

    Ms, os_ = [], []
    world = []
    for (M, o), ec in zip(embeds, eclasses):
        M = np.asarray(M, np.int64)
        o = np.asarray(o, np.int64)
        if ec == ECLASS_SIMPLEX:
            signed_perm_maps(d, M)  # validates admissibility
            rv = rv0
        else:
            if not _is_signed_perm(d, M):
                raise ValueError(
                    f"hex embedding {M.tolist()} is not a signed permutation")
            rv = hex_rv
        Ms.append(M)
        os_.append(o)
        world.append(rv @ M.T + o)

    def face_verts(tr: int, f: int) -> np.ndarray:
        if eclasses[tr] == ECLASS_SIMPLEX:
            return np.delete(world[tr], f, axis=0)
        sel = (hex_rv[:, f // 2] == f % 2)
        return world[tr][sel]

    # face registry in (wrapped) world coordinates at unit scale
    reg: dict[frozenset, list] = {}
    for tr in range(K):
        for f in range(nf_of[eclasses[tr]]):
            V = face_verts(tr, f)
            w = np.zeros(d, np.int64)
            if box is not None:
                for k in range(d):
                    if periodic[k] and np.all(V[:, k] == box[k]):
                        w[k] = -box[k]
            key = frozenset(map(tuple, (V + w).tolist()))
            reg.setdefault(key, []).append((tr, f, w))

    face_tree = np.full((K, nf_max), -1, np.int32)
    face_face = np.zeros((K, nf_max), np.int32)
    face_M = np.tile(np.eye(d, dtype=np.int32), (K, nf_max, 1, 1))
    face_c = np.zeros((K, nf_max, d), np.int64)
    face_typemap = np.tile(np.arange(nt, dtype=np.int32), (K, nf_max, 1))
    face_facemap = np.tile(np.arange(nf_max, dtype=np.int32), (K, nf_max, nt, 1))

    for key, lst in reg.items():
        if len(lst) == 1:
            continue  # domain boundary
        if len(lst) != 2:
            raise ValueError(f"face {sorted(key)} shared by {len(lst)} trees")
        if eclasses[lst[0][0]] != eclasses[lst[1][0]]:
            continue  # cross-class face: stays a domain boundary
        for (t1, f1, w1), (t2, f2, w2) in (lst, lst[::-1]):
            M = Ms[t2].T @ Ms[t1]
            c = (Ms[t2].T @ (os_[t1] - os_[t2] + w1 - w2)) << L
            # adjacent cubes keep |c| <= 2*2^L (the factor 2 needs a
            # reflected embedding, e.g. the rotated pair)
            assert np.abs(c).max(initial=0) <= (2 << L), "non-adjacent gluing"
            face_tree[t1, f1] = t2
            face_face[t1, f1] = f2
            face_M[t1, f1] = M
            face_c[t1, f1] = c
            if eclasses[t1] == ECLASS_SIMPLEX:
                tm, vm = signed_perm_maps(d, M)
                face_typemap[t1, f1] = tm
                face_facemap[t1, f1, :, :d + 1] = vm
            else:
                face_typemap[t1, f1] = 0
                face_facemap[t1, f1, :, :2 * d] = _hex_face_map(d, M)[None, :]

    cm = Cmesh(
        d=d, num_trees=K,
        face_tree=face_tree, face_face=face_face,
        face_M=face_M, face_c=face_c,
        face_typemap=face_typemap, face_facemap=face_facemap,
        tree_embed_M=np.stack(Ms).astype(np.int32),
        tree_embed_o=np.stack(os_),
        tree_eclass=np.asarray(eclasses, np.int32),
    )
    _check_connectivity(cm)
    return cm


def _check_connectivity(cm: Cmesh) -> None:
    """Construction-time proofs: every gluing is involutive (composes with
    its reverse to the identity) and maps the level-0 outside neighbor of
    the source root exactly onto the neighbor tree's root."""
    d, L = cm.d, cm.L
    root = Simplex(
        jnp.zeros((1, d), jnp.int32), jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32)
    )
    for t1 in range(cm.num_trees):
        o = get_ops(d, cm.eclass_of(t1))
        for f1 in range(o.nf):
            t2 = int(cm.face_tree[t1, f1])
            if t2 < 0:
                continue
            assert cm.eclass_of(t2) == cm.eclass_of(t1), "cross-class gluing"
            f2 = int(cm.face_face[t1, f1])
            assert int(cm.face_tree[t2, f2]) == t1 and int(cm.face_face[t2, f2]) == f1
            M12, c12 = cm.face_M[t1, f1].astype(np.int64), cm.face_c[t1, f1]
            M21, c21 = cm.face_M[t2, f2].astype(np.int64), cm.face_c[t2, f2]
            assert np.array_equal(M21 @ M12, np.eye(d, dtype=np.int64))
            assert np.array_equal(M21 @ c12 + c21, np.zeros(d, np.int64))
            # level-0: the outside neighbor across f1 IS the neighbor tree
            nb, dual = o.face_neighbor(root, f1)
            s2, tt = cm.transform_across_face(nb, t1, f1)
            assert tt == t2
            assert int(np.asarray(s2.stype)[0]) == 0 and int(np.asarray(s2.level)[0]) == 0
            assert np.array_equal(np.asarray(s2.anchor)[0], np.zeros(d, np.int32))
            bnb = int(np.asarray(nb.stype)[0])
            assert int(cm.face_facemap[t1, f1, bnb, int(np.asarray(dual)[0])]) == f2


def cmesh_disconnected(d: int, num_trees: int) -> Cmesh:
    """K isolated trees — every tree face is a domain boundary (the legacy
    forest behaviour, and the meaning of `Forest.cmesh is None`).  Trees are
    embedded two cubes apart along axis 0 so world coordinates stay unique."""
    e0 = np.zeros(d, np.int64)
    embeds = []
    for k in range(num_trees):
        o = e0.copy()
        o[0] = 2 * k
        embeds.append((np.eye(d, dtype=np.int64), o))
    return _from_embeddings(d, embeds)


def cmesh_single(d: int) -> Cmesh:
    """One root simplex, all faces domain boundary (the paper's setting)."""
    return cmesh_disconnected(d, 1)


def cmesh_brick(d: int, shape, periodic=None) -> Cmesh:
    """An array of ``prod(shape)`` Kuhn cubes, each split into d! trees
    (2 triangles / 6 tetrahedra); interior and (optionally, per-axis)
    periodic faces are glued, outer faces are domain boundary.

    Tree order: cells in C order (np.ndindex), types 0..d!-1 within a cell.
    """
    shape = tuple(int(s) for s in shape)
    assert len(shape) == d and all(s >= 1 for s in shape)
    nt = math.factorial(d)
    perms = [_perm_matrix_for_type(d, b) for b in range(nt)]
    embeds = []
    for cell in np.ndindex(shape):
        for b in range(nt):
            embeds.append((perms[b], np.asarray(cell, np.int64)))
    return _from_embeddings(d, embeds, box=shape, periodic=periodic)


def cmesh_unit_cube(d: int, periodic=None) -> Cmesh:
    """The Kuhn decomposition of one cube: 2 trees in 2D, 6 in 3D."""
    return cmesh_brick(d, (1,) * d, periodic=periodic)


def cmesh_hex_brick(d: int, shape, periodic=None) -> Cmesh:
    """An array of ``prod(shape)`` hex trees (one tree per cell, identity
    embeddings) on the plain Morton curve; interior and (optionally,
    per-axis) periodic faces glue, outer faces are domain boundary.
    Cell order is C order (np.ndindex)."""
    shape = tuple(int(s) for s in shape)
    assert len(shape) == d and all(s >= 1 for s in shape)
    embeds = [(np.eye(d, dtype=np.int64), np.asarray(cell, np.int64))
              for cell in np.ndindex(shape)]
    return _from_embeddings(d, embeds, box=shape, periodic=periodic,
                            eclasses=[ECLASS_HEX] * len(embeds))


def cmesh_hybrid_pair(d: int) -> Cmesh:
    """The mixed-class fixture: one hex tree at the origin cell next to a
    Kuhn-decomposed simplex cube in the adjacent cell (+1 along axis 0).
    The shared cube face is a cross-class face and therefore stays a domain
    boundary; each class group is a (trivially) conforming sub-mesh.  Tree
    order: tree 0 is the hex, trees 1..d! the simplices."""
    nt = math.factorial(d)
    e0 = np.zeros(d, np.int64)
    e0[0] = 1
    embeds = [(np.eye(d, dtype=np.int64), np.zeros(d, np.int64))]
    eclasses = [ECLASS_HEX]
    for b in range(nt):
        embeds.append((_perm_matrix_for_type(d, b), e0.copy()))
        eclasses.append(ECLASS_SIMPLEX)
    return _from_embeddings(d, embeds, eclasses=eclasses)


def cmesh_rotated_pair() -> Cmesh:
    """2D: S_0 plus its point-reflected copy glued along face 0 into a
    parallelogram — the minimal domain whose gluing has sigma = -1, which
    exercises the reflected-axis branch of the element transform."""
    embeds = [
        (np.eye(2, dtype=np.int64), np.zeros(2, np.int64)),
        (-np.eye(2, dtype=np.int64), np.array([2, 1], np.int64)),
    ]
    return _from_embeddings(2, embeds)
