"""Coarse-mesh inter-tree connectivity for the forest (paper's stated extension).

The paper restricts Balance and Ghost to a single root simplex and names
multi-tree face connectivity as the open extension ("additional theoretical
work"); Holke's dissertation and t8code supply the missing layer: a *coarse
mesh* (cmesh) of K root simplices with per-face gluing data, plus an
element-level transform that re-expresses a boundary element's outside
face-neighbor in the neighbor tree's coordinate system.

Every tree's local frame is the reference root simplex ``S_0`` at scale
``2^MAXLEVEL``.  A gluing between two trees is an affine automorphism of the
Freudenthal (Kuhn) complex

    x  ->  M @ x + c,

where ``M`` is a *global-sign signed permutation* (``M = sigma * P`` with
``P`` a permutation matrix and ``sigma`` in {+1, -1}) and ``c`` an integer
translation.  Signed permutations with mixed signs do NOT preserve the Kuhn
triangulation (they flip the cube diagonal the types share), so they are
rejected; the global-sign family is exactly the lattice-isometry stabilizer
of the complex.  As everywhere in this repo the per-connection tables
(type map, vertex/face map) are *derived* from first principles — by
transforming the reference simplices and re-matching them — not transcribed.

Constructors for canonical domains:

  cmesh_single          one tree, all faces domain boundary
  cmesh_disconnected    K isolated trees (the legacy forest behaviour)
  cmesh_unit_cube       the d!-simplex Kuhn decomposition of one cube
                        (2 triangles in 2D, 6 tetrahedra in 3D)
  cmesh_brick           an n1 x n2 (x n3) array of Kuhn cubes, optionally
                        periodic per axis (wrap gluings are translations)
  cmesh_rotated_pair    2D: a triangle and its point-reflected copy glued
                        into a parallelogram (exercises sigma = -1)

The element-level entry point is ``transform_across_face(s, tree, face)``;
the batched backends reach the same math through
``BatchedOps.tree_transform`` so the forest hot loops stay bit-identical
across reference / jnp / pallas.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from .ops import get_ops
from .tables import MAXLEVEL, get_tables, root_face_planes
from .types import Simplex

__all__ = [
    "Cmesh",
    "cmesh_single",
    "cmesh_disconnected",
    "cmesh_unit_cube",
    "cmesh_brick",
    "cmesh_rotated_pair",
    "signed_perm_maps",
    "wrap_i32",
]


def wrap_i32(a) -> np.ndarray:
    """Two's-complement int32 wrap of an int64 array.

    Gluing translations can reach 2*2^MAXLEVEL (= 2^31 in 2D), one past the
    int32 range; since every *valid* transformed anchor lands back in
    [0, 2^MAXLEVEL), doing the transform arithmetic modulo 2^32 is exact —
    all integer backends (numpy, jnp, Pallas) wrap identically."""
    a = np.asarray(a, np.int64)
    return ((a + 2**31) % 2**32 - 2**31).astype(np.int32)


# ------------------------------------------------------------ derived pieces
@lru_cache(maxsize=None)
def _signed_perm_maps_cached(d: int, M_key: tuple) -> tuple:
    t = get_tables(d)
    nt = t.num_types
    M = np.asarray(M_key, np.int64)
    typemap = np.zeros(nt, np.int32)
    vertmap = np.zeros((nt, d + 1), np.int32)
    refs = [
        [tuple(r) for r in t.ref_verts[b].astype(np.int64).tolist()] for b in range(nt)
    ]
    for b in range(nt):
        W = t.ref_verts[b].astype(np.int64) @ M.T
        # The image cube's min corner: every type contains the cube's main
        # diagonal, so the min over image vertices is the image anchor.
        rel = [tuple(r) for r in (W - W.min(axis=0)).tolist()]
        for b2 in range(nt):
            if set(rel) == set(refs[b2]):
                typemap[b] = b2
                for a in range(d + 1):
                    vertmap[b, a] = refs[b2].index(rel[a])
                break
        else:
            raise ValueError(
                f"linear part {M.tolist()} is not an automorphism of the "
                f"Freudenthal complex (d={d}); only global-sign signed "
                "permutations are admissible"
            )
    return typemap, vertmap


def signed_perm_maps(d: int, M) -> tuple[np.ndarray, np.ndarray]:
    """(typemap, vertmap) of the complex automorphism with linear part `M`.

    typemap[b]    = type of the image of a type-b simplex.
    vertmap[b, a] = vertex index (in the image's reference numbering) that
                    vertex `a` of a type-b simplex maps to; since face f is
                    the face opposite vertex f, this is also the face map.
    Raises ValueError when `M` does not preserve the Kuhn triangulation.
    """
    M = np.asarray(M, np.int64)
    tm, vm = _signed_perm_maps_cached(d, tuple(map(tuple, M.tolist())))
    return tm.copy(), vm.copy()


def _perm_matrix_for_type(d: int, b: int) -> np.ndarray:
    """The unique permutation matrix mapping S_0 onto S_b (brute-forced;
    permutations act simply transitively on the Kuhn simplices of a cube)."""
    t = get_tables(d)
    target = set(map(tuple, t.ref_verts[b].astype(np.int64).tolist()))
    for perm in itertools.permutations(range(d)):
        P = np.zeros((d, d), np.int64)
        for a, pa in enumerate(perm):
            P[pa, a] = 1
        img = set(tuple(v) for v in (t.ref_verts[0].astype(np.int64) @ P.T).tolist())
        if img == target:
            return P
    raise AssertionError(f"no permutation maps S_0 to S_{b} (d={d})")


# ------------------------------------------------------------------- Cmesh
_Conn = dataclasses.make_dataclass(
    "Connection", ["tree", "face", "M", "c", "typemap", "facemap"]
)


@dataclasses.dataclass(eq=False)
class Cmesh:
    """K root simplices with per-face (neighbor tree, neighbor face,
    gluing transform) tables, all in each tree's local frame (root = S_0
    at scale 2^MAXLEVEL).

    face_tree[t, f] is -1 where face f of tree t is a *domain boundary*;
    otherwise the face is an *inter-tree face* and (face_M, face_c) map
    tree-t coordinates into the neighbor tree's frame.
    """

    d: int
    num_trees: int
    face_tree: np.ndarray      # (K, d+1) int32, -1 = domain boundary
    face_face: np.ndarray      # (K, d+1) int32, neighbor's face index
    face_M: np.ndarray         # (K, d+1, d, d) int32 gluing linear part
    face_c: np.ndarray         # (K, d+1, d) int64 gluing translation (scale 2^L)
    face_typemap: np.ndarray   # (K, d+1, d!) int32 type map under face_M
    face_facemap: np.ndarray   # (K, d+1, d!, d+1) int32 vertex/face map
    tree_embed_M: np.ndarray   # (K, d, d) int32 world embedding linear part
    tree_embed_o: np.ndarray   # (K, d) int64 world cube offset (unit scale)

    @property
    def L(self) -> int:
        return MAXLEVEL[self.d]

    def is_connected(self, tree: int, root_face: int) -> bool:
        """True where `root_face` of `tree` is an inter-tree face (False =
        domain boundary) — the split of the old is_root_boundary notion."""
        return bool(self.face_tree[tree, root_face] >= 0)

    def connection(self, tree: int, root_face: int):
        """The gluing record of an inter-tree face (None at the boundary)."""
        if not self.is_connected(tree, root_face):
            return None
        return _Conn(
            int(self.face_tree[tree, root_face]),
            int(self.face_face[tree, root_face]),
            self.face_M[tree, root_face],
            self.face_c[tree, root_face],
            self.face_typemap[tree, root_face],
            self.face_facemap[tree, root_face],
        )

    # ------------------------------------------------------------ geometry
    def root_face_of(self, s: Simplex, face) -> np.ndarray:
        """Which root facet contains face `face` of each element (vectorized
        plane tests against the derived facet equations); -1 when the face
        is interior.  `face` is a scalar or (n,) element-face index."""
        o = get_ops(self.d)
        coords = np.asarray(o.coordinates(s), np.int64)  # (n, d+1, d)
        face = np.broadcast_to(np.asarray(face, np.int32), coords.shape[:1])
        keep = np.arange(self.d + 1)[None, :] != face[:, None]  # (n, d+1)
        V = coords[keep].reshape(coords.shape[0], self.d, self.d)
        out = np.full(V.shape[0], -1, np.int32)
        for rf, (n_, r_) in enumerate(root_face_planes(self.d)):
            on = (V @ np.asarray(n_, np.int64) == (r_ << self.L)).all(axis=1)
            out[on] = rf
        return out

    # ----------------------------------------------------------- transform
    def transform_across_face(self, s: Simplex, tree: int, root_face: int,
                              bops=None) -> tuple[Simplex, int]:
        """Map elements `s` (in `tree`'s frame, lying just OUTSIDE its root
        across `root_face`) into the neighbor tree's frame: (s', tree').

        With `bops` (a BatchedOps), the batched backend does the math —
        reference / jnp / pallas stay bit-identical; otherwise the eager
        SimplexOps path runs."""
        tree, root_face = int(tree), int(root_face)
        t2 = int(self.face_tree[tree, root_face])
        if t2 < 0:
            raise ValueError(f"tree {tree} face {root_face} is a domain boundary")
        M = self.face_M[tree, root_face]
        c = self.face_c[tree, root_face]
        tm = self.face_typemap[tree, root_face]
        if bops is not None:
            return bops.tree_transform(s, M, c, tm), t2
        return get_ops(self.d).tree_transform(s, M, wrap_i32(c), tm), t2

    def world_vertices(self, tree: int, s: Simplex) -> np.ndarray:
        """(n, d+1, d) int64 vertex coordinates in the global world lattice
        (scale 2^L per unit cube) — the frame the brute-force test oracles
        match in."""
        o = get_ops(self.d)
        coords = np.asarray(o.coordinates(s), np.int64)
        M = self.tree_embed_M[tree].astype(np.int64)
        off = self.tree_embed_o[tree].astype(np.int64) << self.L
        return coords @ M.T + off


# ------------------------------------------------------------- construction
def _from_embeddings(d: int, embeds, box=None, periodic=None) -> Cmesh:
    """Derive the full connectivity from per-tree world embeddings
    ``world = M_t @ local + o_t * 2^L`` (unit-scale integer offsets `o_t`),
    by brute-force face matching in world coordinates — the same
    derive-don't-transcribe approach as `tables.py`."""
    t = get_tables(d)
    L = MAXLEVEL[d]
    nt = t.num_types
    K = len(embeds)
    periodic = tuple(periodic) if periodic is not None else (False,) * d
    rv0 = t.ref_verts[0].astype(np.int64)

    Ms, os_ = [], []
    world = []
    for M, o in embeds:
        M = np.asarray(M, np.int64)
        o = np.asarray(o, np.int64)
        signed_perm_maps(d, M)  # validates admissibility
        Ms.append(M)
        os_.append(o)
        world.append(rv0 @ M.T + o)

    # face registry in (wrapped) world coordinates at unit scale
    reg: dict[frozenset, list] = {}
    for tr in range(K):
        for f in range(d + 1):
            V = np.delete(world[tr], f, axis=0)
            w = np.zeros(d, np.int64)
            if box is not None:
                for k in range(d):
                    if periodic[k] and np.all(V[:, k] == box[k]):
                        w[k] = -box[k]
            key = frozenset(map(tuple, (V + w).tolist()))
            reg.setdefault(key, []).append((tr, f, w))

    face_tree = np.full((K, d + 1), -1, np.int32)
    face_face = np.zeros((K, d + 1), np.int32)
    face_M = np.tile(np.eye(d, dtype=np.int32), (K, d + 1, 1, 1))
    face_c = np.zeros((K, d + 1, d), np.int64)
    face_typemap = np.tile(np.arange(nt, dtype=np.int32), (K, d + 1, 1))
    face_facemap = np.tile(np.arange(d + 1, dtype=np.int32), (K, d + 1, nt, 1))

    for key, lst in reg.items():
        if len(lst) == 1:
            continue  # domain boundary
        if len(lst) != 2:
            raise ValueError(f"face {sorted(key)} shared by {len(lst)} trees")
        for (t1, f1, w1), (t2, f2, w2) in (lst, lst[::-1]):
            M = Ms[t2].T @ Ms[t1]
            c = (Ms[t2].T @ (os_[t1] - os_[t2] + w1 - w2)) << L
            # adjacent cubes keep |c| <= 2*2^L (the factor 2 needs a
            # reflected embedding, e.g. the rotated pair)
            assert np.abs(c).max(initial=0) <= (2 << L), "non-adjacent gluing"
            tm, vm = signed_perm_maps(d, M)
            face_tree[t1, f1] = t2
            face_face[t1, f1] = f2
            face_M[t1, f1] = M
            face_c[t1, f1] = c
            face_typemap[t1, f1] = tm
            face_facemap[t1, f1] = vm

    cm = Cmesh(
        d=d, num_trees=K,
        face_tree=face_tree, face_face=face_face,
        face_M=face_M, face_c=face_c,
        face_typemap=face_typemap, face_facemap=face_facemap,
        tree_embed_M=np.stack(Ms).astype(np.int32),
        tree_embed_o=np.stack(os_),
    )
    _check_connectivity(cm)
    return cm


def _check_connectivity(cm: Cmesh) -> None:
    """Construction-time proofs: every gluing is involutive (composes with
    its reverse to the identity) and maps the level-0 outside neighbor of
    the source root exactly onto the neighbor tree's root."""
    d, L = cm.d, cm.L
    o = get_ops(d)
    root = Simplex(
        jnp.zeros((1, d), jnp.int32), jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32)
    )
    for t1 in range(cm.num_trees):
        for f1 in range(d + 1):
            t2 = int(cm.face_tree[t1, f1])
            if t2 < 0:
                continue
            f2 = int(cm.face_face[t1, f1])
            assert int(cm.face_tree[t2, f2]) == t1 and int(cm.face_face[t2, f2]) == f1
            M12, c12 = cm.face_M[t1, f1].astype(np.int64), cm.face_c[t1, f1]
            M21, c21 = cm.face_M[t2, f2].astype(np.int64), cm.face_c[t2, f2]
            assert np.array_equal(M21 @ M12, np.eye(d, dtype=np.int64))
            assert np.array_equal(M21 @ c12 + c21, np.zeros(d, np.int64))
            # level-0: the outside neighbor across f1 IS the neighbor tree
            nb, dual = o.face_neighbor(root, f1)
            s2, tt = cm.transform_across_face(nb, t1, f1)
            assert tt == t2
            assert int(np.asarray(s2.stype)[0]) == 0 and int(np.asarray(s2.level)[0]) == 0
            assert np.array_equal(np.asarray(s2.anchor)[0], np.zeros(d, np.int32))
            bnb = int(np.asarray(nb.stype)[0])
            assert int(cm.face_facemap[t1, f1, bnb, int(np.asarray(dual)[0])]) == f2


def cmesh_disconnected(d: int, num_trees: int) -> Cmesh:
    """K isolated trees — every tree face is a domain boundary (the legacy
    forest behaviour, and the meaning of `Forest.cmesh is None`).  Trees are
    embedded two cubes apart along axis 0 so world coordinates stay unique."""
    e0 = np.zeros(d, np.int64)
    embeds = []
    for k in range(num_trees):
        o = e0.copy()
        o[0] = 2 * k
        embeds.append((np.eye(d, dtype=np.int64), o))
    return _from_embeddings(d, embeds)


def cmesh_single(d: int) -> Cmesh:
    """One root simplex, all faces domain boundary (the paper's setting)."""
    return cmesh_disconnected(d, 1)


def cmesh_brick(d: int, shape, periodic=None) -> Cmesh:
    """An array of ``prod(shape)`` Kuhn cubes, each split into d! trees
    (2 triangles / 6 tetrahedra); interior and (optionally, per-axis)
    periodic faces are glued, outer faces are domain boundary.

    Tree order: cells in C order (np.ndindex), types 0..d!-1 within a cell.
    """
    shape = tuple(int(s) for s in shape)
    assert len(shape) == d and all(s >= 1 for s in shape)
    nt = math.factorial(d)
    perms = [_perm_matrix_for_type(d, b) for b in range(nt)]
    embeds = []
    for cell in np.ndindex(shape):
        for b in range(nt):
            embeds.append((perms[b], np.asarray(cell, np.int64)))
    return _from_embeddings(d, embeds, box=shape, periodic=periodic)


def cmesh_unit_cube(d: int, periodic=None) -> Cmesh:
    """The Kuhn decomposition of one cube: 2 trees in 2D, 6 in 3D."""
    return cmesh_brick(d, (1,) * d, periodic=periodic)


def cmesh_rotated_pair() -> Cmesh:
    """2D: S_0 plus its point-reflected copy glued along face 0 into a
    parallelogram — the minimal domain whose gluing has sigma = -1, which
    exercises the reflected-axis branch of the element transform."""
    embeds = [
        (np.eye(2, dtype=np.int64), np.zeros(2, np.int64)),
        (-np.eye(2, dtype=np.int64), np.array([2, 1], np.int64)),
    ]
    return _from_embeddings(2, embeds)
