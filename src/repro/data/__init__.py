from .pipeline import DataPipeline, synthetic_batch
from .packing import pack_documents

__all__ = ["DataPipeline", "synthetic_batch", "pack_documents"]
