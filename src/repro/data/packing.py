"""Document packing with SFC-balanced rank assignment.

Variable-length documents are packed into fixed-length rows; the
document->DP-rank assignment uses the paper's weighted SFC partition
(`repro.core.placement.document_partition`), which balances token counts
across ranks in linear time while preserving corpus order (deterministic,
seekable, and locality-friendly for curriculum schedules).
"""

from __future__ import annotations

import numpy as np

from repro.core import placement


def pack_documents(doc_lengths: np.ndarray, seq_len: int, num_ranks: int,
                   pad_id: int = 0):
    """Returns (rank_of_doc, rows_per_rank, imbalance).

    rows_per_rank[r] = list of (doc_id, offset, length, row, col) placements:
    greedy first-fit packing of this rank's documents into seq_len rows.
    """
    import jax.numpy as jnp

    rank_of_doc, imb = placement.document_partition(
        jnp.asarray(doc_lengths, jnp.float32), num_ranks)
    rank_of_doc = np.asarray(rank_of_doc)
    rows_per_rank = []
    for r in range(num_ranks):
        docs = np.nonzero(rank_of_doc == r)[0]
        placements = []
        row, col = 0, 0
        for d in docs:
            remaining = int(doc_lengths[d])
            off = 0
            while remaining > 0:
                space = seq_len - col
                take = min(space, remaining)
                placements.append((int(d), off, take, row, col))
                col += take
                off += take
                remaining -= take
                if col == seq_len:
                    row, col = row + 1, 0
        rows_per_rank.append(placements)
    return rank_of_doc, rows_per_rank, float(imb)
