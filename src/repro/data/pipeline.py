"""Deterministic, seekable data pipeline.

Fault-tolerance contract: the batch for (seed, step, dp_rank) is a pure
function — restarting from a checkpoint at step k reproduces the exact token
stream with no data-loader state to save.  This is the property that makes
checkpoint/restart and elastic re-scaling exact (see runtime/trainer.py):
on a DP-size change, ranks re-derive their slice of the same global batch.

The generator is a counter-mode threefry hash (jax.random with a folded key),
so seeking to any step is O(1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, *, seed: int, step: int,
                    dp_rank: int = 0, dp_size: int = 1, seq_len: int | None = None):
    """The dp_rank-th slice of the global batch for `step`. Pure function."""
    S = seq_len or shape.seq_len
    B = shape.global_batch // dp_size
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), dp_rank)
    # zipf-ish marginal over the vocab: keeps losses realistic
    u = jax.random.uniform(key, (B, S), minval=1e-6, maxval=1.0)
    toks = jnp.minimum(
        (jnp.exp(-jnp.log(u) * 0.35) - 1.0).astype(jnp.int32), cfg.vocab_size - 1
    )
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16) * 0.1
    if cfg.family == "vlm":
        P = min(cfg.num_patches, S // 2)
        batch["patches"] = jax.random.normal(
            key, (B, P, cfg.d_model), jnp.bfloat16) * 0.1
        batch["tokens"] = toks[:, : S - P]
    return batch


@dataclasses.dataclass
class DataPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    seq_len: int | None = None

    def batch(self, step: int):
        return synthetic_batch(self.cfg, self.shape, seed=self.seed, step=step,
                               dp_rank=self.dp_rank, dp_size=self.dp_size,
                               seq_len=self.seq_len)

    def reshard(self, dp_rank: int, dp_size: int) -> "DataPipeline":
        """Elastic re-scale: same stream, new slice geometry."""
        assert self.shape.global_batch % dp_size == 0
        return dataclasses.replace(self, dp_rank=dp_rank, dp_size=dp_size)
