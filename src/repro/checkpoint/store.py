"""Checkpointing: sharded, atomic, async, elastic.

Layout:  <dir>/step_<k>/
            manifest.json           tree structure, shapes, dtypes, meta
            arr_<i>.npy             one file per leaf (gathered mode)
            arr_<i>.shard<j>.npy    per-device shards (sharded mode)

Properties required at scale and honored here:
  * atomicity — written to step_<k>.tmp, fsync'd, then renamed; a crash never
    leaves a half checkpoint visible;
  * async — `AsyncCheckpointer` snapshots device arrays to host, then writes
    on a background thread (training continues);
  * elastic restore — gathered-mode checkpoints restore onto ANY mesh/
    sharding (`restore_checkpoint(..., shardings=...)` re-slices); sharded
    mode re-assembles from shard files via make_array_from_callback;
  * bf16-safe via ml_dtypes.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy cannot round-trip ml_dtypes (bf16/f8) through .npy: store raw views
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _to_disk(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _from_disk(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path, tree, *, step: int, sharded: bool = False,
                    extra_meta: dict | None = None):
    """Write atomically to <path>/step_<step>."""
    path = Path(path)
    final = path / f"step_{step}"
    tmp = path / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(jax.tree_util.tree_structure(tree)),  # structure check only
        "num_leaves": len(leaves),
        "sharded": sharded,
        "leaves": [],
        "meta": extra_meta or {},
    }
    for i, leaf in enumerate(leaves):
        arr = leaf
        entry = {"index": i, "dtype": str(np.asarray(arr).dtype), "shape": list(arr.shape)}
        if sharded and isinstance(arr, jax.Array) and len(arr.addressable_shards) > 1:
            entry["files"] = []
            for sh in arr.addressable_shards:
                fn = f"arr_{i}.shard{sh.replica_id}_{'_'.join(map(str, [idx.start or 0 for idx in sh.index]))}.npy"
                data, dt = _to_disk(np.asarray(sh.data))
                np.save(tmp / fn, data)
                entry["dtype"] = dt
                entry["files"].append(
                    {"file": fn,
                     "index": [[idx.start or 0, idx.stop if idx.stop is not None else s]
                               for idx, s in zip(sh.index, arr.shape)]})
        else:
            fn = f"arr_{i}.npy"
            data, dt = _to_disk(np.asarray(arr))
            np.save(tmp / fn, data)
            entry["file"] = fn
            entry["dtype"] = dt
        manifest["leaves"].append(entry)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path) -> int | None:
    path = Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(path, tree_like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `tree_like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of shardings —
    enables restore onto a different mesh than the one that saved."""
    path = Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = path / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = _flatten(tree_like)
    shard_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    assert len(leaves_like) == manifest["num_leaves"], "tree structure changed"
    out = []
    for i, (like, shd) in enumerate(zip(leaves_like, shard_leaves)):
        entry = manifest["leaves"][i]
        if "file" in entry:
            arr = _from_disk(np.load(d / entry["file"]), entry["dtype"])
        else:
            dtype = (np.dtype(getattr(ml_dtypes, entry["dtype"]))
                     if entry["dtype"] in _EXOTIC else np.dtype(entry["dtype"]))
            arr = np.zeros(entry["shape"], dtype)
            for f in entry["files"]:
                sl = tuple(slice(a, b) for a, b in f["index"])
                arr[sl] = _from_disk(np.load(d / f["file"]), entry["dtype"])
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, path):
        self.path = Path(path)
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error:
            raise self.last_error

    def save(self, tree, *, step: int, **kw):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def _write():
            try:
                save_checkpoint(self.path, host_tree, step=step, **kw)
            except Exception as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
