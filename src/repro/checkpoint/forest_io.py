"""Forest checkpointing: packed at-rest blobs + partition markers, elastic.

A forest checkpoint persists the paper's Remark 20 low-memory element
encoding (`repro.core.types.pack`: int32 coords + int8 level + int8 type =
10/14 bytes per element) for the *global* leaf sequence in (tree, TM-index)
order, alongside the partition markers of the rank layout that wrote it.
Restore is elastic: loading onto the same rank count reproduces the saved
partition exactly (marker split); loading onto any other rank count
re-splits the global SFC sequence into equal contiguous runs — the same
invariant `new_uniform` establishes — so a 4-rank run restores onto 2
ranks (or 2 onto 4) and passes `validate()` unchanged.

Integrity is end to end: `save_forest` records a CRC32 per payload column
in the manifest, and `load_forest` re-hashes every restored column,
cross-checks the element count, and runs the global `forest.validate`
oracle on the restored sequence before slicing — a corrupted, truncated,
or bit-flipped checkpoint raises `CheckpointIntegrityError`, never a
silently wrong forest.  This is what makes the checkpoint a safe
`recover()` target after a rank failure.

Storage goes through `repro.checkpoint.store` (atomic rename, manifest,
optional async) so forest checkpoints live next to model checkpoints.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.core import forest as forest_mod
from repro.core.cmesh import Cmesh
from repro.core.comm import Comm
from repro.core.errors import CheckpointIntegrityError
from repro.core.forest import Forest, partition_markers
from repro.core.placement import target_ranks_np
from repro.core.types import Simplex, pack

from .store import restore_checkpoint, save_checkpoint

__all__ = ["save_forest", "load_forest"]


def _column_crc(arr) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _gather_global(forests: list[Forest], comm: Comm):
    """Concatenate the per-rank SoA arrays into the global (tree, TM-index)
    sequence — rank-major order IS global SFC order (the partition
    invariant), so a plain allgather+concat is exact."""
    per_local = [
        (f.anchor.astype(np.int32), f.level.astype(np.int8),
         f.stype.astype(np.int8), f.tree.astype(np.int32))
        for f in forests
    ]
    parts = comm.allgather(per_local)  # one (anchor, level, stype, tree) per rank
    A = np.concatenate([p[0] for p in parts])
    L = np.concatenate([p[1] for p in parts])
    B = np.concatenate([p[2] for p in parts])
    T = np.concatenate([p[3] for p in parts])
    return A, L, B, T


def save_forest(path, forests: list[Forest], comm: Comm, *, step: int = 0):
    """Persist the forest as packed blobs + partition markers.

    Collective: every rank participates in the gather; the process hosting
    global rank 0 writes (under `SimComm` that is the only process).  The
    manifest carries a CRC32 per payload column so `load_forest` can prove
    the blobs it reads back are the blobs that were written."""
    f0 = forests[0]
    with comm.phase("checkpoint"):
        anchor, level, stype, tree = _gather_global(forests, comm)
        mt, mk = partition_markers(forests, comm)
    blob = pack(Simplex(anchor, level.astype(np.int32), stype.astype(np.int32)))
    tree_payload = {
        "anchor": blob["anchor"],
        "level": blob["level"],
        "stype": blob["stype"],
        "tree": tree,
        "marker_tree": mt,
        # uint64 keys at rest as two uint32 words: the checkpoint store
        # round-trips leaves through jnp, which is 32-bit by default
        "marker_key_hi": (mk >> np.uint64(32)).astype(np.uint32),
        "marker_key_lo": (mk & np.uint64(0xFFFFFFFF)).astype(np.uint32),
    }
    meta = {
        "kind": "forest",
        "d": int(f0.d),
        "num_trees": int(f0.num_trees),
        "num_ranks": int(comm.size),
        "count": int(len(level)),
        "crc32": {k: _column_crc(v) for k, v in tree_payload.items()},
    }
    if 0 in comm.local_ranks:
        out = save_checkpoint(path, tree_payload, step=step, extra_meta=meta)
    else:  # pragma: no cover - distributed hosting writes on rank 0 only
        out = None
    comm.barrier()
    return out


def load_forest(path, comm: Comm, *, step: int | None = None,
                cmesh: Cmesh | None = None,
                weights: np.ndarray | None = None,
                verify: bool = True) -> list[Forest]:
    """Restore a forest checkpoint onto `comm` — elastically.

    Same rank count as the writer: the saved markers reproduce the original
    partition bit for bit.  Different rank count: the global SFC sequence is
    re-split into `comm.size` equal contiguous runs.  With `weights` (one
    nonnegative float per GLOBAL element, in the saved SFC order) the
    restore splits by the paper's weighted Partition rule instead —
    overriding the marker split even at equal rank count, so a restore can
    land directly on the rebalanced layout `forest.repartition` would reach
    (identical boundaries: both routes go through
    `placement.target_ranks_np` over the same prefix sums).  Returns one
    `Forest` per local rank (all of them under `SimComm`).

    With `verify` (the default) every restored column is CRC32-checked
    against the manifest, the element count is cross-checked, and the
    restored GLOBAL sequence must pass `forest.validate` (strict SFC
    order, inside-root anchors, exact coverage) before it is sliced onto
    the ranks; any mismatch — including an unreadable or truncated blob —
    raises `CheckpointIntegrityError`."""
    like = {k: np.zeros(0, np.uint8) for k in
            ("anchor", "level", "stype", "tree", "marker_tree",
             "marker_key_hi", "marker_key_lo")}
    try:
        tree_payload, manifest = restore_checkpoint(path, like, step=step)
    except CheckpointIntegrityError:
        raise
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointIntegrityError(
            f"unreadable forest checkpoint at {path!s}: {e}") from e
    meta = manifest.get("meta", {})
    if meta.get("kind") != "forest":
        raise CheckpointIntegrityError(
            f"not a forest checkpoint: kind={meta.get('kind')!r}")
    if verify:
        stored = meta.get("crc32")
        if stored is not None:
            for k, v in tree_payload.items():
                want = stored.get(k)
                got = _column_crc(v)
                if want is None or int(want) != got:
                    raise CheckpointIntegrityError(
                        f"checkpoint column {k!r} failed its integrity "
                        f"check: stored crc32={want}, recomputed {got} — "
                        f"the blob was corrupted or truncated at rest")
    d, num_trees = int(meta["d"]), int(meta["num_trees"])
    anchor = np.asarray(tree_payload["anchor"], np.int32).reshape(-1, d)
    level = np.asarray(tree_payload["level"], np.int32).reshape(-1)
    stype = np.asarray(tree_payload["stype"], np.int32).reshape(-1)
    tree = np.asarray(tree_payload["tree"], np.int32).reshape(-1)
    N = len(level)
    if verify:
        want_n = int(meta.get("count", N))
        if not (len(anchor) == len(stype) == len(tree) == N == want_n):
            raise CheckpointIntegrityError(
                f"checkpoint element counts disagree: manifest says "
                f"{want_n}, columns hold "
                f"{(len(anchor), N, len(stype), len(tree))}")
        # the restored GLOBAL sequence must be a valid forest before any
        # rank-local slicing — hosting-independent, catches reordered or
        # semantically corrupted (but checksum-consistent) payloads too
        gf = forest_mod._empty(d, num_trees, 0, 1, cmesh).replace_elements(
            anchor, level, stype, tree)
        try:
            ok = forest_mod.validate([gf])
        except Exception as e:
            raise CheckpointIntegrityError(
                f"restored forest failed validate(): {e}") from e
        if not ok:
            raise CheckpointIntegrityError(
                "restored forest failed validate(): the checkpoint decodes "
                "but is not a well-formed global SFC sequence (order, "
                "overlap, root containment, or coverage violated)")
    P = comm.size
    if weights is not None:
        w = np.asarray(weights, np.float64).reshape(-1)
        if len(w) != N:
            raise ValueError(
                f"need one weight per saved element: {len(w)} vs {N}")
        t = target_ranks_np(np.cumsum(w) - w / 2.0, P, float(w.sum()))
        bounds = [int(b) for b in np.searchsorted(t, np.arange(P + 1))]
    elif P == int(meta["num_ranks"]):
        # exact restore: split at the saved markers
        mt = np.asarray(tree_payload["marker_tree"], np.int64).reshape(-1)
        mk = (np.asarray(tree_payload["marker_key_hi"], np.uint64).reshape(-1)
              << np.uint64(32)) | np.asarray(
                  tree_payload["marker_key_lo"], np.uint64).reshape(-1)
        s = Simplex(anchor, level, stype)
        keys = forest_mod.get_batch_ops(d).morton_key_np(s)
        # first global index whose (tree, key) lex->= marker_r
        bounds = []
        for r in range(P):
            t_r, k_r = int(mt[r]), np.uint64(mk[r])
            lo = int(np.searchsorted(tree, t_r))
            hi = int(np.searchsorted(tree, t_r + 1))
            bounds.append(lo + int(np.searchsorted(keys[lo:hi], k_r)))
        bounds.append(N)
    else:
        bounds = [(N * r) // P for r in range(P + 1)]
    out = []
    for i, g in enumerate(comm.local_ranks):
        a, b = bounds[g], bounds[g + 1]
        f = forest_mod._empty(d, num_trees, g, P, cmesh)
        out.append(f.replace_elements(anchor[a:b], level[a:b], stype[a:b], tree[a:b]))
    return out
