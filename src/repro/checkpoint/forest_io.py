"""Forest checkpointing: packed at-rest blobs + partition markers, elastic.

A forest checkpoint persists the paper's Remark 20 low-memory element
encoding (`repro.core.types.pack`: int32 coords + int8 level + int8 type =
10/14 bytes per element for simplices; hex meshes drop the type column —
9/13 bytes) for the *global* leaf sequence in (tree, TM-index) order,
alongside the partition markers of the rank layout that wrote it.  The
manifest records the mesh's element class ("eclass": 0 simplex — implied
when absent, so pre-eclass checkpoints restore unchanged — 1 hex, or
"mixed" with a per-tree class column); restoring a non-simplex checkpoint
requires passing the matching `cmesh`, which carries the per-tree classes
the keys and validation dispatch on.
Restore is elastic: loading onto the same rank count reproduces the saved
partition exactly (marker split); loading onto any other rank count
re-splits the global SFC sequence into equal contiguous runs — the same
invariant `new_uniform` establishes — so a 4-rank run restores onto 2
ranks (or 2 onto 4) and passes `validate()` unchanged.

Integrity is end to end: `save_forest` records a CRC32 per payload column
in the manifest, and `load_forest` re-hashes every restored column,
cross-checks the element count, and runs the global `forest.validate`
oracle on the restored sequence before slicing — a corrupted, truncated,
or bit-flipped checkpoint raises `CheckpointIntegrityError`, never a
silently wrong forest.  This is what makes the checkpoint a safe
`recover()` target after a rank failure.

Storage goes through `repro.checkpoint.store` (atomic rename, manifest,
optional async) so forest checkpoints live next to model checkpoints.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.core import forest as forest_mod
from repro.core.cmesh import Cmesh
from repro.core.comm import Comm
from repro.core.errors import CheckpointIntegrityError
from repro.core.forest import Forest, partition_markers
from repro.core.placement import target_ranks_np
from repro.core.types import ECLASS_HEX, ECLASS_SIMPLEX, Simplex, pack

from .store import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["save_forest", "load_forest"]


def _column_crc(arr) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _gather_global(forests: list[Forest], comm: Comm):
    """Concatenate the per-rank SoA arrays into the global (tree, TM-index)
    sequence — rank-major order IS global SFC order (the partition
    invariant), so a plain allgather+concat is exact."""
    per_local = [
        (f.anchor.astype(np.int32), f.level.astype(np.int8),
         f.stype.astype(np.int8), f.tree.astype(np.int32))
        for f in forests
    ]
    parts = comm.allgather(per_local)  # one (anchor, level, stype, tree) per rank
    A = np.concatenate([p[0] for p in parts])
    L = np.concatenate([p[1] for p in parts])
    B = np.concatenate([p[2] for p in parts])
    T = np.concatenate([p[3] for p in parts])
    return A, L, B, T


def save_forest(path, forests: list[Forest], comm: Comm, *, step: int = 0):
    """Persist the forest as packed blobs + partition markers.

    Collective: every rank participates in the gather; the process hosting
    global rank 0 writes (under `SimComm` that is the only process).  The
    manifest carries a CRC32 per payload column so `load_forest` can prove
    the blobs it reads back are the blobs that were written."""
    f0 = forests[0]
    cm = f0.cmesh
    ecs = (ECLASS_SIMPLEX,) if cm is None else cm.eclasses
    with comm.phase("checkpoint"):
        anchor, level, stype, tree = _gather_global(forests, comm)
        mt, mk = partition_markers(forests, comm)
    if ecs == (ECLASS_HEX,):
        # pure-hex mesh: the at-rest encoding has no type column (Remark 20
        # analogue: 4d+1 bytes per element)
        blob = pack(Simplex(anchor, level.astype(np.int32),
                            stype.astype(np.int32)), eclass=ECLASS_HEX)
        eclass_meta = ECLASS_HEX
    else:
        # simplex (byte-identical to the pre-eclass layout) or mixed (the
        # type column is only meaningful on simplex rows; hex rows are 0)
        blob = pack(Simplex(anchor, level.astype(np.int32),
                            stype.astype(np.int32)))
        eclass_meta = ECLASS_SIMPLEX if len(ecs) == 1 else "mixed"
    tree_payload = {
        "anchor": blob["anchor"],
        "level": blob["level"],
        "tree": tree,
        "marker_tree": mt,
        # uint64 keys at rest as two uint32 words: the checkpoint store
        # round-trips leaves through jnp, which is 32-bit by default
        "marker_key_hi": (mk >> np.uint64(32)).astype(np.uint32),
        "marker_key_lo": (mk & np.uint64(0xFFFFFFFF)).astype(np.uint32),
    }
    if "stype" in blob:
        tree_payload["stype"] = blob["stype"]
    if eclass_meta == "mixed":
        # the per-tree class column lets the loader cross-check the cmesh
        tree_payload["tree_eclass"] = np.asarray(cm.tree_eclass, np.int32)
    meta = {
        "kind": "forest",
        "d": int(f0.d),
        "num_trees": int(f0.num_trees),
        "num_ranks": int(comm.size),
        "count": int(len(level)),
        "eclass": eclass_meta,
        "crc32": {k: _column_crc(v) for k, v in tree_payload.items()},
    }
    if 0 in comm.local_ranks:
        out = save_checkpoint(path, tree_payload, step=step, extra_meta=meta)
    else:  # pragma: no cover - distributed hosting writes on rank 0 only
        out = None
    comm.barrier()
    return out


def load_forest(path, comm: Comm, *, step: int | None = None,
                cmesh: Cmesh | None = None,
                weights: np.ndarray | None = None,
                verify: bool = True) -> list[Forest]:
    """Restore a forest checkpoint onto `comm` — elastically.

    Same rank count as the writer: the saved markers reproduce the original
    partition bit for bit.  Different rank count: the global SFC sequence is
    re-split into `comm.size` equal contiguous runs.  With `weights` (one
    nonnegative float per GLOBAL element, in the saved SFC order) the
    restore splits by the paper's weighted Partition rule instead —
    overriding the marker split even at equal rank count, so a restore can
    land directly on the rebalanced layout `forest.repartition` would reach
    (identical boundaries: both routes go through
    `placement.target_ranks_np` over the same prefix sums).  Returns one
    `Forest` per local rank (all of them under `SimComm`).

    With `verify` (the default) every restored column is CRC32-checked
    against the manifest, the element count is cross-checked, and the
    restored GLOBAL sequence must pass `forest.validate` (strict SFC
    order, inside-root anchors, exact coverage) before it is sliced onto
    the ranks; any mismatch — including an unreadable or truncated blob —
    raises `CheckpointIntegrityError`."""
    # peek the manifest for the element-class schema first: a hex
    # checkpoint has no "stype" column, a mixed one adds "tree_eclass",
    # and the restore structure must match leaf for leaf.  Pre-eclass
    # manifests carry no "eclass" key — they are simplex checkpoints.
    eclass_meta = _peek_eclass(path, step)
    cols = ["anchor", "level", "tree", "marker_tree",
            "marker_key_hi", "marker_key_lo"]
    if eclass_meta != ECLASS_HEX:
        cols.insert(2, "stype")
    if eclass_meta == "mixed":
        cols.append("tree_eclass")
    like = {k: np.zeros(0, np.uint8) for k in cols}
    try:
        tree_payload, manifest = restore_checkpoint(path, like, step=step)
    except CheckpointIntegrityError:
        raise
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointIntegrityError(
            f"unreadable forest checkpoint at {path!s}: {e}") from e
    meta = manifest.get("meta", {})
    if meta.get("kind") != "forest":
        raise CheckpointIntegrityError(
            f"not a forest checkpoint: kind={meta.get('kind')!r}")
    if verify:
        stored = meta.get("crc32")
        if stored is not None:
            for k, v in tree_payload.items():
                want = stored.get(k)
                got = _column_crc(v)
                if want is None or int(want) != got:
                    raise CheckpointIntegrityError(
                        f"checkpoint column {k!r} failed its integrity "
                        f"check: stored crc32={want}, recomputed {got} — "
                        f"the blob was corrupted or truncated at rest")
    d, num_trees = int(meta["d"]), int(meta["num_trees"])
    anchor = np.asarray(tree_payload["anchor"], np.int32).reshape(-1, d)
    level = np.asarray(tree_payload["level"], np.int32).reshape(-1)
    if "stype" in tree_payload:
        stype = np.asarray(tree_payload["stype"], np.int32).reshape(-1)
    else:  # hex checkpoint: no type column at rest, the lane is all-zero
        stype = np.zeros(len(level), np.int32)
    tree = np.asarray(tree_payload["tree"], np.int32).reshape(-1)
    N = len(level)
    if eclass_meta != ECLASS_SIMPLEX:
        # keys and root-containment validation dispatch on per-tree classes,
        # which live in the cmesh — a class-less restore would silently run
        # hex leaves through the simplex curve
        if cmesh is None:
            raise CheckpointIntegrityError(
                f"checkpoint at {path!s} holds a non-simplex mesh "
                f"(eclass={eclass_meta!r}); pass the matching cmesh to "
                f"load_forest")
        if eclass_meta == "mixed":
            saved_te = np.asarray(
                tree_payload["tree_eclass"], np.int32).reshape(-1)
            if not np.array_equal(saved_te, np.asarray(cmesh.tree_eclass)):
                raise CheckpointIntegrityError(
                    "checkpoint per-tree element classes disagree with the "
                    "given cmesh")
        elif tuple(cmesh.eclasses) != (ECLASS_HEX,):
            raise CheckpointIntegrityError(
                f"hex checkpoint restored against a cmesh with classes "
                f"{cmesh.eclasses}")
    if verify:
        want_n = int(meta.get("count", N))
        if not (len(anchor) == len(stype) == len(tree) == N == want_n):
            raise CheckpointIntegrityError(
                f"checkpoint element counts disagree: manifest says "
                f"{want_n}, columns hold "
                f"{(len(anchor), N, len(stype), len(tree))}")
        # the restored GLOBAL sequence must be a valid forest before any
        # rank-local slicing — hosting-independent, catches reordered or
        # semantically corrupted (but checksum-consistent) payloads too
        gf = forest_mod._empty(d, num_trees, 0, 1, cmesh).replace_elements(
            anchor, level, stype, tree)
        try:
            ok = forest_mod.validate([gf])
        except Exception as e:
            raise CheckpointIntegrityError(
                f"restored forest failed validate(): {e}") from e
        if not ok:
            raise CheckpointIntegrityError(
                "restored forest failed validate(): the checkpoint decodes "
                "but is not a well-formed global SFC sequence (order, "
                "overlap, root containment, or coverage violated)")
    P = comm.size
    if weights is not None:
        w = np.asarray(weights, np.float64).reshape(-1)
        if len(w) != N:
            raise ValueError(
                f"need one weight per saved element: {len(w)} vs {N}")
        t = target_ranks_np(np.cumsum(w) - w / 2.0, P, float(w.sum()))
        bounds = [int(b) for b in np.searchsorted(t, np.arange(P + 1))]
    elif P == int(meta["num_ranks"]):
        # exact restore: split at the saved markers
        mt = np.asarray(tree_payload["marker_tree"], np.int64).reshape(-1)
        mk = (np.asarray(tree_payload["marker_key_hi"], np.uint64).reshape(-1)
              << np.uint64(32)) | np.asarray(
                  tree_payload["marker_key_lo"], np.uint64).reshape(-1)
        # per-class key recompute (replace_elements dispatches per tree class)
        keys = forest_mod._empty(d, num_trees, 0, 1, cmesh).replace_elements(
            anchor, level, stype, tree).keys
        # first global index whose (tree, key) lex->= marker_r
        bounds = []
        for r in range(P):
            t_r, k_r = int(mt[r]), np.uint64(mk[r])
            lo = int(np.searchsorted(tree, t_r))
            hi = int(np.searchsorted(tree, t_r + 1))
            bounds.append(lo + int(np.searchsorted(keys[lo:hi], k_r)))
        bounds.append(N)
    else:
        bounds = [(N * r) // P for r in range(P + 1)]
    out = []
    for i, g in enumerate(comm.local_ranks):
        a, b = bounds[g], bounds[g + 1]
        f = forest_mod._empty(d, num_trees, g, P, cmesh)
        out.append(f.replace_elements(anchor[a:b], level[a:b], stype[a:b], tree[a:b]))
    return out


def _peek_eclass(path, step):
    """The "eclass" meta of the checkpoint's manifest (0 when absent —
    pre-eclass checkpoints are simplex) without restoring any column."""
    import json
    from pathlib import Path

    p = Path(path)
    if step is None:
        step = latest_step(p)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    mf = p / f"step_{step}" / "manifest.json"
    try:
        meta = json.loads(mf.read_text()).get("meta", {})
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointIntegrityError(
            f"unreadable forest checkpoint manifest at {mf}: {e}") from e
    return meta.get("eclass", ECLASS_SIMPLEX)
