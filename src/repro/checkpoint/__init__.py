from .store import (latest_step, restore_checkpoint, save_checkpoint,
                    AsyncCheckpointer)
from .forest_io import load_forest, save_forest

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "save_forest", "load_forest"]
