"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from results/dryrun.

    PYTHONPATH=src python -m repro.launch.report
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
RESULTS = ROOT / "results" / "dryrun"

HINTS = {
    "memory": "fuse attention (Pallas flash kernel keeps scores in VMEM) / "
              "shard long-lived activations over 'model' (sequence parallel)",
    "collective": "reduce-scatter instead of all-reduce (sequence-parallel "
                  "residuals), shard_map the MoE dispatch into all-to-all, "
                  "int8 cross-pod gradient reduction",
    "compute": "raise per-device batch or quantize; compute-bound is the "
               "target regime",
}


def _gb(x):
    return f"{x / 2**30:.2f}"


def load_cells():
    out = []
    for p in sorted(RESULTS.glob("*.json")):
        j = json.loads(p.read_text())
        out.append(j)
    return out


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | status | compile s | peak GB/dev | params GB/dev | collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for j in cells:
        if j.get("status") == "skip":
            lines.append(f"| {j['arch']} | {j['shape']} | {j['mesh']} | SKIP ({j['why'][:40]}...) | | | | |")
            continue
        if j.get("status") != "ok":
            lines.append(f"| {j['arch']} | {j['shape']} | {j['mesh']} | ERROR | | | | |")
            continue
        mem = j.get("memory", {})
        peak = mem.get("peak_bytes_per_device")
        cc = j.get("hlo_cost", {}).get("collective_counts", {})
        cstr = " ".join(f"{k.split('-')[-1][:3]}:{int(v)}" for k, v in sorted(cc.items()))
        lines.append(
            f"| {j['arch']} | {j['shape']} | {j['mesh']} | ok | {j.get('compile_s', '')} "
            f"| {_gb(peak) if peak else '?'} | {_gb(j.get('analytic_param_bytes_per_device', 0))} "
            f"| {cstr} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS | useful/HLO flops | roofline frac | frac w/ fused attn |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for j in cells:
        if j.get("mesh") != "single" or j.get("status") != "ok":
            continue
        r = j["roofline"]
        rf = j.get("roofline_fused_attention", {})
        lines.append(
            f"| {j['arch']} | {j['shape']} | {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | **{r['bottleneck']}** "
            f"| {j['model_flops_global']:.3g} | {j['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {rf.get('roofline_fraction', float('nan')):.3f} |")
    lines.append("")
    lines.append("Per-bottleneck lever (applied in §Perf): ")
    for k, v in HINTS.items():
        lines.append(f"- **{k}**: {v}")
    return "\n".join(lines)


def skip_table(cells):
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for j in cells:
        if j.get("status") == "skip" and (j["arch"], j["shape"]) not in seen:
            seen.add((j["arch"], j["shape"]))
            lines.append(f"| {j['arch']} | {j['shape']} | {j['why']} |")
    return "\n".join(lines)


def inject(md_path: Path, tag: str, content: str):
    begin, end = f"<!-- BEGIN {tag} -->", f"<!-- END {tag} -->"
    text = md_path.read_text() if md_path.exists() else ""
    if begin not in text:
        text += f"\n{begin}\n{end}\n"
    pre = text.split(begin)[0]
    post = text.split(end)[1] if end in text else ""
    md_path.write_text(pre + begin + "\n" + content + "\n" + end + post)


def main():
    cells = load_cells()
    md = ROOT / "EXPERIMENTS.md"
    inject(md, "DRYRUN_TABLE", dryrun_table(cells))
    inject(md, "ROOFLINE_TABLE", roofline_table(cells))
    inject(md, "SKIP_TABLE", skip_table(cells))
    n_ok = sum(1 for c in cells if c.get("status") == "ok")
    n_skip = sum(1 for c in cells if c.get("status") == "skip")
    n_err = sum(1 for c in cells if c.get("status") not in ("ok", "skip"))
    print(f"report: {n_ok} ok, {n_skip} skip, {n_err} error -> {md}")


if __name__ == "__main__":
    main()
