"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis is a
second data-parallel dimension whose collectives cross the inter-pod DCN
links (gradient all-reduce only; see repro.optim.compression for the int8
cross-pod reduction).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    dp = max(1, n // model_parallel)
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec_axes(mesh, batch: int):
    """Largest prefix of the DP axes that evenly divides `batch` (possibly
    none — e.g. long_500k has global_batch=1)."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        size = mesh.shape[a]
        if batch % (prod * size) == 0:
            axes.append(a)
            prod *= size
    return tuple(axes)
