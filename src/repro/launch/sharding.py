"""Parameter / optimizer / batch / cache sharding rules.

Path-name-based GSPMD rules: every parameter leaf name maps to a
PartitionSpec over ('model', fsdp-axis).  Conventions (see models/lm.py):

  TP ('model'):   attention heads (wq/wk/wv in, wo out), FFN hidden
                  (w_gate/w_up in, w_down out), vocab (tok_embed rows /
                  out_head cols), experts (leading E dim = expert parallel),
                  MLA up-projections, RG-LRU width.
  FSDP ('data'):  the other large dim of each matrix when cfg.fsdp — ZeRO-3
                  style; GSPMD inserts the all-gathers per layer.
  Replicated:     norms, scalars, routers, small SSM tensors.

Stacked layer dims (leading axis from lax.scan stacking) get None prepended.
Divisibility is checked against the mesh; dims that do not divide fall back
to replication (e.g. mamba2's fused in_proj, kv heads < model size).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from .mesh import batch_spec_axes, dp_axes

# leaf name -> (axes template applied to the LAST ndim dims)
# 'tp' = model axis, 'fsdp' = data axis (if cfg.fsdp), None = replicate
_RULES: dict[str, tuple] = {
    "tok_embed": ("tp", "fsdp"),
    "out_head": ("fsdp", "tp"),
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "router": (None, None),
    # expert parallel when E divides the model axis; otherwise fall back to
    # tensor-parallel inside each expert (mixtral: E=8 < model=16)
    "experts_gate": ("tp", "fsdp", None),
    "experts_up": ("tp", "fsdp", None),
    "experts_down": ("tp", None, "fsdp"),
    "shared_gate": (None, "fsdp", "tp"),
    "shared_up": (None, "fsdp", "tp"),
    "shared_down": (None, "tp", "fsdp"),
    "q_down": ("fsdp", None),
    "q_up": (None, "tp"),
    "kv_down": ("fsdp", None),
    "k_up": (None, "tp"),
    "v_up": (None, "tp"),
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "gate_proj": ("fsdp", "tp"),
    "w_r": (None, "tp"),
    "w_i": (None, "tp"),
    "conv_w": (None, "tp"),
    "mtp_proj": ("fsdp", None),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return str(p.key)
    return ""


_EXPERT_FALLBACK = {
    # when num_experts doesn't divide the model axis: TP inside each expert
    "experts_gate": (None, "fsdp", "tp"),
    "experts_up": (None, "fsdp", "tp"),
    "experts_down": (None, "tp", "fsdp"),
}


def param_pspec(cfg: ModelConfig, mesh, path, leaf) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    tmpl = _RULES.get(name)
    if tmpl is None or leaf.ndim == 0:
        return P()
    if cfg.parallelism == "fsdp_sp":
        # pure FSDP: shard the first dim that divides over ALL mesh axes
        all_ax = tuple(mesh.axis_names)
        total = 1
        for a in all_ax:
            total *= mesh.shape[a]
        ndim = leaf.ndim
        k = len(tmpl)
        for i in range(k):
            dim = ndim - k + i
            if dim >= 0 and tmpl[i] is not None and shape[dim] % total == 0:
                axes = [None] * ndim
                axes[dim] = all_ax
                return P(*axes)
        return P()
    if name in _EXPERT_FALLBACK:
        # expert dim is dim -3 (after the stacked layer dim)
        e_dim = shape[leaf.ndim - 3]
        if e_dim % mesh.shape.get("model", 1) != 0:
            tmpl = _EXPERT_FALLBACK[name]
    tp_size = mesh.shape.get("model", 1)
    # FSDP spans every data-parallel axis present (pod + data on multi-pod:
    # a 671B model's states only fit when sharded across all 512 chips).
    fsdp_ax = dp_axes(mesh) if cfg.fsdp else ()
    fsdp_size = 1
    for a in fsdp_ax:
        fsdp_size *= mesh.shape[a]
    ndim = leaf.ndim
    k = len(tmpl)
    axes: list = [None] * ndim
    for i, a in enumerate(tmpl):
        dim = ndim - k + i
        if dim < 0 or a is None:
            continue
        if a == "tp" and tp_size > 1 and shape[dim] % tp_size == 0:
            axes[dim] = "model"
        elif a == "fsdp" and fsdp_ax and shape[dim] % fsdp_size == 0:
            axes[dim] = fsdp_ax if len(fsdp_ax) > 1 else fsdp_ax[0]
    return P(*axes)


def params_pspecs(cfg: ModelConfig, mesh, params_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(cfg, mesh, path, leaf), params_shapes
    )


def opt_state_pspecs(cfg: ModelConfig, mesh, pspecs, params_shapes, optimizer: str):
    """Mirror init_opt_state: adamw states share the param spec; adafactor
    keeps factored (row, col) states with the matching sub-specs."""
    from repro.optim.optimizers import OptState

    if optimizer == "adamw":
        return OptState(P(), jax.tree.map(lambda s: s, pspecs),
                        jax.tree.map(lambda s: s, pspecs))

    mu = jax.tree.map(lambda s: P(), pspecs)

    def factored(spec, shp):
        if len(shp.shape) >= 2:
            row = P(*spec[:-1]) if len(spec) else P()
            col = P(*(tuple(spec[:-2]) + (spec[-1],))) if len(spec) >= 2 else P()
            return (row, col)
        return (spec, P())

    nu = jax.tree.map(factored, pspecs, params_shapes,
                      is_leaf=lambda x: isinstance(x, P))
    return OptState(P(), mu, nu)


def batch_pspecs(mesh, batch_shapes) -> Any:
    """tokens (B, S) -> P(dp_axes, None); frame/patch embeds likewise."""

    def one(leaf):
        axes = batch_spec_axes(mesh, leaf.shape[0])
        spec = (axes if axes else None,) + (None,) * (len(leaf.shape) - 1)
        return P(*spec)

    return jax.tree.map(one, batch_shapes)


def cache_pspecs(cfg: ModelConfig, mesh, cache_shapes) -> Any:
    """Decode caches: batch dim (after the stacked layer dim) over DP; the
    KV-head dim over 'model' when divisible; seq/state dims unsharded."""
    tp = mesh.shape.get("model", 1)

    def one(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        # leading dim is the stacked layer count; batch is dim 1
        axes: list = [None] * len(shape)
        if len(shape) >= 2:
            dp = batch_spec_axes(mesh, shape[1])
            if dp:
                axes[1] = dp
        if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
            if shape[3] % tp == 0:
                axes[3] = "model"      # (L, B, C, KV, hd): shard KV heads
            elif shape[2] % tp == 0 and shape[2] >= tp:
                axes[2] = "model"      # context parallel: shard the seq dim
        if name == "lat" and len(shape) == 4 and shape[2] % tp == 0 and shape[2] >= tp:
            axes[2] = "model"          # MLA latent cache: shard seq
        if name == "state" and len(shape) == 5 and shape[2] % tp == 0:
            axes[2] = "model"  # (L, B, H, P, N): shard SSD heads
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_named(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
