"""Distributed train step: microbatched grad accumulation + optimizer.

`make_train_step` builds the pjit-able step used both by the multi-pod
dry-run (lower/compile only) and the real CPU-scale training examples.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import (adafactor_update, adamw_update, apply_updates,
                         cosine_schedule, init_opt_state)
from repro.optim.optimizers import clip_by_global_norm


def default_num_micro(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Microbatch count: keep per-device microbatch tokens ~<= 8k for big
    models (activation memory), fewer micro-steps for small ones."""
    if cfg.num_micro_override:
        return cfg.num_micro_override
    from .mesh import batch_spec_axes
    dp = 1
    for a in batch_spec_axes(mesh, shape.global_batch):
        dp *= mesh.shape[a]
    per_dev = max(1, shape.global_batch // dp)
    if cfg.d_model >= 4096:
        per_dev_micro = 1          # big models: one sequence per device/micro
    elif cfg.d_model >= 2048:
        per_dev_micro = min(per_dev, 4)
    else:
        per_dev_micro = min(per_dev, 8)
    n = max(1, per_dev // per_dev_micro)
    while shape.global_batch % n:
        n -= 1
    return n


def make_train_step(cfg: ModelConfig, *, num_micro: int = 1, lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    clip_norm: float = 1.0, micro_shardings=None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  Gradient accumulation over `num_micro` microbatches
    via lax.scan (activation memory ~ 1/num_micro).

    micro_shardings: optional pytree of NamedShardings (leading micro dim
    unsharded, batch dim over DP) applied to the reshaped microbatch stack —
    without it GSPMD splits the data axis across (micro, batch), silently
    multiplying per-device compute (see EXPERIMENTS.md Perf log).

    grad_shardings: optional pytree of NamedShardings (same structure as
    params) constraining each microbatch's gradients — forces GSPMD to
    reduce-scatter dW into the parameter sharding instead of all-reducing
    full tensors inside the accumulation scan (EXPERIMENTS.md Perf log,
    iteration 2)."""

    update_fn = adamw_update if cfg.optimizer == "adamw" else adafactor_update

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

    def train_step(params, opt_state, batch, step):
        def micro_loss(p, mb):
            return loss_fn(cfg, p, mb)

        grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

        if num_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            def reshape(x):
                return x.reshape((num_micro, x.shape[0] // num_micro) + x.shape[1:])
            micro = jax.tree.map(reshape, batch)
            if micro_shardings is not None:
                micro = jax.tree.map(jax.lax.with_sharding_constraint,
                                     micro, micro_shardings)

            acc_dt = jnp.bfloat16 if cfg.grad_acc_dtype == "bfloat16" else jnp.float32

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                g = constrain_grads(g)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / num_micro, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr_t = cosine_schedule(step, peak_lr=lr, warmup_steps=warmup,
                               total_steps=total_steps)
        updates, opt_state = update_fn(grads, opt_state, params, lr_t)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr_t)
        return params, opt_state, metrics

    return train_step


def abstract_train_state(cfg: ModelConfig, rng=None):
    """ShapeDtypeStruct trees for (params, opt_state) — no allocation."""
    from repro.models import init_params
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(cfg, key))
    opt = jax.eval_shape(
        lambda: init_opt_state(params, cfg.optimizer, cfg.opt_state_dtype))
    return params, opt
