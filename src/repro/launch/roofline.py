"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = sum over collective ops of operand bytes / link bandwidth
               (per device, ICI for intra-pod axes; DCN factor for 'pod')

HLO_FLOPs/bytes come from compiled.cost_analysis() (per-device SPMD module).
Collective bytes are NOT in cost_analysis: we parse the optimized HLO text
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.  Hardware model: TPU v5e.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

# ----------------------------------------------------------- hardware model
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (v5e: ~50 GB/s/link)
DCN_POD_BW = 6.25e9             # bytes/s per chip cross-pod (50 Gbps eq.)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _parse_shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes in an HLO type signature string
    like 'bf16[16,4096,7168]' or '(f32[8,128], u32[2])'."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    bytes_cross_pod: int
    total_bytes: int


def parse_collectives(hlo_text: str, pod_axis_size: int = 1,
                      num_partitions: int = 256) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Uses the *output* side of each op as the wire-bytes proxy per device
    (all-gather output = bytes received; all-reduce ~ 2x in ring terms —
    we report raw operand bytes and keep the ring factor in the time model).
    Cross-pod detection: replica_groups spanning partitions whose linear
    index differs in the slowest (pod) dimension.
    """
    counts: dict = {}
    bytes_by_kind: dict = {}
    cross = 0
    per_pod = num_partitions // max(pod_axis_size, 1)
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        if "-start" in s.split("=", 1)[1].split("(")[0] and "-done" in s:
            pass
        b = _parse_shape_bytes(sig)
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
        if pod_axis_size > 1:
            rg = re.search(r"replica_groups=\{(.*?)\}", s)
            if rg:
                groups = rg.group(1)
                first = re.search(r"([\d,]+)", groups)
                if first:
                    ids = [int(x) for x in first.group(1).split(",") if x]
                    if ids and (max(ids) // per_pod) != (min(ids) // per_pod):
                        cross += b
            sd = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", s)
            if sd:
                a, t = int(sd.group(1)), int(sd.group(2))
                if a // per_pod != t // per_pod:
                    cross += b
    total = sum(bytes_by_kind.values())
    return CollectiveStats(counts, bytes_by_kind, cross, total)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_total_bytes: float, cross_pod_bytes: float = 0.0) -> dict:
    """The three roofline terms, in seconds (per device, per step)."""
    intra = coll_total_bytes - cross_pod_bytes
    t_compute = flops_per_dev / PEAK_FLOPS_BF16
    t_memory = bytes_per_dev / HBM_BW
    t_coll = intra / ICI_BW + cross_pod_bytes / DCN_POD_BW
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "collective_intra_bytes": int(intra),
        "collective_cross_pod_bytes": int(cross_pod_bytes),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction"] = float(t_compute / bound) if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6 N D for training (N = active params, D = tokens);
    2 N D for inference forward passes."""
    toks = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6 if shape.mode == "train" else 2
    return float(mult) * n * toks
