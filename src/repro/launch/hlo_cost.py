"""HLO-text cost model with while-loop trip-count accounting.

XLA's `compiled.cost_analysis()` counts each while-loop *body once*, which
massively under-counts scanned programs (layer stacks, microbatch
accumulation, chunked losses).  This module parses the optimized HLO text,
computes per-computation costs, and propagates them through the call graph
multiplying loop bodies by their trip counts:

  flops      — 2 * output_elems * contraction_size for every dot
               (incl. dots inside fusions)
  bytes      — operand + output bytes at fusion/instruction boundaries
               (the standard HBM-traffic proxy, matching cost_analysis
               semantics but loop-aware)
  collectives — per-kind wire bytes (output-shape proxy), split into
               intra-pod and cross-pod by replica group analysis

Trip counts are recovered from the loop-condition computation (the compare
constant); scan-lowered loops always have static trips.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_in(sig: str):
    """All (dtype, dims) in a type string; handles tuples."""
    out = []
    for m in _SHAPE_RE.finditer(sig):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        out.append((dt, dims))
    return out


def _bytes_of(sig: str) -> int:
    total = 0
    for dt, dims in _shapes_in(sig):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0       # CPU-granularity: operands + outputs of every op
    bytes_tpu: float = 0.0   # TPU-fusion model: 2x outputs of materializing ops
    bytes_attn: float = 0.0  # portion of bytes_tpu inside flash_attention scopes
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    cross_pod_bytes: float = 0.0
    # (kind, multiplier, callee) edges
    calls: list = dataclasses.field(default_factory=list)


# Ops whose outputs a TPU compiler materialises in HBM (fusion roots,
# matmuls, data movement); pure elementwise/convert/copy chains are assumed
# fused into their consumers.
_MATERIALIZING = ("dot", "convolution", "fusion", "reduce", "sort", "gather",
                  "scatter", "reduce-window", "concatenate", "pad",
                  "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "iota", "rng")


def _split_computations(hlo: str):
    """name -> list of instruction lines (including the header)."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        s = line.rstrip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->", s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = [s]
            continue
        if cur is not None:
            comps[cur].append(s)
            if s.strip() == "}":
                cur = None
    return comps


def _dot_flops(line: str, shape_of) -> float:
    """2 * out_elems * K for a dot line."""
    m = _INSTR_RE.match(line)
    if not m:
        return 0.0
    rhs = m.group(2)
    out_shapes = _shapes_in(rhs.split(" dot(")[0])
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    # operands: newer XLA prints inline types (`dot(f32[64,64]{1,0} %a, ...)`),
    # older prints bare names (`dot(%a, %b)`) — handle both
    ops = re.search(r"dot\(([^)]*)\)", rhs)
    lhs_dims = None
    if ops:
        args_str = ops.group(1)
        inline = _shapes_in(args_str.split("%")[0])  # type before first operand name
        if inline:
            lhs_dims = inline[0][1]
        else:
            names = re.findall(r"%([\w.\-]+)", args_str)
            lhs_name = names[0] if names else args_str.split(",")[0].strip()
            lhs_dims = shape_of.get(lhs_name)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if lhs_dims is None or cd is None:
        return 2.0 * out_elems  # degenerate fallback
    k = 1
    for idx in cd.group(1).split(","):
        if idx:
            k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _conv_flops(line: str, shape_of) -> float:
    m = _INSTR_RE.match(line)
    rhs = m.group(2) if m else ""
    out_shapes = _shapes_in(rhs.split(" convolution(")[0])
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    ops = re.search(r"convolution\(([^)]*)\)", rhs)
    if not ops:
        return 0.0
    args_str = ops.group(1)
    inline = _shapes_in(args_str)  # inline operand types (newer XLA)
    if len(inline) >= 2:
        kdims = inline[1][1]
    else:
        names = re.findall(r"%([\w.\-]+)", args_str)
        rhs_name = (names[1] if len(names) > 1
                    else args_str.split(",")[-1].strip())
        kdims = shape_of.get(rhs_name, [1])
    k = 1
    for d in kdims:
        k *= d
    return 2.0 * out_elems * k  # upper bound: full kernel contraction


def analyze(hlo: str, *, pod_axis_size: int = 1, num_partitions: int = 256):
    """Returns dict with loop-aware totals for the ENTRY computation."""
    comps = _split_computations(hlo)
    costs: dict[str, CompCost] = {}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    per_pod = num_partitions // max(pod_axis_size, 1)

    # trip count per condition computation: max int constant
    cond_trip = {}
    for name, lines in comps.items():
        mx = 0
        for l in lines:
            for c in re.finditer(r"constant\((\d+)\)", l):
                mx = max(mx, int(c.group(1)))
        cond_trip[name] = max(mx, 1)

    for name, lines in comps.items():
        cc = CompCost()
        shape_of = {}
        # parameters
        hdr = lines[0]
        for pm in re.finditer(r"%?([\w.\-]+):\s*(\([^)]*\)|[\w\[\],]+)", hdr):
            shps = _shapes_in(pm.group(2))
            if len(shps) == 1:
                shape_of[pm.group(1)] = shps[0][1]
        for l in lines[1:]:
            m = _INSTR_RE.match(l)
            if not m:
                continue
            out_name, rhs = m.group(1).lstrip("%"), m.group(2)
            shps = _shapes_in(rhs.split("(")[0] if "(" in rhs else rhs)
            if shps:
                shape_of[out_name] = shps[0][1]
            opm = re.match(r"((?:\([^)]*\)|[\w\[\],{}]+))\s+([\w\-]+)", rhs)
            if not opm:
                continue
            out_sig, op = opm.group(1), opm.group(2)
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy-start", "copy-done", "after-all"):
                continue
            out_bytes = _bytes_of(out_sig)
            # operand bytes
            args = re.search(rf"{op}\(([^)]*)\)", rhs)
            arg_bytes = 0
            if args:
                for a in args.group(1).split(","):
                    a = a.strip().lstrip("%")
                    dims = shape_of.get(a)
                    if dims is not None:
                        # dtype unknown from table; approximate with out dtype
                        n = 1
                        for d in dims:
                            n *= d
                        arg_bytes += n * (
                            _DTYPE_BYTES.get(_shapes_in(out_sig)[0][0], 4)
                            if _shapes_in(out_sig) else 4)
            if op in ("dynamic-update-slice", "dynamic-slice"):
                # in-place update / slice read: traffic ~ 2x the slice, not
                # the full operand (XLA buffers these in place)
                sl = 2 * out_bytes
                if op == "dynamic-update-slice" and args:
                    parts = [a.strip().lstrip("%") for a in args.group(1).split(",")]
                    upd = shape_of.get(parts[1]) if len(parts) > 1 else None
                    if upd is not None:
                        n = 1
                        for d in upd:
                            n *= d
                        dt = _shapes_in(out_sig)[0][0] if _shapes_in(out_sig) else "f32"
                        sl = 2 * n * _DTYPE_BYTES.get(dt, 4)
                cc.bytes += sl
                cc.bytes_tpu += sl
                continue
            if op.startswith(_MATERIALIZING):
                cc.bytes_tpu += 2 * out_bytes
                if "flash_attention" in l:
                    # with a fused Pallas flash-attention kernel these
                    # tensors (scores/probs/online-softmax stats) stay in
                    # VMEM; tracked separately so the roofline can report
                    # the fused-kernel memory term
                    cc.bytes_attn += 2 * out_bytes
            if op == "dot":
                cc.flops += _dot_flops(l, shape_of)
                cc.bytes += out_bytes + arg_bytes
            elif op == "convolution":
                cc.flops += _conv_flops(l, shape_of)
                cc.bytes += out_bytes + arg_bytes
            elif op.startswith("fusion"):
                callee = re.search(r"calls=%?([\w.\-]+)", rhs)
                if callee:
                    cc.calls.append(("fusion", 1, callee.group(1)))
                cc.bytes += out_bytes + arg_bytes
            elif op == "while":
                body = re.search(r"body=%?([\w.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w.\-]+)", rhs)
                trip = cond_trip.get(cond.group(1), 1) if cond else 1
                if body:
                    cc.calls.append(("while", trip, body.group(1)))
            elif op == "conditional":
                for cal in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]*)", rhs):
                    nm = cal.group(1).strip().lstrip("%")
                    if nm in comps:
                        cc.calls.append(("cond", 1, nm))
            elif op == "call":
                callee = re.search(r"to_apply=%?([\w.\-]+)", rhs)
                if callee:
                    cc.calls.append(("call", 1, callee.group(1)))
            elif any(op.startswith(k) for k in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(k for k in _COLLECTIVES if op.startswith(k))
                cc.coll_bytes[kind] += out_bytes
                cc.coll_counts[kind] += 1
                cc.bytes += out_bytes + arg_bytes
                if pod_axis_size > 1:
                    rg = re.search(r"replica_groups=\{\{([\d,]+)", rhs)
                    crossed = False
                    if rg:
                        ids = [int(x) for x in rg.group(1).split(",") if x]
                        if ids and (max(ids) // per_pod) != (min(ids) // per_pod):
                            crossed = True
                    stp = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", rhs)
                    if stp and (int(stp.group(1)) // per_pod != int(stp.group(2)) // per_pod):
                        crossed = True
                    if crossed:
                        cc.cross_pod_bytes += out_bytes
            else:
                cc.bytes += out_bytes + arg_bytes
        costs[name] = cc

    # propagate through the call graph (memoized)
    memo: dict[str, tuple] = {}

    def total(name):
        if name in memo:
            return memo[name]
        cc = costs.get(name)
        if cc is None:
            return (0.0, 0.0, 0.0, 0.0, {}, {}, 0.0)
        f, b, bt, ba = cc.flops, cc.bytes, cc.bytes_tpu, cc.bytes_attn
        cb, cnts, xp = dict(cc.coll_bytes), dict(cc.coll_counts), cc.cross_pod_bytes
        memo[name] = (f, b, bt, ba, cb, cnts, xp)  # cycle guard
        for _, mult, callee in cc.calls:
            cf, cbt, cbtpu, cba, ccb, ccnt, cxp = total(callee)
            f += mult * cf
            b += mult * cbt
            bt += mult * cbtpu
            ba += mult * cba
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + mult * v
            for k, v in ccnt.items():
                cnts[k] = cnts.get(k, 0.0) + mult * v
            xp += mult * cxp
        memo[name] = (f, b, bt, ba, cb, cnts, xp)
        return memo[name]

    f, b, bt, ba, cb, cnts, xp = total(entry)
    return {
        "flops": f,
        "bytes_cpu_granularity": b,
        "bytes": bt,  # TPU-fusion model; roofline memory term uses this
        "bytes_attention_internal": ba,  # subtractable: fused flash kernel
        "collective_bytes_by_kind": cb,
        "collective_counts": cnts,
        "collective_total_bytes": sum(cb.values()),
        "cross_pod_bytes": xp,
        "entry": entry,
        "num_computations": len(comps),
    }
