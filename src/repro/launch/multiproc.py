"""Spawn P coordinated `jax.distributed` processes (one rank each).

The subprocess harness shared by the DistComm substrate tests and the
`--suite scale` benchmark: bind a free localhost port for the coordinator,
launch P copies of a `python -c` script that calls
`jax.distributed.initialize` against it, run them CONCURRENTLY (the ranks
rendezvous at the coordinator — launching sequentially would deadlock),
and collect per-rank (stdout, stderr), killing the whole fleet if any
rank hangs past the timeout.
"""

from __future__ import annotations

import socket
import subprocess
import sys
import time
from pathlib import Path

from repro.core.errors import RankTimeoutError

__all__ = ["SKEW_BRICK_SETUP", "WEAK_BRICK_SETUP", "free_port", "run_ranks"]

_ROOT = Path(__file__).resolve().parents[3]

# The shared weak-scaling scenario of the DistComm subprocess runs (the
# P=4 substrate test, the --suite scale benchmark ranks, and its
# in-process P=1 baseline): a 2D Kuhn brick with one cube column per rank
# and corner refinement (cap = level + 2) in EVERY tree, so the per-rank
# element load is constant in P and the 2:1 ripple crosses every
# inter-cell face.  `exec` it with `np`, `C` (repro.core.cmesh), `F`
# (repro.core.forest), `P`, `level`, and `comm_ov` bound; it defines
# `corner`, `cm`, and the adapted single-local-rank forest list `fs0`.
# One copy here keeps the benchmark rows, the baseline, and the test
# fixture refining identically.
WEAK_BRICK_SETUP = r"""
def corner(tree, elems, cap=level + 2):
    a = np.asarray(elems.anchor)
    l = np.asarray(elems.level)
    return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

cm = C.cmesh_brick(2, (P, 1))   # one Kuhn cell column per rank
fs0 = F.new_uniform(2, cm.num_trees, level, comm_ov, cmesh=cm)
fs0 = [F.adapt(fs0[0], corner, recursive=True)]
"""

# The shared skewed-adapt scenario of the dynamic-repartition runs (the
# P=4 substrate acceptance test, the --suite repartition benchmark ranks,
# and the rank-0 single-rank oracle): the same Kuhn brick, but only the
# FIRST cube cell (trees 0 and 1) refines — to level 4 from a level-2
# uniform start — so the initial SFC split leaves almost all elements on
# the low ranks and `repartition` has real migration to do.  `exec` it
# with `np`, `C`, `F`, `P` (the brick width, == world size in subprocess
# runs), and `comm_ov` bound; it defines `skew`, `cm`, and the adapted
# forest list `fs0` (one entry per local rank).
SKEW_BRICK_SETUP = r"""
def skew(tree, elems, cap=4):
    l = np.asarray(elems.level)
    return ((np.asarray(tree) < 2) & (l < cap)).astype(np.int32)

cm = C.cmesh_brick(2, (P, 1))   # one Kuhn cell column per rank
fs0 = F.new_uniform(2, cm.num_trees, 2, comm_ov, cmesh=cm)
fs0 = [F.adapt(f, skew, recursive=True) for f in fs0]
"""


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def run_ranks(script: str, num_ranks: int, extra_args: tuple = (),
              timeout: float = 600.0, check: bool = True):
    """Run `script` in `num_ranks` concurrent subprocesses.

    Each subprocess receives argv = [coordinator_port, rank, *extra_args]
    and a minimal CPU-only environment with the repo's `src` on
    PYTHONPATH.

    `timeout` is one HARD wall clock for the whole fleet (not a per-rank
    budget that stacks to P*timeout when every rank hangs): the deadline
    starts at launch, every rank's `communicate` gets only the remaining
    slice, and on expiry ALL stragglers are killed and a
    `RankTimeoutError` reports each rank's state with its captured stderr
    tail — so a hung subprocess suite fails fast with a diagnosis instead
    of stalling the tier.

    With `check` (the default) a nonzero rank raises RuntimeError naming
    it with its stderr tail; `check=False` instead returns the per-rank
    `(stdout, stderr, returncode)` triples — the recovery tests use this
    to run fleets where a crash is the expected outcome.  With `check`
    the return value stays the historical `(stdout, stderr)` pair list.
    """
    port = free_port()
    env = {"PYTHONPATH": str(_ROOT / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu"}
    deadline = time.monotonic() + timeout
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", script, str(port), str(pid),
             *[str(a) for a in extra_args]],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
        )
        for pid in range(num_ranks)
    ]
    outs: list = [None] * num_ranks
    timed_out = False
    for pid, pr in enumerate(procs):
        try:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise subprocess.TimeoutExpired(pr.args, timeout)
            outs[pid] = pr.communicate(timeout=remaining)
        except subprocess.TimeoutExpired:
            timed_out = True
            break
    if timed_out:
        for p2 in procs:
            p2.kill()
        per_rank = {}
        for pid, p2 in enumerate(procs):  # reap: no zombies/undrained pipes
            if outs[pid] is None:
                try:
                    out, err = p2.communicate(timeout=5.0)
                except Exception:  # noqa: BLE001 - double-kill raced the reap
                    p2.wait()
                    out, err = "", ""
                outs[pid] = (out, err)
                state = "killed after wall-clock timeout"
            else:
                state = f"exited {p2.returncode}"
            per_rank[pid] = (state, outs[pid][1][-2000:])
        lines = "\n".join(f"  rank {pid}: {st}\n    stderr: {tail!r}"
                          for pid, (st, tail) in per_rank.items())
        raise RankTimeoutError(
            f"run_ranks hit its {timeout:.1f}s wall clock with "
            f"{sum(1 for s, _ in per_rank.values() if 'killed' in s)} of "
            f"{num_ranks} rank(s) still running:\n{lines}",
            per_rank=per_rank)
    if not check:
        return [(out, err, procs[pid].returncode)
                for pid, (out, err) in enumerate(outs)]
    for pid, (out, err) in enumerate(outs):
        if procs[pid].returncode != 0:
            raise RuntimeError(
                f"rank {pid} exited {procs[pid].returncode}: {err[-3000:]}")
    return outs
