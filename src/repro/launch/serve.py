"""Distributed serving steps: prefill and batched decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.lm import unembed


def make_prefill_step(cfg: ModelConfig):
    """prefill(params, batch, cache) -> (last-token logits, filled cache)."""

    def prefill(params, batch, cache):
        hidden, _, cache = forward(cfg, params, batch, cache=cache, cache_pos=0)
        logits = unembed(cfg, params, hidden[:, -1]).astype(jnp.float32)
        return logits, cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, cache, tokens (B,1), pos) -> (logits, cache)."""

    def step(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    return step


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
