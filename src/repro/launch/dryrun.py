import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract memory / cost / collective statistics.

  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # driver: all cells, both meshes
  python -m repro.launch.dryrun --list

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json.  A compile
failure here (sharding mismatch, OOM at compile, unsupported collective) is
a bug in the system, not in the cell.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _cell_path(arch, shape, mesh_kind):
    return RESULTS / f"{arch}__{shape}__{mesh_kind}.json"


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import cell_supported, get_config, get_shape, input_specs
    from repro.launch import hlo_cost
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import model_flops, roofline_terms
    from repro.launch.serve import abstract_cache, make_decode_step, make_prefill_step
    from repro.launch.train import (abstract_train_state, default_num_micro,
                                    make_train_step)

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    ok, why = cell_supported(cfg, shape)
    if not ok:
        out.update(status="skip", why=why)
        return out

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = int(np.prod(list(mesh.shape.values())))
    pod = mesh.shape.get("pod", 1)

    specs = input_specs(cfg, shape)
    batch_ps = sh.batch_pspecs(mesh, specs)
    params_s, opt_s = abstract_train_state(cfg)
    params_ps = sh.params_pspecs(cfg, mesh, params_s)

    # sequence-parallel residuals for pure-FSDP profiles (train/prefill only)
    from repro.models import lm as lm_mod
    from repro.models import moe_a2a
    from repro.launch.mesh import batch_spec_axes
    tp_size = mesh.shape.get("model", 1)
    a2a_moe = (cfg.moe is not None and shape.mode in ("train", "prefill")
               and cfg.moe.num_experts % tp_size == 0)
    if ((cfg.parallelism == "fsdp_sp" or a2a_moe)
            and shape.mode in ("train", "prefill")):
        # sequence-parallel residuals: also for a2a-MoE configs, so the
        # shard_map boundary needs no per-layer activation reshard
        bax = batch_spec_axes(mesh, shape.global_batch)
        lm_mod.set_activation_spec(P(bax if bax else None, "model", None))
    else:
        lm_mod.set_activation_spec(None)
    # shard_map all-to-all MoE dispatch (EXPERIMENTS.md Perf iteration 6)
    if a2a_moe:
        moe_a2a.set_moe_impl(mesh=mesh,
                             dp_axes=batch_spec_axes(mesh, shape.global_batch),
                             model_axis="model")
    else:
        moe_a2a.set_moe_impl(mesh=None)

    t0 = time.time()
    if shape.mode == "train":
        num_micro = default_num_micro(cfg, shape, mesh)
        out["num_micro"] = num_micro
        opt_ps = sh.opt_state_pspecs(cfg, mesh, params_ps, params_s, cfg.optimizer)
        micro_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(None, *tuple(s))),
            batch_ps, is_leaf=lambda x: isinstance(x, P),
        ) if num_micro > 1 else None
        step_fn = make_train_step(cfg, num_micro=num_micro,
                                  micro_shardings=micro_sh,
                                  grad_shardings=sh.to_named(mesh, params_ps))
        jf = jax.jit(
            step_fn,
            in_shardings=(sh.to_named(mesh, params_ps), sh.to_named(mesh, opt_ps),
                          sh.to_named(mesh, batch_ps), NamedSharding(mesh, P())),
            out_shardings=(sh.to_named(mesh, params_ps), sh.to_named(mesh, opt_ps),
                           None),
            donate_argnums=(0, 1),
        )
        with jax.set_mesh(mesh):
            lowered = jf.lower(params_s, opt_s,
                               specs, jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.mode == "prefill":
        cache_s = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_ps = sh.cache_pspecs(cfg, mesh, cache_s)
        fn = make_prefill_step(cfg)
        jf = jax.jit(
            fn,
            in_shardings=(sh.to_named(mesh, params_ps), sh.to_named(mesh, batch_ps),
                          sh.to_named(mesh, cache_ps)),
            out_shardings=(None, sh.to_named(mesh, cache_ps)),
            donate_argnums=(2,),
        )
        with jax.set_mesh(mesh):
            lowered = jf.lower(params_s, specs, cache_s)
    else:  # decode
        cache_s = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_ps = sh.cache_pspecs(cfg, mesh, cache_s)
        fn = make_decode_step(cfg)
        jf = jax.jit(
            fn,
            in_shardings=(sh.to_named(mesh, params_ps), sh.to_named(mesh, cache_ps),
                          sh.to_named(mesh, batch_ps["tokens"]),
                          NamedSharding(mesh, P())),
            out_shardings=(None, sh.to_named(mesh, cache_ps)),
            donate_argnums=(1,),
        )
        with jax.set_mesh(mesh):
            lowered = jf.lower(params_s, cache_s, specs["tokens"],
                               jax.ShapeDtypeStruct((), jnp.int32))
    out["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    out["compile_s"] = round(time.time() - t0, 2)

    # ---- memory analysis (proves it fits) ----
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        mem["peak_bytes_per_device"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
        out["memory"] = mem
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}

    # analytic per-device parameter+optimizer bytes from the shardings
    def _sharded_bytes(struct_tree, spec_tree):
        total = 0
        for leaf, spec in zip(jax.tree.leaves(struct_tree),
                              jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))):
            div = 1
            for ax in jax.tree.leaves(tuple(spec)):
                if ax is not None:
                    div *= mesh.shape[ax]
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // max(div, 1)
        return total

    out["analytic_param_bytes_per_device"] = _sharded_bytes(params_s, params_ps)

    # ---- raw XLA cost analysis (loop bodies counted ONCE — reference only) ----
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out["xla_cost_raw"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(sum(
            v for k, v in ca.items() if k.startswith("bytes accessed"))),
    }

    # ---- loop-aware HLO cost model (flops / bytes / collectives) ----
    hlo = compiled.as_text()
    hc = hlo_cost.analyze(hlo, pod_axis_size=pod, num_partitions=n_dev)
    out["hlo_cost"] = {
        "flops_per_device": hc["flops"],
        "bytes_per_device": hc["bytes"],
        "bytes_per_device_cpu_granularity": hc["bytes_cpu_granularity"],
        "collective_counts": {k: int(v) for k, v in hc["collective_counts"].items()},
        "collective_bytes_by_kind": {k: int(v) for k, v in
                                     hc["collective_bytes_by_kind"].items()},
        "collective_total_bytes": int(hc["collective_total_bytes"]),
        "cross_pod_bytes": int(hc["cross_pod_bytes"]),
    }

    out["hlo_cost"]["bytes_attention_internal"] = hc.get("bytes_attention_internal", 0.0)

    # ---- roofline ----
    rt = roofline_terms(hc["flops"], hc["bytes"],
                        hc["collective_total_bytes"], hc["cross_pod_bytes"])
    # variant: Pallas fused flash-attention kernel (scores stay in VMEM)
    rt_fused = roofline_terms(hc["flops"],
                              hc["bytes"] - hc.get("bytes_attention_internal", 0.0),
                              hc["collective_total_bytes"], hc["cross_pod_bytes"])
    out["roofline_fused_attention"] = rt_fused
    mf = model_flops(cfg, shape)
    out["roofline"] = rt
    out["model_flops_global"] = mf
    total_hlo_flops = hc["flops"] * n_dev
    out["useful_flops_ratio"] = mf / total_hlo_flops if total_hlo_flops else 0.0
    out["status"] = "ok"
    return out


# ------------------------------------------------------------------ driver
def drive_all(meshes=("single", "multi"), force=False, timeout=3600,
              only_arch=None, only_shape=None):
    from repro.configs import all_cells
    RESULTS.mkdir(parents=True, exist_ok=True)
    cells = all_cells()
    todo = []
    for arch, shp, ok, why in cells:
        if only_arch and arch != only_arch:
            continue
        if only_shape and shp != only_shape:
            continue
        for mk in meshes:
            path = _cell_path(arch, shp, mk)
            if path.exists() and not force:
                continue
            todo.append((arch, shp, mk))
    print(f"dryrun driver: {len(todo)} cells to run")
    for i, (arch, shp, mk) in enumerate(todo):
        print(f"[{i+1}/{len(todo)}] {arch} x {shp} x {mk} ...", flush=True)
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shp, "--mesh", mk],
            capture_output=True, text=True, timeout=timeout,
            env=dict(os.environ, PYTHONPATH=str(Path(__file__).resolve().parents[2])),
        )
        dt = time.time() - t0
        path = _cell_path(arch, shp, mk)
        if r.returncode != 0 and not path.exists():
            path.write_text(json.dumps({
                "arch": arch, "shape": shp, "mesh": mk, "status": "error",
                "why": r.stderr[-4000:], "wall_s": dt,
            }, indent=2))
            print(f"    ERROR after {dt:.0f}s (see json)")
        else:
            print(f"    done in {dt:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only-arch")
    ap.add_argument("--only-shape")
    args = ap.parse_args()

    if args.list:
        from repro.configs import all_cells
        for arch, shp, ok, why in all_cells():
            print(f"{arch:24s} {shp:12s} {'ok' if ok else 'SKIP: ' + why}")
        return
    if args.all:
        drive_all(force=args.force, only_arch=args.only_arch,
                  only_shape=args.only_shape)
        return
    assert args.arch and args.shape
    RESULTS.mkdir(parents=True, exist_ok=True)
    try:
        out = run_cell(args.arch, args.shape, args.mesh)
    except Exception:
        out = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "why": traceback.format_exc()[-6000:]}
    path = _cell_path(args.arch, args.shape, args.mesh)
    path.write_text(json.dumps(out, indent=2))
    print(json.dumps({k: v for k, v in out.items() if k != "why"}, indent=2))
    if out["status"] == "error":
        print(out["why"][-3000:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
