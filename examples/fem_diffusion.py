"""Finite-volume diffusion on an adapted tetrahedral forest.

Shows the AMR library as a numerical substrate: a piecewise-constant field
lives on the leaves of an adapted forest; explicit heat diffusion exchanges
flux across interior faces enumerated by `Iterate` (paper Sec. 5), with a
hot blob refined to two extra levels. Verifies discrete conservation
(sum u * volume is constant) and monotone decay of the max.

    PYTHONPATH=src python examples/fem_diffusion.py
"""

import numpy as np

from repro.core import forest as F
from repro.core import ops3d


def volumes(f):
    # each level-l tet has volume (1/6) * 8^-l of the unit cube (root tet = 1/6)
    return (1.0 / 6.0) * (8.0 ** -f.level.astype(np.float64))


def main():
    comm = F.SimComm(1)
    fs = F.new_uniform(3, 1, 2, comm)

    # refine around a hot corner blob
    L = ops3d.L

    def near_corner(tree, elems):
        c = np.asarray(ops3d.coordinates(elems)).mean(axis=1) / (1 << L)
        lv = np.asarray(elems.level)
        return ((np.linalg.norm(c - np.array([0.9, 0.1, 0.5]), axis=1) < 0.25)
                & (lv < 4)).astype(np.int32)

    fs = [F.adapt(f, near_corner, recursive=True) for f in fs]
    fs = F.balance(fs, comm)
    f = fs[0]
    n = f.num_local
    print(f"adapted+balanced mesh: {n} leaves, levels "
          f"{int(f.level.min())}..{int(f.level.max())}")

    # initial condition: hot blob
    cent = np.asarray(ops3d.coordinates(f.simplices())).mean(axis=1) / (1 << L)
    u = np.exp(-40 * np.linalg.norm(cent - np.array([0.9, 0.1, 0.5]), axis=1) ** 2)
    vol = volumes(f)
    total0 = float((u * vol).sum())

    # face pairs once (mesh is static during the solve)
    pairs = {}
    F.iterate(f, face_fn=lambda ff, pp: pairs.setdefault("p", pp))
    p = pairs["p"]
    i, j = p[:, 0], p[:, 1]
    print(f"interior face pairs: {len(p)}")

    # explicit diffusion: du_i = dt * sum_faces k * (u_j - u_i) * A_ij / vol_i
    # (uniform transmissibility; hanging faces appear as coarse-fine pairs)
    area = np.minimum(vol[i], vol[j]) ** (2 / 3)
    rowsum = np.zeros(n)
    np.add.at(rowsum, i, area / vol[i])
    np.add.at(rowsum, j, area / vol[j])
    dt_k = 0.9 / rowsum.max()  # explicit stability bound
    for step in range(60):
        flux = dt_k * (u[j] - u[i]) * area
        du = np.zeros_like(u)
        np.add.at(du, i, flux / vol[i])
        np.add.at(du, j, -flux / vol[j])
        u = u + du
        if step % 20 == 0:
            total = float((u * vol).sum())
            print(f"step {step:3d}: max u = {u.max():.4f}, "
                  f"conservation error = {abs(total - total0) / total0:.2e}")
    total = float((u * vol).sum())
    assert abs(total - total0) / total0 < 1e-12, "not conservative!"
    assert u.max() < 1.0, "diffusion must decay the max"
    print("conservation + decay verified")


if __name__ == "__main__":
    main()
