"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

tiny  — CPU-friendly smoke run (finishes in ~a minute).
100m  — a ~100M-parameter qwen3-style model, seq 512: the "train a ~100M
        model for a few hundred steps" deliverable (hours on this 1-core
        CPU box; the loop, checkpointing and restart logic are identical).

Kill the process (Ctrl-C / SIGTERM) at any point and re-run: it resumes
from the latest checkpoint with an identical loss trajectory (deterministic
seekable data pipeline + atomic checkpoints).
"""

import argparse
from dataclasses import replace

import jax

from repro.configs import get_config, reduced
from repro.launch.train import make_train_step
from repro.models.config import ShapeConfig
from repro.runtime import Trainer, TrainerConfig


def preset(name: str):
    base = get_config("qwen3-1.7b")
    if name == "tiny":
        cfg = replace(reduced(base), dtype="float32")
        shape = ShapeConfig("tiny", seq_len=64, global_batch=8, mode="train")
    elif name == "100m":
        cfg = replace(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768, tie_embeddings=True,
        )  # ~100M params
        shape = ShapeConfig("100m", seq_len=512, global_batch=8, mode="train")
    else:
        raise SystemExit(f"unknown preset {name}")
    return cfg, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg, shape = preset(args.preset)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"seq={shape.seq_len} batch={shape.global_batch}")
    step_fn = jax.jit(make_train_step(cfg, num_micro=1, lr=args.lr,
                                      warmup=20, total_steps=args.steps))
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(ckpt_dir=f"{args.ckpt_dir}_{args.preset}",
                      ckpt_every=args.ckpt_every, max_steps=args.steps),
        step_fn=step_fn, seed=0,
    )
    _, _, log = trainer.run(jax.random.PRNGKey(0))
    if log:
        print(f"steps {log[0]['step']}..{log[-1]['step']}  "
              f"loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
