"""Paper Figure 12 reproduction (scaled to this box): the fractal
refinement pattern — refine sub-tetrahedra of types 0 and 3 recursively —
validated against the analytic transfer-matrix count, plus the runtime
linearity / level-independence claims of Figure 11.

    PYTHONPATH=src python examples/amr_fractal.py
"""

import time

import numpy as np

from repro.core import forest as F
from repro.core.tables import get_tables


def analytic_fractal_count(trees: int, k: int, depth: int) -> int:
    t = get_tables(3)
    M = np.zeros((6, 6), dtype=object)
    for b in range(6):
        for i in range(8):
            M[b, t.child_type[b, i]] += 1
    c = np.zeros(6, dtype=object)
    c[0] = trees
    for _ in range(k):
        c = c @ M
    Fj = 1
    for _ in range(depth):
        Fj = 4 * Fj + 4
    refin = c[0] + c[3]
    return int(refin * Fj + (c.sum() - refin))


def fractal_cb(max_level):
    def cb(tree, elems):
        b = np.asarray(elems.stype)
        l = np.asarray(elems.level)
        return (((b == 0) | (b == 3)) & (l < max_level)).astype(np.int32)
    return cb


def main():
    comm = F.SimComm(4)
    print("== paper Fig. 12 extrapolation (transfer matrix) ==")
    n12 = analytic_fractal_count(512, 7, 5)
    print(f"   512 trees, k=7 -> level 12: {n12:,} elements "
          f"(paper reports 858,588,635,136; delta {abs(n12-858588635136)/858588635136:.2%} "
          f"from the unspecified coarse-mesh type mix)")
    for k in (1, 2, 3):
        trees = 4
        fs = F.new_uniform(3, trees, k, comm)
        fs = [F.adapt(f, fractal_cb(k + 2), recursive=True) for f in fs]
        got = F.count_global(fs)
        want = analytic_fractal_count(trees, k, 2)
        print(f"   measured k={k}: {got:,} == analytic {want:,}: {got == want}")

    print("== paper Fig. 11: New is linear in elements, level-independent ==")
    for level in (4, 5, 6):
        t0 = time.time()
        f = F.new_uniform_rank(3, 1, level, 0, 1)
        dt = time.time() - t0
        per = dt / f.num_local * 1e9
        print(f"   level {level}: {f.num_local:>9,} elements  {dt:7.3f}s  "
              f"{per:7.1f} ns/element")


if __name__ == "__main__":
    main()
