"""Inter-tree connectivity demo: one connected cube domain of Kuhn simplices.

The paper restricts Balance/Ghost to a single root simplex; the coarse-mesh
layer `repro.core.cmesh` lifts that: the unit cube splits into d! root
simplices (2 triangles / 6 tetrahedra) glued along their shared faces, and
refinement driven inside ONE tree ripples across tree faces during Balance,
while Ghost returns remote leaves from *other* trees, re-expressed in their
owner tree's coordinates.

    PYTHONPATH=src python examples/multitree_cube.py
"""

import numpy as np

from repro.core import cmesh as C
from repro.core import forest as F


def corner_cb(deep):
    def cb(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < deep)).astype(np.int32)
    return cb


def main():
    for d, base, deep in ((2, 2, 5), (3, 1, 4)):
        cm = C.cmesh_unit_cube(d)
        n_conn = int((cm.face_tree >= 0).sum())
        print(f"== d={d}: {cm.num_trees}-tree cube, {n_conn} glued tree faces ==")
        comm = F.SimComm(2)
        fs = F.new_uniform(d, cm.num_trees, base, comm, cmesh=cm)

        # refine the origin corner of tree 0 only
        fs = [F.adapt(f, corner_cb(deep), recursive=True) for f in fs]
        before = F.count_global(fs)
        per_tree_before = np.bincount(
            np.concatenate([f.tree for f in fs]), minlength=cm.num_trees
        )

        fs = F.balance(fs, comm)
        per_tree = np.bincount(
            np.concatenate([f.tree for f in fs]), minlength=cm.num_trees
        )
        print(f"   balance: {before} -> {F.count_global(fs)} elements; per tree "
              f"{per_tree_before.tolist()} -> {per_tree.tolist()} "
              f"(refinement crossed the tree faces)")

        gh = F.ghost(fs, comm)
        total = sum(len(g["level"]) for g in gh)
        cross = 0
        for p, g in enumerate(gh):
            local_trees = set(fs[p].tree.tolist())
            cross += sum(1 for t in g["tree"].tolist() if t not in local_trees)
        print(f"   ghost: {total} entries, {cross} from trees the rank holds "
              f"no elements of; validate(fs, gh) = {F.validate(fs, gh)}")

        # face classification on rank 0 (the old is_root_boundary, split)
        s = fs[0].simplices()
        kinds = F.face_kinds(fs[0], s)  # all faces, one sweep
        print(f"   rank-0 faces: {int((kinds == F.FACE_INTERIOR).sum())} interior, "
              f"{int((kinds == F.FACE_INTER_TREE).sum())} inter-tree, "
              f"{int((kinds == F.FACE_DOMAIN_BOUNDARY).sum())} domain boundary")

    # fully periodic cube: no boundary at all
    cm = C.cmesh_unit_cube(2, periodic=(True, True))
    comm = F.SimComm(1)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    s = fs[0].simplices()
    nb = int((F.face_kinds(fs[0], s) == F.FACE_DOMAIN_BOUNDARY).sum())
    print(f"== periodic 2D cube: {nb} boundary faces (torus) ==")


if __name__ == "__main__":
    main()
