"""Batched serving demo: prefill + continuous batched decode with SFC page
layout.

Serves a reduced model on CPU: a queue of requests with different prompt
lengths is admitted into a fixed batch; each step decodes one token for
every active slot; finished requests leave and the next request is
prefilled into the freed slot (continuous batching).  The paged-KV block
table uses the SFC order from repro.core.placement.page_order.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.placement import page_order
from repro.models import decode_step, forward, init_cache, init_params


def main():
    cfg = replace(reduced(get_config("qwen3-1.7b")), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, CACHE = 4, 160
    cache = init_cache(cfg, B, CACHE)
    print(f"serving {cfg.name}-reduced, batch={B}, cache={CACHE}")
    print("SFC page order (4 requests x 10 pages of 16 tokens):")
    print(np.asarray(page_order(10, B)))

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, cfg.vocab_size, size=rng.integers(8, 32)).tolist()
             for _ in range(10)]
    max_new = 16

    prefill = jax.jit(
        lambda p, toks, c: forward(cfg, p, {"tokens": toks}, cache=c, cache_pos=0))
    step = jax.jit(lambda p, c, t, k: decode_step(cfg, p, c, t, k))

    # continuous batching over ONE shared cache: for simplicity each slot
    # round-trips through its own prefill into a per-slot cache copy.
    slots = [None] * B           # (tokens_done, remaining, pos)
    done, t0, steps = 0, time.time(), 0
    per_slot_cache = [init_cache(cfg, 1, CACHE) for _ in range(B)]
    while done < 10:
        for s in range(B):
            if slots[s] is None and queue:
                prompt = queue.pop(0)
                toks = jnp.asarray(prompt, jnp.int32)[None]
                _, _, per_slot_cache[s] = prefill(params, toks, init_cache(cfg, 1, CACHE))
                slots[s] = [prompt[-1], max_new, len(prompt)]
        for s in range(B):
            if slots[s] is None:
                continue
            last, remaining, pos = slots[s]
            logits, per_slot_cache[s] = step(
                params, per_slot_cache[s], jnp.asarray([[last]], jnp.int32),
                jnp.int32(pos))
            nxt = int(jnp.argmax(logits[0]))
            steps += 1
            slots[s] = [nxt, remaining - 1, pos + 1]
            if slots[s][1] == 0:
                slots[s] = None
                done += 1
    dt = time.time() - t0
    print(f"served 10 requests, {steps} decode steps in {dt:.1f}s "
          f"({steps/dt:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
