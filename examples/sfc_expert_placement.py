"""The paper's technique inside the training stack: SFC-weighted partition
for MoE expert placement and token load balancing.

Simulates a skewed MoE routing distribution (Zipf over 256 experts, as seen
in real deepseek-scale training), then compares:
  * naive blocked placement (experts e*E/D .. (e+1)*E/D per device) vs
  * SFC-weighted contiguous partition over measured loads
and shows the documents->DP-ranks token balancing used by the data pipeline.

    PYTHONPATH=src python examples/sfc_expert_placement.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.placement import (document_partition, expert_placement,
                                  imbalance, target_ranks)


def main():
    rng = np.random.default_rng(0)
    E, D = 256, 16
    load = (rng.zipf(1.3, size=E) % 4000 + 50).astype(np.float32)
    load = jnp.asarray(load)

    naive = jnp.repeat(jnp.arange(D), E // D)
    imb_naive = float(imbalance(load, naive, D))
    dev, imb_sfc = expert_placement(load, D)
    print(f"expert load imbalance (max/mean): naive blocked {imb_naive:.2f} "
          f"-> SFC weighted {float(imb_sfc):.2f}")
    counts = np.bincount(np.asarray(dev), minlength=D)
    print("experts per device:", counts.tolist())

    print()
    doc_lens = rng.lognormal(6.2, 1.1, size=4096).astype(np.float32)
    ranks, imb = document_partition(jnp.asarray(doc_lens), 32)
    per = np.bincount(np.asarray(ranks), weights=doc_lens, minlength=32)
    print(f"document->rank token balancing over 32 DP ranks: "
          f"imbalance {float(imb):.3f} (min {per.min():.0f} max {per.max():.0f} tokens)")


if __name__ == "__main__":
    main()
