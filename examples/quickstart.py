"""Quickstart: the tetrahedral SFC end to end.

Builds a forest over 2 root tetrahedra, refines adaptively near a sphere,
2:1-balances, partitions by weight across 4 simulated ranks, builds the
ghost layer, and round-trips elements through the Pallas kernels.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import forest as F
from repro.core import ops3d, u64
from repro.kernels import ops as kops


def main():
    comm = F.SimComm(4)
    print("== New: uniform level-2 forest of 2 trees on 4 ranks ==")
    fs = F.new_uniform(3, 2, 2, comm)
    print("   local counts:", [f.num_local for f in fs], "valid:", F.validate(fs))

    print("== Adapt: refine elements near the domain diagonal, 3 rounds ==")
    L = ops3d.L

    def near_diagonal(tree, elems):
        c = np.asarray(ops3d.coordinates(elems)).mean(axis=1)  # centroids
        t = c / (1 << L)
        d = np.abs(t - t.mean(axis=1, keepdims=True)).max(axis=1)
        lv = np.asarray(elems.level)
        return ((d < 0.1) & (lv < 5)).astype(np.int32)

    fs = [F.adapt(f, near_diagonal, recursive=True) for f in fs]
    print("   adapted:", F.count_global(fs), "elements; valid:", F.validate(fs))

    print("== Balance: enforce 2:1 across faces ==")
    fs = F.balance(fs, comm)
    print("   balanced:", F.count_global(fs), "elements; valid:", F.validate(fs))

    print("== Partition: weight ~ 2^level (finer elements cost more) ==")
    fs = F.partition(fs, comm, weights=[2.0 ** f.level for f in fs])
    loads = [float((2.0 ** f.level).sum()) for f in fs]
    print("   per-rank load:", [round(l) for l in loads],
          "imbalance:", round(max(loads) / (sum(loads) / 4), 4))

    print("== Ghost layer ==")
    gh = F.ghost(fs, comm)
    print("   ghosts per rank:", [len(g["level"]) for g in gh])

    print("== Pallas kernels (interpret mode on CPU) ==")
    f0 = fs[0]
    s = f0.simplices()
    hi, lo = kops.morton_key(3, s)
    back = kops.decode(3, u64.U64(hi, lo), s.level)
    ok = np.array_equal(np.asarray(back.anchor), f0.anchor)
    print("   encode->decode roundtrip on rank 0:", ok)
    nb, dual = kops.face_neighbor(3, s, 0)
    print("   face-0 neighbors inside root:",
          int(np.asarray(ops3d.is_inside_root(nb)).sum()), "/", f0.num_local)


if __name__ == "__main__":
    main()
