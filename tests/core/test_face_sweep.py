"""The fused face sweep vs the composed per-face ops it replaces.

`BatchedOps.face_sweep` must be bit-identical to composing `face_neighbor` +
`is_inside_root` + `morton_key` per face — across all three backends, on
random batches (property-tested through the offline `_pbt` shim) AND on the
forests of every multitree fixture, whose elements exercise all three face
kinds (interior, inter-tree, domain boundary).  `forest.face_sweep_layer`
(the sweep + cross-tree fixup the hot loops consume) is pinned against an
independent composed-and-dict-grouped reimplementation of the pre-fusion
lookup, and the Balance/Ghost dispatch-count invariant — one sweep dispatch
per eval layer, zero per-face neighbor dispatches — is asserted directly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: bounded random sampling
    from _pbt import given, settings, strategies as st

from _helpers import rand_simplices
from repro.core import batch, get_ops
from repro.core import cmesh as C
from repro.core import forest as F
from repro.core import u64 as u64m
from repro.core.types import Simplex

BACKENDS = ["reference", "jnp", pytest.param("pallas", marks=pytest.mark.slow)]

N = 64  # one padding bucket -> one jit/interpret compile per op


def composed_sweep(bops, s):
    """The pre-fusion composition, stacked per face: the oracle the fused
    dispatch must match bit for bit."""
    nbs, duals, insides, keys = [], [], [], []
    for f in range(bops.d + 1):
        nb, dual = bops.face_neighbor(s, f)
        nbs.append(nb)
        duals.append(np.asarray(dual))
        insides.append(np.asarray(bops.is_inside_root(nb)))
        keys.append(bops.morton_key_np(nb))
    return (
        np.stack([np.asarray(x.anchor) for x in nbs]),
        np.stack([np.asarray(x.level) for x in nbs]),
        np.stack([np.asarray(x.stype) for x in nbs]),
        np.stack(duals), np.stack(insides), np.stack(keys),
    )


def assert_sweep_matches(sw: batch.FaceSweep, oracle) -> None:
    anchor, level, stype, dual, inside, keys = oracle
    np.testing.assert_array_equal(np.asarray(sw.neighbor.anchor), anchor)
    np.testing.assert_array_equal(np.asarray(sw.neighbor.level), level)
    np.testing.assert_array_equal(np.asarray(sw.neighbor.stype), stype)
    np.testing.assert_array_equal(np.asarray(sw.dual), dual)
    np.testing.assert_array_equal(np.asarray(sw.inside), inside.astype(bool))
    np.testing.assert_array_equal(u64m.to_np(sw.key), keys)


@pytest.fixture(params=[2, 3])
def d(request):
    return request.param


@pytest.mark.parametrize("backend", BACKENDS)
def test_face_sweep_matches_composed_ops(d, backend):
    """Random batches (levels 0..MAXLEVEL, neighbors falling outside the
    root included): fused == composed, bit for bit, per backend."""
    s = rand_simplices(d, N, seed=70 + d, min_level=0)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    assert_sweep_matches(got.face_sweep(s), composed_sweep(ref, s))


@given(seed=st.integers(0, 2**31 - 1), dim=st.sampled_from([2, 3]))
@settings(max_examples=25, deadline=None)
def test_face_sweep_property(seed, dim):
    """Property test (hypothesis, or offline via tests/_pbt.py): for
    arbitrary valid elements the fused jnp sweep equals the composed
    reference ops on every face."""
    s = rand_simplices(dim, 16, seed=seed, min_level=0)
    ref = batch.get_batch_ops(dim, "reference")
    got = batch.get_batch_ops(dim, "jnp")
    assert_sweep_matches(got.face_sweep(s), composed_sweep(ref, s))


def test_face_sweep_empty_batch(d):
    o = get_ops(d)
    s = o.from_linear_id(u64m.from_int(np.zeros(0, np.uint64)), jnp.zeros(0, jnp.int32))
    for backend in ("reference", "jnp"):
        sw = batch.get_batch_ops(d, backend).face_sweep(s)
        assert sw.neighbor.anchor.shape == (d + 1, 0, d)
        assert sw.dual.shape == (d + 1, 0)
        assert sw.inside.shape == (d + 1, 0)
        assert sw.key.hi.shape == (d + 1, 0)


# ------------------------------------------------ forest layer (cross-tree)
FIXTURES = {
    # name: (d, cmesh factory, base level, deep level)
    "kuhn2_d2": (2, lambda: C.cmesh_unit_cube(2), 2, 4),
    "kuhn6_d3": (3, lambda: C.cmesh_unit_cube(3), 1, 3),
    "periodic_d2": (2, lambda: C.cmesh_unit_cube(2, periodic=(True, True)), 2, 4),
    "rotated_pair": (2, C.cmesh_rotated_pair, 2, 4),
    "single_tree_d3": (3, lambda: None, 1, 3),
}


def _fixture_forest(name):
    d, mk_cmesh, base, deep = FIXTURES[name]
    cm = mk_cmesh()
    num_trees = cm.num_trees if cm is not None else 2
    comm = F.SimComm(1)

    def corner(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < deep)).astype(np.int32)

    [f] = F.new_uniform(d, num_trees, base, comm, cmesh=cm)
    return F.adapt(f, corner, recursive=True)


def composed_face_lookup(f, tree_ids, s, face):
    """Independent reimplementation of the pre-fusion `_face_lookup`: per-face
    composed dispatches + the per-element Python dict grouping for cross-tree
    faces.  Kept verbatim from the pre-sweep code so the vectorized
    lexsort-grouped fixup has a fixed oracle."""
    bops = f.bops
    tree_ids = np.asarray(tree_ids)
    s_anchor, s_level, s_stype = (np.asarray(s.anchor), np.asarray(s.level),
                                  np.asarray(s.stype))
    nb, dual = bops.face_neighbor(s, face)
    inside = np.asarray(bops.is_inside_root(nb))
    tgt = tree_ids.copy()
    valid = inside.copy()
    kind = np.where(inside, F.FACE_INTERIOR, F.FACE_DOMAIN_BOUNDARY).astype(np.int32)
    dual_np = np.asarray(dual).copy()
    anchor = np.asarray(nb.anchor)
    stype = np.asarray(nb.stype)
    cm = f.cmesh
    if cm is not None and not inside.all():
        anchor = anchor.copy()
        stype = stype.copy()
        out_idx = np.nonzero(~inside)[0]
        src = Simplex(jnp.asarray(s_anchor[out_idx]), jnp.asarray(s_level[out_idx]),
                      jnp.asarray(s_stype[out_idx]))
        rf = cm.root_face_of(src, face)
        groups = {}
        for pos, (t1, rfv) in enumerate(zip(tree_ids[out_idx], rf)):
            if rfv >= 0 and cm.face_tree[t1, rfv] >= 0:
                groups.setdefault((int(t1), int(rfv)), []).append(pos)
        for (t1, rfv), poss in groups.items():
            idx = out_idx[np.asarray(poss)]
            sub = Simplex(jnp.asarray(anchor[idx]), jnp.asarray(s_level[idx]),
                          jnp.asarray(stype[idx]))
            s2, t2 = cm.transform_across_face(sub, t1, rfv, bops=bops)
            old_stype = stype[idx]
            anchor[idx] = np.asarray(s2.anchor)
            stype[idx] = np.asarray(s2.stype)
            dual_np[idx] = cm.face_facemap[t1, rfv][old_stype, dual_np[idx]]
            tgt[idx] = t2
            valid[idx] = True
            kind[idx] = F.FACE_INTER_TREE
    nb = Simplex(jnp.asarray(anchor), nb.level, jnp.asarray(stype))
    nkey = bops.morton_key_np(nb)
    return tgt, nkey, valid, nb, dual_np, kind


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_sweep_layer_matches_composed_lookup(name, backend):
    """On every multitree fixture (interior + inter-tree + domain-boundary
    faces) the fused layer equals the composed per-face lookup, element for
    element, on every backend."""
    with batch.use_backend(backend):
        f = _fixture_forest(name)
        s = f.simplices()
        sweep = F.face_sweep_layer(f, f.tree, s)
        assert {int(k) for k in np.unique(sweep.kind)} <= {
            F.FACE_INTERIOR, F.FACE_INTER_TREE, F.FACE_DOMAIN_BOUNDARY}
        for face in range(f.d + 1):
            tgt, nkey, valid, nb, dual, kind = composed_face_lookup(
                f, f.tree, s, face)
            np.testing.assert_array_equal(sweep.tgt[face], tgt)
            np.testing.assert_array_equal(sweep.valid[face], valid)
            np.testing.assert_array_equal(sweep.dual[face], dual)
            np.testing.assert_array_equal(sweep.kind[face], kind)
            np.testing.assert_array_equal(sweep.nkey[face], nkey)
            np.testing.assert_array_equal(sweep.anchor[face], np.asarray(nb.anchor))
            np.testing.assert_array_equal(sweep.stype[face], np.asarray(nb.stype))
            # the public single-face view slices the same sweep
            got = F._face_lookup(f, f.tree, s, face)
            np.testing.assert_array_equal(got[0], tgt)
            np.testing.assert_array_equal(got[1], nkey)
        if f.cmesh is not None:
            assert (sweep.kind == F.FACE_INTER_TREE).any(), name


@pytest.mark.parametrize("name", ["kuhn2_d2", "single_tree_d3"])
def test_balance_and_ghost_fuse_the_face_dispatches(name):
    """The point of the fusion: Balance/Ghost evaluation issues `face_sweep`
    dispatches ONLY — never per-face face_neighbor / is_inside_root — and
    Ghost's routing pass is exactly one sweep per non-empty rank."""
    d, mk_cmesh, base, deep = FIXTURES[name]
    cm = mk_cmesh()
    num_trees = cm.num_trees if cm is not None else 2
    comm = F.SimComm(2)
    fs = F.new_uniform(d, num_trees, base, comm, cmesh=cm)

    def corner(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < deep)).astype(np.int32)

    fs = [F.adapt(f, corner, recursive=True) for f in fs]
    batch.reset_dispatch_counts()
    out = F.balance(fs, comm)
    counts = batch.dispatch_counts()
    assert counts.get("face_sweep", 0) > 0
    assert counts.get("face_neighbor", 0) == 0, counts
    assert counts.get("is_inside_root", 0) == 0, counts
    batch.reset_dispatch_counts()
    F.ghost(out, comm)
    counts = batch.dispatch_counts()
    nonempty = sum(1 for f in out if f.num_local)
    assert counts.get("face_sweep", 0) == nonempty, counts
    assert counts.get("face_neighbor", 0) == 0, counts
    batch.reset_dispatch_counts()
