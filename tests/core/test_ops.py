"""Property tests for the vectorized element algorithms vs. Python oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from repro.core import ops2d, ops3d, simplex, root
from repro.core import u64 as u64m
from repro.core import reference as R
from repro.core.types import Simplex

from _helpers import rand_simplices

OPS = {2: ops2d, 3: ops3d}


@pytest.mark.parametrize("d", [2, 3])
def test_linear_id_roundtrip_deep_levels(d):
    o = OPS[d]
    s = rand_simplices(d, 256, seed=1, max_level=o.L)
    ids = o.linear_id(s)
    s2 = o.from_linear_id(ids, s.level)
    np.testing.assert_array_equal(np.asarray(s2.anchor), np.asarray(s.anchor))
    np.testing.assert_array_equal(np.asarray(s2.stype), np.asarray(s.stype))


@pytest.mark.parametrize("d", [2, 3])
def test_linear_id_matches_reference(d):
    o = OPS[d]
    s = rand_simplices(d, 32, seed=2, max_level=5)
    ids = u64m.to_np(o.linear_id(s))
    A, L, B = np.asarray(s.anchor), np.asarray(s.level), np.asarray(s.stype)
    for i in range(len(ids)):
        tet = (tuple(int(x) for x in A[i]), int(L[i]), int(B[i]))
        assert int(ids[i]) == R.ref_linear_id(d, tet)


@pytest.mark.parametrize("d", [2, 3])
def test_uniform_enumeration_matches_tm_order(d):
    o = OPS[d]
    lvl = 2
    ref = R.ref_uniform_level(d, lvl)
    n = o.num_elements(lvl)
    s = o.from_linear_id(u64m.from_int(np.arange(n, dtype=np.uint64)), jnp.full((n,), lvl))
    got = [
        (tuple(int(x) for x in np.asarray(s.anchor)[i]), lvl, int(np.asarray(s.stype)[i]))
        for i in range(n)
    ]
    assert got == ref


@pytest.mark.parametrize("d", [2, 3])
def test_parent_child_roundtrip(d):
    o = OPS[d]
    s = rand_simplices(d, 128, seed=3, max_level=o.L - 1)
    for i in range(o.nc):
        c = o.child_tm(s, i)
        p = o.parent(c)
        np.testing.assert_array_equal(np.asarray(p.anchor), np.asarray(s.anchor))
        np.testing.assert_array_equal(np.asarray(p.stype), np.asarray(s.stype))
        np.testing.assert_array_equal(np.asarray(o.local_index(c)), np.full(s.shape, i))
        # Bey/TM index conversion consistency (Algorithm 4.5)
        bey = o.LOCAL_TO_BEY[s.stype, i]
        c2 = o.child_bey(s, bey)
        np.testing.assert_array_equal(np.asarray(c2.anchor), np.asarray(c.anchor))
        np.testing.assert_array_equal(np.asarray(c2.stype), np.asarray(c.stype))


@pytest.mark.parametrize("d", [2, 3])
def test_children_against_reference(d):
    o = OPS[d]
    s = rand_simplices(d, 16, seed=4, max_level=4)
    A, L, B = np.asarray(s.anchor), np.asarray(s.level), np.asarray(s.stype)
    for i in range(len(L)):
        tet = (tuple(int(x) for x in A[i]), int(L[i]), int(B[i]))
        want = R.ref_children_bey(d, tet)
        for bey in range(o.nc):
            c = o.child_bey(Simplex(s.anchor[i], s.level[i], s.stype[i]), bey)
            got = (tuple(int(x) for x in np.asarray(c.anchor)), int(c.level), int(c.stype))
            assert got == want[bey]


@pytest.mark.parametrize("d", [2, 3])
def test_successor_predecessor(d):
    o = OPS[d]
    lvl = 3
    n = o.num_elements(lvl)
    ids = np.arange(n - 1, dtype=np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.full((n - 1,), lvl))
    succ = o.successor(s)
    back = o.predecessor(succ)
    np.testing.assert_array_equal(np.asarray(back.anchor), np.asarray(s.anchor))
    np.testing.assert_array_equal(np.asarray(back.stype), np.asarray(s.stype))
    got_ids = u64m.to_np(o.linear_id(succ))
    np.testing.assert_array_equal(got_ids, ids + 1)


@pytest.mark.parametrize("d", [2, 3])
def test_successor_matches_paper_recursion(d):
    o = OPS[d]
    lvl = 4 if d == 2 else 3
    rng = np.random.default_rng(5)
    ids = rng.integers(0, o.num_elements(lvl) - 1, size=16).astype(np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.full((16,), lvl))
    succ = o.successor(s)
    A, B = np.asarray(s.anchor), np.asarray(s.stype)
    SA, SB = np.asarray(succ.anchor), np.asarray(succ.stype)
    for i in range(16):
        tet = (tuple(int(x) for x in A[i]), lvl, int(B[i]))
        want = R.ref_successor(d, tet)
        assert (tuple(int(x) for x in SA[i]), lvl, int(SB[i])) == want


@pytest.mark.parametrize("d", [2, 3])
def test_face_neighbor_involution(d):
    o = OPS[d]
    s = rand_simplices(d, 256, seed=6, max_level=o.L)
    for f in range(d + 1):
        nb, fd = o.face_neighbor(s, f)
        back, f2 = o.face_neighbor(nb, fd)
        np.testing.assert_array_equal(np.asarray(back.anchor), np.asarray(s.anchor))
        np.testing.assert_array_equal(np.asarray(back.stype), np.asarray(s.stype))
        np.testing.assert_array_equal(np.asarray(f2), np.full(s.shape, f))


@pytest.mark.parametrize("d", [2, 3])
def test_neighbor_shares_d_vertices(d):
    """Geometric check: a face neighbor shares exactly d corner nodes."""
    o = OPS[d]
    s = rand_simplices(d, 64, seed=7, max_level=6)
    coords = np.asarray(o.coordinates(s))
    for f in range(d + 1):
        nb, _ = o.face_neighbor(s, f)
        nc = np.asarray(o.coordinates(nb))
        for i in range(64):
            a = {tuple(v) for v in coords[i].tolist()}
            b = {tuple(v) for v in nc[i].tolist()}
            assert len(a & b) == d


@pytest.mark.parametrize("d", [2, 3])
def test_is_ancestor_vs_oracle(d):
    o = OPS[d]
    anc_lvl = 1
    ref_anc = R.ref_uniform_level(d, anc_lvl)
    ref_dsc = R.ref_uniform_level(d, anc_lvl + 2)
    for ta in ref_anc:
        a = simplex(np.array(ta[0]), ta[1], ta[2])
        for td in ref_dsc:
            nsim = simplex(np.array(td[0]), td[1], td[2])
            got = bool(o.is_ancestor(a, nsim))
            want = R.ref_is_descendant(d, td, ta)
            assert got == want, (ta, td)


@pytest.mark.parametrize("d", [2, 3])
def test_theorem16_locality(d):
    """Theorem 16 (iii): descendants of T are contiguous in the SFC order."""
    o = OPS[d]
    lvl, dl = 1, 2
    coarse = R.ref_uniform_level(d, lvl)
    fine = R.ref_uniform_level(d, lvl + dl)  # already TM-sorted
    for ta in coarse:
        a = simplex(np.array(ta[0]), ta[1], ta[2])
        flags = []
        for td in fine:
            flags.append(R.ref_is_descendant(d, td, ta))
        arr = np.array(flags)
        (idx,) = np.nonzero(arr)
        assert len(idx) == o.nc ** dl
        assert idx[-1] - idx[0] + 1 == len(idx), "descendants not contiguous"


@pytest.mark.parametrize("d", [2, 3])
def test_morton_key_prefix_property(d):
    """Theorem 16 (i)+(ii) via keys: ancestor keys are <= and prefix-aligned."""
    o = OPS[d]
    s = rand_simplices(d, 256, seed=8, max_level=o.L)
    anc = o.ancestor_at_level(s, jnp.maximum(s.level - 3, 0))
    ks = u64m.to_np(o.morton_key(s))
    ka = u64m.to_np(o.morton_key(anc))
    lv = np.asarray(anc.level)
    # key(anc) is key(s) with the fine digits zeroed
    shift = np.uint64(d) * (np.uint64(o.L) - lv.astype(np.uint64))
    np.testing.assert_array_equal(ka >> shift, ks >> shift)
    assert np.all(ka <= ks)


@pytest.mark.parametrize("d", [2, 3])
def test_type_ratios_prop8(d):
    """Proposition 8: types equidistribute in uniform refinements."""
    o = OPS[d]
    lvl = 4 if d == 3 else 6
    n = o.num_elements(lvl)
    s = o.from_linear_id(u64m.from_int(np.arange(n, dtype=np.uint64)), jnp.full((n,), lvl))
    counts = np.bincount(np.asarray(s.stype), minlength=o.nt)
    ratios = counts / n
    assert np.all(np.abs(ratios - 1 / o.nt) < 0.05), ratios


@given(st.integers(0, 2**63 - 1), st.integers(0, 2**63 - 1))
@settings(max_examples=200, deadline=None)
def test_u64_arithmetic(a, b):
    ua, ub = u64m.from_int(a), u64m.from_int(b)
    assert int(u64m.to_np(u64m.add(ua, ub))) == (a + b) % 2**64
    assert int(u64m.to_np(u64m.sub(ua, ub))) == (a - b) % 2**64
    assert bool(u64m.lt(ua, ub)) == (a < b)
    assert bool(u64m.le(ua, ub)) == (a <= b)
    assert bool(u64m.eq(ua, ub)) == (a == b)
    for k in (0, 1, 3, 31, 32, 33, 63):
        assert int(u64m.to_np(u64m.shl(ua, k))) == (a << k) % 2**64
        assert int(u64m.to_np(u64m.shr(ua, k))) == a >> k
        kk = jnp.int32(k)
        assert int(u64m.to_np(u64m.select_shl(ua, kk, 63))) == (a << k) % 2**64
        assert int(u64m.to_np(u64m.select_shr(ua, kk, 63))) == a >> k


@given(st.integers(1, 5), st.data())
@settings(max_examples=30, deadline=None)
def test_hypothesis_roundtrips_3d(lvl, data):
    o = ops3d
    I = data.draw(st.integers(0, o.num_elements(lvl) - 1))
    s = o.from_linear_id(u64m.from_int(I), lvl)
    assert int(u64m.to_np(o.linear_id(s))) == I
    if lvl < o.L:
        kids = o.children_tm(s)
        ids = u64m.to_np(o.linear_id(kids))
        np.testing.assert_array_equal(ids, I * o.nc + np.arange(o.nc, dtype=np.uint64))
