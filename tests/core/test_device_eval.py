"""Device-resident fused eval stage (`BatchedOps.sweep_full` / `eval_2to1` /
`eval_cache` / `eval_route`).

The reference backend computes every mask eagerly on the host and is the
oracle; the jnp and pallas backends must match it bit for bit — need-masks,
boundary masks, cache-eval masks, and the compacted routing rows — over all
multitree fixtures x adapt patterns x partition sizes.  On top of parity the
suite pins the per-round budget that makes the fusion a speedup at all: O(1)
batched-op dispatches, at most two host materializations per rank per round,
and ZERO jit retraces once a padding bucket is warm.
"""

import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import rand_simplices
from repro.core import batch, get_ops
from repro.core import cmesh as C
from repro.core import forest as F
from repro.core import u64 as u64m
from repro.core.types import Simplex

DEVICE_BACKENDS = ["jnp", pytest.param("pallas", marks=pytest.mark.slow)]

FIXTURES = {
    # name: (d, cmesh factory, base level, deep level)
    "kuhn2_d2": (2, lambda: C.cmesh_unit_cube(2), 2, 4),
    "kuhn6_d3": (3, lambda: C.cmesh_unit_cube(3), 1, 3),
    "periodic_d2": (2, lambda: C.cmesh_unit_cube(2, periodic=(True, True)), 2, 4),
    "rotated_pair": (2, C.cmesh_rotated_pair, 2, 4),
    "single_tree_d3": (3, lambda: None, 1, 3),
}


def _mk_forests(name, P, pattern, seed=0):
    d, mk_cmesh, base, deep = FIXTURES[name]
    cm = mk_cmesh()
    K = cm.num_trees if cm is not None else 2
    comm = F.SimComm(P)
    fs = F.new_uniform(d, K, base, comm, cmesh=cm)
    if pattern == "corner":
        def fn(tree, elems):
            a = np.asarray(elems.anchor)
            l = np.asarray(elems.level)
            return ((a.sum(1) == 0) & (l < deep)).astype(np.int32)

        fs = [F.adapt(f, fn, recursive=True) for f in fs]
    else:
        rng = np.random.default_rng(seed)

        def fn(tree, elems):
            return (rng.random(elems.level.shape[0]) < 0.3).astype(np.int32)

        fs = [F.adapt(f, fn, recursive=False) for f in fs]
    return fs, comm


def _sweep_and_table(bops, f):
    """Mirror the balance/ghost layer construction for one rank."""
    if f.num_local == 0:
        return None, None
    table = bops.upload_table(f.tree, f.keys, f.level)
    if f.cmesh is None:
        return bops.sweep_full(f.simplices(), f.tree), table
    sw = F.face_sweep_layer(f, f.tree, f.simplices())
    return bops.sweep_from_host(
        sw.tgt, sw.nkey, sw.valid, sw.dual, sw.level), table


def _route_rows(rp):
    return (np.asarray(rp.tree), np.asarray(rp.key), np.asarray(rp.level),
            np.asarray(rp.dual), np.asarray(rp.first), np.asarray(rp.last))


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
@pytest.mark.parametrize("pattern", ["corner", "random"])
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fused_eval_backend_parity(name, pattern, backend):
    """reference == device backend for every fused eval output: the 2:1
    need-mask and boundary mask, the remote-cache need-mask, and the
    compacted (tree, key, level, dual, first, last) routing rows — on every
    rank of every fixture, including the cmesh cross-tree sweeps."""
    fs, comm = _mk_forests(name, 3, pattern)
    mt, mk = F.partition_markers(fs, comm)
    d = fs[0].d
    ref = batch.get_batch_ops(d, "reference")
    dev = batch.get_batch_ops(d, backend)
    # a synthetic remote-leaf cache: every OTHER rank's leaves, lex-sorted —
    # the shape eval_cache sees after balance folds replies in
    for i, f in enumerate(fs):
        g = comm.local_ranks[i]
        others = [o for j, o in enumerate(fs) if j != i and o.num_local]
        ct = np.concatenate([o.tree for o in others])
        ck = np.concatenate([o.keys for o in others])
        cl = np.concatenate([o.level for o in others])
        order = np.lexsort((cl, ck, ct))
        sw_r, tb_r = _sweep_and_table(ref, f)
        sw_d, tb_d = _sweep_and_table(dev, f)
        cache_r = ref.upload_table(ct[order], ck[order], cl[order])
        cache_d = dev.upload_table(ct[order], ck[order], cl[order])
        need_r, bm_r = ref.eval_2to1(sw_r, tb_r, mt, mk, g)
        need_d, bm_d = dev.eval_2to1(sw_d, tb_d, mt, mk, g)
        np.testing.assert_array_equal(need_d, need_r, err_msg=f"need rank {g}")
        np.testing.assert_array_equal(bm_d, bm_r, err_msg=f"bmask rank {g}")
        cn_r = ref.eval_cache(sw_r, cache_r, mt, mk, g)
        cn_d = dev.eval_cache(sw_d, cache_d, mt, mk, g)
        np.testing.assert_array_equal(cn_d, cn_r, err_msg=f"cache rank {g}")
        rp_r = _route_rows(ref.eval_route(sw_r, mt, mk, g))
        rp_d = _route_rows(dev.eval_route(sw_d, mt, mk, g))
        for col_d, col_r, what in zip(
                rp_d, rp_r, ("tree", "key", "level", "dual", "first", "last")):
            np.testing.assert_array_equal(
                col_d, col_r, err_msg=f"route {what} rank {g}")


@pytest.mark.parametrize("backend", ["reference"] + DEVICE_BACKENDS)
def test_fused_eval_empty_and_missing_inputs(backend):
    """Empty ranks (sw None) and empty tables short-circuit identically."""
    bops = batch.get_batch_ops(2, backend)
    mt = np.array([0, 1], np.int32)
    mk = np.array([0, 0], np.uint64)
    need, bm = bops.eval_2to1(None, None, mt, mk, 0)
    assert need.shape == (0,) and bm.shape == (0,)
    assert bops.eval_cache(None, None, mt, mk, 0).shape == (0,)
    assert len(bops.eval_route(None, mt, mk, 0).tree) == 0
    assert bops.upload_table(
        np.zeros(0, np.int32), np.zeros(0, np.uint64), np.zeros(0, np.int32)
    ) is None


@pytest.mark.parametrize("name", ["kuhn2_d2", "single_tree_d3"])
def test_balance_round_dispatch_budget(name):
    """The O(1)-dispatch invariant: one balanced no-op round issues exactly
    one face_sweep + one eval_route + one eval_2to1 per non-empty rank and
    ZERO per-face / per-element fallback dispatches."""
    fs, comm = _mk_forests(name, 2, "corner")
    fs = F.balance(fs, comm)
    nonempty = sum(1 for f in fs if f.num_local)
    batch.reset_dispatch_counts()
    F.balance(fs, comm)
    counts = batch.dispatch_counts()
    assert counts.get("face_sweep", 0) == nonempty, counts
    assert counts.get("eval_route", 0) == nonempty, counts
    assert counts.get("eval_2to1", 0) == nonempty, counts
    assert counts.get("eval_cache", 0) == 0, counts
    for banned in ("face_neighbor", "is_inside_root", "owner_rank"):
        assert counts.get(banned, 0) == 0, counts
    batch.reset_dispatch_counts()


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
@pytest.mark.parametrize("name", ["kuhn2_d2", "single_tree_d3"])
def test_balance_round_host_fetch_budget(name, backend):
    """<=2 host materializations per rank per round on the device backends:
    the compacted routing rows and the fused need/boundary masks — never a
    per-field sweep fan-out."""
    with batch.use_backend(backend):
        fs, comm = _mk_forests(name, 2, "corner")
        fs = F.balance(fs, comm)
        nonempty = sum(1 for f in fs if f.num_local)
        batch.reset_host_fetch_counts()
        F.balance(fs, comm)
        fetches = batch.host_fetch_counts()
        assert fetches.get("eval_route", 0) == nonempty, fetches
        assert fetches.get("eval_2to1", 0) == nonempty, fetches
        assert fetches.get("eval_cache", 0) == 0, fetches
        batch.reset_host_fetch_counts()


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_balance_and_ghost_do_not_retrace(backend):
    """Retrace guard: at a fixed padding bucket the fused programs compile
    once — a second balance+ghost over the same forests must not trace any
    eval program again (stable padded shapes are the point of bucketing)."""
    with batch.use_backend(backend):
        fs, comm = _mk_forests("kuhn2_d2", 2, "corner")
        fs = F.balance(fs, comm)
        F.balance(fs, comm)  # warm every bucket this workload touches
        F.ghost(fs, comm)
        batch.reset_trace_counts()
        F.balance(fs, comm)
        F.ghost(fs, comm)
        traces = batch.trace_counts()
        assert all(v == 0 for v in traces.values()), traces
        batch.reset_trace_counts()


@pytest.mark.parametrize("d", [2, 3])
def test_eval_route_kernel_matches_ref(d):
    """The pallas routing kernel (interpret mode) equals `eval_route_ref`
    elementwise: interval-end key words and [first, last] owner ranks."""
    from repro.core.batch import _pad_markers
    from repro.kernels import ref as kref
    from repro.kernels import sfc as ksfc

    o = get_ops(d)
    rng = np.random.default_rng(d)
    N, nf, P = 128, d + 1, 5
    lvl = rng.integers(0, o.L + 1, (N, nf)).astype(np.int32)
    shift = (np.uint64(d) * (np.uint64(o.L) - lvl.astype(np.uint64)))
    raw = rng.integers(0, 1 << 62, (N, nf), dtype=np.uint64)
    key = (raw >> shift) << shift  # span-aligned, as real neighbor keys are
    key &= np.uint64((1 << (d * o.L)) - 1)
    t = rng.integers(0, 4, (N, nf)).astype(np.int32)
    mt = np.sort(rng.integers(0, 4, P)).astype(np.int32)
    mk = rng.integers(0, 1 << (d * o.L), P).astype(np.uint64)
    order = np.lexsort((mk, mt))
    mt_p, mk_p = _pad_markers(mt[order], mk[order])
    mhi = (mk_p >> np.uint64(32)).astype(np.uint32)
    mlo = mk_p.astype(np.uint32)
    hi = (key >> np.uint64(32)).astype(np.uint32)
    lo = key.astype(np.uint32)
    got = ksfc.eval_route_kernel(
        d, jnp.asarray(t), jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(lvl),
        jnp.asarray(mt_p), jnp.asarray(mhi), jnp.asarray(mlo),
        block=64, interpret=True)
    want = kref.eval_route_ref(d, t, hi, lo, lvl, mt_p, mhi, mlo)
    for g, w, what in zip(got, want, ("end_hi", "end_lo", "first", "last")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=what)
