"""At-rest encoding tests (paper Remark 20): pack/unpack round-trip and the
exact 10-bytes-per-triangle / 14-bytes-per-tetrahedron storage bound."""

import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import rand_simplices
from repro.core import get_ops
from repro.core import u64 as u64m
from repro.core.types import Simplex, nbytes_at_rest, pack, simplex, unpack


@pytest.mark.parametrize("d", [2, 3])
def test_pack_unpack_roundtrip(d):
    s = rand_simplices(d, 257, seed=d, min_level=0)
    back = unpack(pack(s))
    np.testing.assert_array_equal(np.asarray(back.anchor), np.asarray(s.anchor))
    np.testing.assert_array_equal(np.asarray(back.level), np.asarray(s.level))
    np.testing.assert_array_equal(np.asarray(back.stype), np.asarray(s.stype))
    assert back.anchor.dtype == jnp.int32
    assert back.level.dtype == jnp.int32
    assert back.stype.dtype == jnp.int32


@pytest.mark.parametrize("d,per_elem", [(2, 10), (3, 14)])
def test_nbytes_at_rest_matches_remark_20(d, per_elem):
    """Remark 20: 4 bytes per coordinate + 1 byte level + 1 byte type
    = exactly 10 B per triangle, 14 B per tetrahedron."""
    for n in (1, 7, 1024):
        s = rand_simplices(d, n, seed=n + d, min_level=0)
        assert nbytes_at_rest(s) == per_elem * n
        blob = pack(s)
        actual = sum(a.nbytes for a in blob.values())
        assert actual == nbytes_at_rest(s)


@pytest.mark.parametrize("d", [2, 3])
def test_pack_preserves_extremes(d):
    """Deep levels use the full int32 coordinate range; level/type must
    survive the int8 narrowing (MAXLEVEL <= 30 < 127, types < 6)."""
    o = get_ops(d)
    ids = u64m.from_int(np.array([o.num_elements(o.L) - 1], np.uint64))
    s = o.from_linear_id(ids, jnp.full(1, o.L, jnp.int32))
    back = unpack(pack(s))
    np.testing.assert_array_equal(np.asarray(back.anchor), np.asarray(s.anchor))
    assert int(back.level[0]) == o.L
    assert int(back.stype[0]) == int(np.asarray(s.stype)[0])


def test_scalar_simplex_nbytes():
    assert nbytes_at_rest(simplex(np.zeros(3), 0, 0)) == 14
    assert nbytes_at_rest(simplex(np.zeros(2), 0, 0)) == 10


# ------------------------------------------------------------ element classes
@pytest.mark.parametrize("d,per_elem", [(2, 9), (3, 13)])
def test_hex_nbytes_at_rest(d, per_elem):
    """Hexes carry no type byte: 4d + 1 = 9 B per quad, 13 B per hex."""
    from repro.core.types import ECLASS_HEX

    for n in (1, 7, 1024):
        s = rand_simplices(d, n, seed=n + d, min_level=0, eclass=ECLASS_HEX)
        assert nbytes_at_rest(s, eclass=ECLASS_HEX) == per_elem * n
        blob = pack(s, eclass=ECLASS_HEX)
        assert "stype" not in blob
        assert sum(a.nbytes for a in blob.values()) == per_elem * n
        back = unpack(blob)
        np.testing.assert_array_equal(np.asarray(back.anchor), np.asarray(s.anchor))
        np.testing.assert_array_equal(np.asarray(back.level), np.asarray(s.level))
        assert not np.asarray(back.stype).any()  # hex stype lane is all-zero


def test_pack_rejects_unknown_eclass():
    s = rand_simplices(2, 3, seed=0, min_level=0)
    with pytest.raises(ValueError):
        pack(s, eclass=7)
    with pytest.raises(ValueError):
        nbytes_at_rest(s, eclass=7)


def test_simplex_pack_blob_golden_bytes():
    """The simplex at-rest encoding is pinned byte for byte to the
    pre-eclass layout (old checkpoints must keep loading): int32 LE anchor
    rows, int8 level, int8 type — no eclass tag anywhere in the blob."""
    s = simplex(np.array([[1, 2, 3], [4, 5, 6]], np.int32), [7, 8], [0, 5])
    blob = pack(s)
    assert sorted(blob.keys()) == ["anchor", "level", "stype"]
    assert blob["anchor"].tobytes() == (
        b"\x01\x00\x00\x00\x02\x00\x00\x00\x03\x00\x00\x00"
        b"\x04\x00\x00\x00\x05\x00\x00\x00\x06\x00\x00\x00")
    assert blob["level"].tobytes() == b"\x07\x08"
    assert blob["stype"].tobytes() == b"\x00\x05"
