"""Wire hardening: integrity framing, codec fuzzing, packed-triple guards.

Three layers, one invariant — malformed bytes NEVER decode silently wrong:

  * `frame_blob`/`unframe_blob`: any single-byte flip, truncation, or
    duplication of a framed transport blob raises `WireIntegrityError`
    (CRC32 detects all single-byte errors; the length field catches every
    size change);
  * `decode_payload`: random byte mutations of valid `encode_payload`
    buffers either decode (harmless mutation) or raise the structured
    `WireFormatError` — never a bare `struct.error`, `IndexError`,
    `UnicodeDecodeError`, or assert;
  * `types.unpack_wire`: ragged or garbage packed-triple buffers raise
    `WireFormatError` instead of asserting or viewing misaligned columns.
"""

import numpy as np
import pytest

from repro.core.comm import (
    _FRAME,
    decode_payload,
    encode_payload,
    frame_blob,
    unframe_blob,
)
from repro.core.errors import WireFormatError, WireIntegrityError
from repro.core.types import pack_wire, unpack_wire


def _sample_payloads():
    rng = np.random.default_rng(7)
    return [
        None,
        True,
        -17,
        1 << 70,
        3.25,
        "balance:q",
        b"\x00\x01\x02",
        np.arange(33, dtype=np.uint64),
        rng.integers(0, 100, (5, 3)).astype(np.int32),
        [np.float64(1.5), "x", None, (2, 3)],
        {"tree": np.arange(4, dtype=np.int32), "lvl": 2,
         "nested": {"k": [b"ab", False]}},
    ]


@pytest.mark.parametrize("obj", _sample_payloads())
def test_frame_roundtrip(obj):
    blob = encode_payload(obj)
    framed = frame_blob(blob)
    assert len(framed) == len(blob) + _FRAME.size
    assert unframe_blob(framed) == blob
    back = decode_payload(unframe_blob(framed))
    assert type(back) is type(obj) or isinstance(obj, (list, tuple, dict))


def test_frame_detects_every_single_byte_flip():
    """CRC32 detects all single-byte errors; header flips hit the magic,
    length, or checksum checks — so every position must raise."""
    blob = encode_payload({"a": np.arange(9, dtype=np.int32), "b": "xyz"})
    framed = frame_blob(blob)
    for idx in range(len(framed)):
        bad = bytearray(framed)
        bad[idx] ^= 0x5A
        with pytest.raises(WireIntegrityError):
            unframe_blob(bytes(bad), where=f"flip@{idx}")


def test_frame_detects_truncation_and_duplication():
    framed = frame_blob(encode_payload(list(range(50))))
    for cut in (1, 7, len(framed) - _FRAME.size, len(framed) - 1):
        with pytest.raises(WireIntegrityError):
            unframe_blob(framed[:-cut])
    with pytest.raises(WireIntegrityError):  # shorter than the header
        unframe_blob(framed[: _FRAME.size - 1])
    with pytest.raises(WireIntegrityError):  # body doubled
        unframe_blob(framed + framed[_FRAME.size:])
    with pytest.raises(WireIntegrityError):  # whole frame doubled
        unframe_blob(framed + framed)
    with pytest.raises(WireIntegrityError):  # foreign magic
        unframe_blob(b"XX99" + framed[4:])


def test_frame_where_context_in_message():
    framed = bytearray(frame_blob(encode_payload(1)))
    framed[-1] ^= 1
    with pytest.raises(WireIntegrityError) as ei:
        unframe_blob(bytes(framed), where="balance:a2a:gen3:1->0")
    assert "balance:a2a:gen3:1->0" in str(ei.value)
    assert ei.value.where == "balance:a2a:gen3:1->0"


def test_decode_rejects_truncations_structurally():
    """Every proper prefix of a valid buffer must raise WireFormatError
    (the decoder runs out of bytes) — no prefix may decode cleanly, since
    the codec has no padding."""
    blob = encode_payload({"a": np.arange(6, dtype=np.uint64),
                           "s": "hello", "n": [1, 2, None]})
    for cut in range(1, len(blob)):
        with pytest.raises(WireFormatError):
            decode_payload(blob[:cut])


def test_decode_rejects_trailing_garbage():
    blob = encode_payload([1, 2, 3])
    with pytest.raises(WireFormatError, match="trailing"):
        decode_payload(blob + b"\x00")


def test_decode_rejects_bogus_counts_and_tags():
    with pytest.raises(WireFormatError):
        decode_payload(b"")                          # empty buffer
    with pytest.raises(WireFormatError):
        decode_payload(b"Z")                         # unknown tag
    with pytest.raises(WireFormatError):
        decode_payload(b"l\xff\xff\xff\xff")         # 4G-element list
    with pytest.raises(WireFormatError):
        decode_payload(b"d\xff\xff\xff\x7f")         # huge dict count
    with pytest.raises(WireFormatError):
        decode_payload(b"s\x10\x00\x00\x00ab")       # short string body
    with pytest.raises(WireFormatError):
        decode_payload(b"a\x04<u8!")                 # truncated array header
    with pytest.raises(WireFormatError):
        # invalid dtype string
        decode_payload(b"a\x03zzz\x01\x01\x00\x00\x00" + b"\x00" * 8)
    with pytest.raises(WireFormatError):
        # object dtype is not a wire type
        decode_payload(b"a\x02|O\x01\x01\x00\x00\x00" + b"\x00" * 8)


def test_decode_fuzz_random_mutations_never_crash_unstructured():
    """Property fuzz (seeded): mutate valid payload buffers with byte
    flips, truncations, insertions, and swaps; every outcome is either a
    clean decode or a `WireFormatError`.  Anything else — struct.error,
    IndexError, UnicodeDecodeError, SystemError from numpy — is the class
    of bug this satellite exists to kill."""
    rng = np.random.default_rng(0xC0FFEE)
    payloads = [encode_payload(p) for p in _sample_payloads()]
    outcomes = {"ok": 0, "rejected": 0}
    for trial in range(400):
        blob = bytearray(payloads[int(rng.integers(len(payloads)))])
        for _ in range(1 + int(rng.integers(3))):
            op = int(rng.integers(4))
            if op == 0 and blob:                      # flip
                blob[int(rng.integers(len(blob)))] ^= 1 + int(rng.integers(255))
            elif op == 1 and len(blob) > 1:           # truncate
                del blob[int(rng.integers(1, len(blob))):]
            elif op == 2:                             # insert garbage
                at = int(rng.integers(len(blob) + 1))
                blob[at:at] = bytes(rng.integers(0, 256, 1 + int(rng.integers(4)),
                                                 dtype=np.uint8))
            elif blob:                                # swap two bytes
                i, j = rng.integers(0, len(blob), 2)
                blob[int(i)], blob[int(j)] = blob[int(j)], blob[int(i)]
        try:
            decode_payload(bytes(blob))
            outcomes["ok"] += 1
        except WireFormatError:
            outcomes["rejected"] += 1
    # the fuzz must actually exercise the reject path
    assert outcomes["rejected"] > 100, outcomes


def test_framed_fuzz_mutation_always_detected_or_identical():
    """The transport-level guarantee behind 'never a silently wrong
    forest': a mutated FRAMED blob either unframes to the identical body
    (mutation missed the frame entirely — impossible here since we always
    change at least one byte) or raises `WireIntegrityError`."""
    rng = np.random.default_rng(1234)
    for trial in range(300):
        obj = _sample_payloads()[trial % len(_sample_payloads())]
        framed = bytearray(frame_blob(encode_payload(obj)))
        kind = trial % 3
        if kind == 0:
            framed[int(rng.integers(len(framed)))] ^= 1 + int(rng.integers(255))
        elif kind == 1:
            del framed[len(framed) - 1 - int(rng.integers(len(framed) - 1)):]
        else:
            framed.extend(framed[_FRAME.size:] or b"\x00")
        with pytest.raises(WireIntegrityError):
            unframe_blob(bytes(framed), where=f"fuzz:{trial}")


def test_unpack_wire_rejects_ragged_buffers():
    buf = pack_wire([0, 1], [5, 9], [1, 2])
    t, k, lv = unpack_wire(buf)
    np.testing.assert_array_equal(t, [0, 1])
    np.testing.assert_array_equal(k, [5, 9])
    np.testing.assert_array_equal(lv, [1, 2])
    for cut in (1, 5, 12):
        with pytest.raises(WireFormatError):
            unpack_wire(buf[:-cut])
    with pytest.raises(WireFormatError):
        unpack_wire(np.r_[buf, np.zeros(3, np.uint8)])
    with pytest.raises(WireFormatError):
        unpack_wire(buf, with_extra=True)  # 26 bytes is not a multiple of 14


def test_unpack_wire_rejects_garbage_columns():
    # entry-aligned garbage: all 0xFF decodes to tree=-1, level=255 — both
    # out of domain, so the plausibility guards must fire
    with pytest.raises(WireFormatError):
        unpack_wire(np.full(13, 0xFF, np.uint8))
    ok = pack_wire([2], [77], [63])  # level 63 is the domain edge: accepted
    t, k, lv = unpack_wire(ok)
    assert int(lv[0]) == 63


# ---------------------------------------------------------- wire eclass tag
def test_pack_wire_eclass_tag_roundtrip():
    """The element class rides in bits 6-7 of the level byte; simplex
    entries (class 0) are byte-identical to the pre-eclass wire format."""
    from repro.core.types import ECLASS_HEX, WIRE_ECLASS_SHIFT

    t, k, lv = [0, 1, 2], [5, 9, 77], [1, 2, 63]
    plain = pack_wire(t, k, lv)
    tagged0 = pack_wire(t, k, lv, eclass=0)
    assert plain.tobytes() == tagged0.tobytes()
    hexed = pack_wire(t, k, lv, eclass=ECLASS_HEX)
    assert hexed.tobytes() != plain.tobytes()
    t2, k2, lv2, ec2 = unpack_wire(hexed, with_eclass=True)
    np.testing.assert_array_equal(t2, t)
    np.testing.assert_array_equal(k2, k)
    np.testing.assert_array_equal(lv2, lv)  # levels survive the tag bits
    np.testing.assert_array_equal(ec2, [ECLASS_HEX] * 3)
    # per-entry class column (mixed-mesh repartition blobs)
    mixed = pack_wire(t, k, lv, eclass=np.array([0, 1, 0]))
    _, _, _, ecm = unpack_wire(mixed, with_eclass=True)
    np.testing.assert_array_equal(ecm, [0, 1, 0])


def test_pack_wire_rejects_unknown_eclass():
    with pytest.raises(ValueError):
        pack_wire([0], [1], [2], eclass=2)
    with pytest.raises(ValueError):
        pack_wire([0, 0], [1, 1], [2, 2], eclass=np.array([0, 3]))


def test_unpack_wire_rejects_unknown_eclass_bits():
    """Entries whose class bits exceed NUM_ECLASSES are rejected whether or
    not the caller asked for the eclass column — a hex key must never be
    silently routed through simplex decode (nor vice versa)."""
    from repro.core.types import WIRE_ECLASS_SHIFT

    buf = pack_wire([3], [42], [5]).copy()
    rec = buf.view(np.dtype([("key", "<u8"), ("tree", "<i4"), ("level", "u1")]))
    for bad in (2, 3):
        rec["level"][0] = 5 | (bad << WIRE_ECLASS_SHIFT)
        with pytest.raises(WireFormatError):
            unpack_wire(buf)
        with pytest.raises(WireFormatError):
            unpack_wire(buf, with_eclass=True)


def test_eclass_fuzz_level_byte_mutations():
    """Fuzz the level byte of valid wire entries: every mutation either
    round-trips to an in-domain (level, eclass) pair or raises the
    structured WireFormatError — never a misdecoded class."""
    from repro.core.types import (
        NUM_ECLASSES,
        WIRE_ECLASS_SHIFT,
        WIRE_LEVEL_MASK,
    )

    rng = np.random.default_rng(23)
    base = pack_wire(np.arange(8), rng.integers(0, 1 << 60, 8).astype(np.uint64),
                     rng.integers(0, 22, 8), eclass=rng.integers(0, 2, 8))
    dt = np.dtype([("key", "<u8"), ("tree", "<i4"), ("level", "u1")])
    for _ in range(200):
        buf = base.copy()
        rec = buf.view(dt)
        i = int(rng.integers(0, len(rec)))
        byte = int(rng.integers(0, 256))
        rec["level"][i] = byte
        ec = byte >> WIRE_ECLASS_SHIFT
        if ec >= NUM_ECLASSES:
            with pytest.raises(WireFormatError):
                unpack_wire(buf, with_eclass=True)
        else:
            _, _, lv2, ec2 = unpack_wire(buf, with_eclass=True)
            assert int(lv2[i]) == byte & WIRE_LEVEL_MASK
            assert int(ec2[i]) == ec
