"""Forest AMR tests: New/Adapt/Partition/Balance/Ghost invariants."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from repro.core import forest as F
from repro.core import get_ops


def refine_all(tree, elems):
    return np.ones(len(tree), np.int32)


def coarsen_all(tree, elems):
    return -np.ones(len(tree), np.int32)


def fractal_cb(max_level):
    def cb(tree, elems):
        b = np.asarray(elems.stype)
        l = np.asarray(elems.level)
        return (((b == 0) | (b == 3)) & (l < max_level)).astype(np.int32)
    return cb


@pytest.mark.parametrize("d,K,level,P", [(2, 1, 3, 2), (2, 3, 2, 4), (3, 2, 2, 4), (3, 5, 1, 3)])
def test_new_uniform_counts_and_validity(d, K, level, P):
    comm = F.SimComm(P)
    fs = F.new_uniform(d, K, level, comm)
    o = get_ops(d)
    assert F.count_global(fs) == K * o.num_elements(level)
    assert F.validate(fs)
    counts = [f.num_local for f in fs]
    assert max(counts) - min(counts) <= 1  # New is perfectly balanced


@pytest.mark.parametrize("d", [2, 3])
def test_new_expansion_equals_decode(d):
    for p in range(5):
        fa = F.new_uniform_rank(d, 3, 3, p, 5, method="decode")
        fb = F.new_uniform_rank(d, 3, 3, p, 5, method="successor")
        np.testing.assert_array_equal(fa.anchor, fb.anchor)
        np.testing.assert_array_equal(fa.stype, fb.stype)
        np.testing.assert_array_equal(fa.tree, fb.tree)


@pytest.mark.parametrize("d", [2, 3])
def test_adapt_refine_then_coarsen_roundtrip(d):
    comm = F.SimComm(2)
    fs = F.new_uniform(d, 1, 2, comm)
    fs2 = [F.adapt(f, refine_all) for f in fs]
    o = get_ops(d)
    assert F.count_global(fs2) == o.num_elements(3)
    assert F.validate(fs2)
    fs3 = [F.adapt(f, coarsen_all) for f in fs2]
    # coarsening recovers level 2 wherever families are rank-complete
    assert F.validate(fs3)
    assert F.count_global(fs3) <= F.count_global(fs2) // 2


def test_adapt_refine_coarsen_not_in_same_call():
    """Paper's recursion assumptions: refine-created elements are not
    re-coarsened within one adapt call (and vice versa)."""
    comm = F.SimComm(1)
    fs = F.new_uniform(3, 1, 1, comm)

    calls = {"n": 0}

    def flip(tree, elems):
        calls["n"] += 1
        l = np.asarray(elems.level)
        return np.where(l == 1, 1, -1).astype(np.int32)  # refine coarse, coarsen fine

    out = F.adapt(fs[0], flip, recursive=True)
    # all level-1 got refined to level 2; the new level-2 children voted -1
    # but must NOT be coarsened in the same call
    assert set(np.unique(out.level)) == {2}
    assert F.validate([out])


def test_fractal_adapt_matches_transfer_matrix():
    """Validates Adapt against the analytic count of the paper's Fig. 12
    fractal pattern (types 0 and 3 refined recursively)."""
    d, K, k0, depth = 3, 2, 2, 2
    comm = F.SimComm(4)
    fs = F.new_uniform(d, K, k0, comm)
    fs = [F.adapt(f, fractal_cb(k0 + depth), recursive=True) for f in fs]
    got = F.count_global(fs)

    # transfer matrix over types
    from repro.core.tables import get_tables
    t = get_tables(3)
    M = np.zeros((6, 6), np.int64)
    for b in range(6):
        for i in range(8):
            M[b, t.child_type[b, i]] += 1
    c = np.zeros(6, np.int64)
    c[0] = K
    for _ in range(k0):
        c = c @ M
    refinable = c[0] + c[3]
    others = c.sum() - refinable
    Fj = 1
    for _ in range(depth):
        Fj = 4 * Fj + 4
    want = refinable * Fj + others
    assert got == want


def test_partition_balances_weighted():
    comm = F.SimComm(4)
    fs = F.new_uniform(3, 2, 2, comm)
    fs = [F.adapt(f, fractal_cb(4), recursive=True) for f in fs]
    fs = F.partition(fs, comm)
    counts = [f.num_local for f in fs]
    assert max(counts) - min(counts) <= 1
    assert F.validate(fs)
    # weighted: weight 2^level
    ws = [2.0 ** f.level for f in fs]
    fs2 = F.partition(fs, comm, weights=ws)
    loads = [float((2.0 ** f.level).sum()) for f in fs2]
    assert F.validate(fs2)
    assert max(loads) / (sum(loads) / len(loads)) < 1.05


def test_partition_preserves_global_order():
    comm = F.SimComm(3)
    fs = F.new_uniform(3, 2, 2, comm)
    fs = [F.adapt(f, fractal_cb(3), recursive=True) for f in fs]
    before = np.concatenate([f.keys for f in fs])
    tbefore = np.concatenate([f.tree for f in fs])
    fs2 = F.partition(fs, comm)
    after = np.concatenate([f.keys for f in fs2])
    tafter = np.concatenate([f.tree for f in fs2])
    np.testing.assert_array_equal(before, after)
    np.testing.assert_array_equal(tbefore, tafter)


@pytest.mark.parametrize("d", [2, 3])
def test_balance_two_to_one(d):
    comm = F.SimComm(2)
    fs = F.new_uniform(d, 1, 1, comm)

    def corner_only(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < 5)).astype(np.int32)  # refine origin corner deep

    fs = [F.adapt(f, corner_only, recursive=True) for f in fs]
    fs = F.balance(fs, comm)
    assert F.validate(fs)
    # verify the 2:1 property directly
    o = get_ops(d)
    all_keys = np.concatenate([f.keys for f in fs])
    all_lvl = np.concatenate([f.level for f in fs])
    order = np.argsort(all_keys)
    keys, lvls = all_keys[order], all_lvl[order]
    from repro.core import u64 as u64m
    import jax.numpy as jnp
    for f_ in fs:
        if f_.num_local == 0:
            continue
        s = f_.simplices()
        for face in range(d + 1):
            nb, _ = o.face_neighbor(s, face)
            inside = np.asarray(o.is_inside_root(nb))
            nkey = u64m.to_np(o.morton_key(nb))
            span = np.uint64(1) << (np.uint64(d) * (np.uint64(o.L) - f_.level.astype(np.uint64)))
            lo = np.searchsorted(keys, nkey)
            hi = np.searchsorted(keys, nkey + span)
            for i in np.nonzero(inside)[0]:
                if hi[i] > lo[i]:
                    assert lvls[lo[i]:hi[i]].max() <= f_.level[i] + 1


def test_ghost_symmetric_and_remote():
    comm = F.SimComm(4)
    fs = F.new_uniform(3, 1, 2, comm)
    gh = F.ghost(fs, comm)
    for p, g in enumerate(gh):
        assert np.all(g["owner"] != p)
        # every ghost element is an actual leaf on its owner
        for j in range(len(g["level"])):
            q = int(g["owner"][j])
            mask = (
                (fs[q].level == g["level"][j])
                & (fs[q].tree == g["tree"][j])
                & (fs[q].anchor == g["anchor"][j]).all(1)
                & (fs[q].stype == g["stype"][j])
            )
            assert mask.any()


def test_iterate_faces():
    comm = F.SimComm(1)
    fs = F.new_uniform(3, 1, 2, comm)
    seen = {}

    def face_fn(f, pairs):
        seen["pairs"] = pairs
        return len(pairs)

    F.iterate(fs[0], face_fn=face_fn)
    pairs = seen["pairs"]
    # each interior face appears exactly once; count faces of uniform level-2
    # refinement of one tet: interior faces = (4 faces * n - boundary) / 2
    n = fs[0].num_local
    # boundary faces of the root tet: 4 faces, each covered by 4^2 level-2
    # triangle faces
    boundary = 4 * 16
    assert len(pairs) == (4 * n - boundary) // 2
