"""Multi-tree forest oracles: cross-tree Ghost/Balance vs brute force.

The independent check: every tree is embedded into one WORLD lattice via its
cmesh embedding, and face adjacency is recomputed there by brute-force
vertex-coordinate matching (uniform meshes: two leaves are face-adjacent iff
they share exactly d world vertices; adapted meshes: a face of the finer
leaf is contained in the coarser leaf, tested with exact integer barycentric
coordinates).  None of this touches the connectivity tables under test.

Covers the acceptance domains — the 2-tree cube in d=2 and the 6-tree cube
in d=3 — plus periodic gluings and the reflected (rotated-pair) domain, with
bit-identical results across the element-ops backends (pallas rows carry the
`slow` marker like the rest of the suite; the full tier runs them).
"""

import numpy as np
import pytest

from repro.core import batch
from repro.core import cmesh as C
from repro.core import forest as F
from repro.core import get_batch_ops, get_ops
from repro.core.types import Simplex

BACKENDS = ["reference", "jnp", pytest.param("pallas", marks=pytest.mark.slow)]


# ----------------------------------------------------------- world geometry
def _world_leaves(cm, forests):
    """Per global leaf: (rank, tree, key, level, verts) with world vertex
    coordinates divided by the finest cube side present (small exact ints)."""
    d = forests[0].d
    o = get_ops(d)
    leaves = []
    max_level = max((int(f.level.max()) for f in forests if f.num_local), default=0)
    g = 1 << (o.L - max_level)  # finest cube side: every coordinate divides
    for p, f in enumerate(forests):
        if f.num_local == 0:
            continue
        for t in np.unique(f.tree):
            sel = np.nonzero(f.tree == t)[0]
            s = Simplex(f.anchor[sel], f.level[sel], f.stype[sel])
            W = cm.world_vertices(int(t), s)
            assert (W % g == 0).all()
            W //= g
            for i, li in enumerate(sel):
                leaves.append((p, int(t), int(f.keys[li]), int(f.level[li]), W[i]))
    return leaves


def _det(A):
    if A.shape == (2, 2):
        return int(A[0, 0]) * int(A[1, 1]) - int(A[0, 1]) * int(A[1, 0])
    return (
        int(A[0, 0]) * (int(A[1, 1]) * int(A[2, 2]) - int(A[1, 2]) * int(A[2, 1]))
        - int(A[0, 1]) * (int(A[1, 0]) * int(A[2, 2]) - int(A[1, 2]) * int(A[2, 0]))
        + int(A[0, 2]) * (int(A[1, 0]) * int(A[2, 1]) - int(A[1, 1]) * int(A[2, 0]))
    )


def _in_simplex(V, p):
    """Exact closed containment of integer point p in integer simplex V."""
    d = len(p)
    A = (V[1:] - V[0]).T
    b = p - V[0]
    D = _det(A)
    sgn = 1 if D > 0 else -1
    lams = []
    for m in range(d):
        Am = A.copy()
        Am[:, m] = b
        lams.append(_det(Am) * sgn)
    return all(l >= 0 for l in lams) and sum(lams) <= D * sgn


def _face_adjacent(Va, la, Vb, lb):
    """Leaves with |level difference| <= 1 share a (d-1)-face iff some face
    of the finer lies (closed) inside the coarser simplex."""
    if la < lb:
        Va, la, Vb, lb = Vb, lb, Va, la
    d = Va.shape[1]
    for f in range(d + 1):
        Fv = np.delete(Va, f, axis=0)
        if all(_in_simplex(Vb, v) for v in Fv):
            return True
    return False


def _bbox_touch(leaves):
    """(n, n) bool: candidate pairs whose axis-aligned boxes touch."""
    lo = np.stack([v.min(axis=0) for *_, v in leaves])
    hi = np.stack([v.max(axis=0) for *_, v in leaves])
    return ((lo[:, None, :] <= hi[None, :, :]) & (lo[None, :, :] <= hi[:, None, :])).all(-1)


def _oracle_ghost_uniform(cm, forests):
    """Brute-force vertex-coordinate matching: on a uniform mesh two leaves
    are face-adjacent iff they share exactly d world vertices."""
    d = forests[0].d
    leaves = _world_leaves(cm, forests)
    vsets = [frozenset(map(tuple, v.tolist())) for *_, v in leaves]
    touch = _bbox_touch(leaves)
    want = [set() for _ in forests]
    for i in range(len(leaves)):
        for j in range(len(leaves)):
            if leaves[i][0] == leaves[j][0] or not touch[i, j]:
                continue
            if len(vsets[i] & vsets[j]) == d:
                p = leaves[i][0]
                q, t, k, l, _ = leaves[j]
                want[p].add((t, k, l, q))
    return want


def _oracle_ghost_adapted(cm, forests):
    """Brute-force face-containment adjacency for balanced (2:1) meshes."""
    leaves = _world_leaves(cm, forests)
    touch = _bbox_touch(leaves)
    want = [set() for _ in forests]
    for i in range(len(leaves)):
        for j in range(len(leaves)):
            if leaves[i][0] == leaves[j][0] or not touch[i, j]:
                continue
            if _face_adjacent(leaves[i][4], leaves[i][3], leaves[j][4], leaves[j][3]):
                q, t, k, l, _ = leaves[j]
                want[leaves[i][0]].add((t, k, l, q))
    return want


def _ghost_sets(d, gh):
    bops = get_batch_ops(d)
    out = []
    for g in gh:
        if len(g["level"]) == 0:
            out.append(set())
            continue
        s = Simplex(g["anchor"], g["level"], g["stype"])
        keys = bops.morton_key_np(s)
        out.append({
            (int(g["tree"][j]), int(keys[j]), int(g["level"][j]), int(g["owner"][j]))
            for j in range(len(keys))
        })
    return out


def _assert_cross_tree_present(gh, forests):
    """The point of the PR: some ghost entries live in a tree the receiving
    rank holds no elements of."""
    cross = 0
    for p, g in enumerate(gh):
        local_trees = set(forests[p].tree.tolist())
        cross += sum(1 for t in g["tree"].tolist() if t not in local_trees)
    assert cross > 0


# ------------------------------------------------------------------- tests
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("d,level,P", [(2, 3, 2), (3, 2, 3)])
def test_uniform_ghost_matches_vertex_oracle(d, level, P, backend):
    """Acceptance: 2-tree (d=2) / 6-tree (d=3) cube, cross-tree ghosts equal
    the brute-force vertex-matching oracle, per backend."""
    cm = C.cmesh_unit_cube(d)
    comm = F.SimComm(P)
    with batch.use_backend(backend):
        fs = F.new_uniform(d, cm.num_trees, level, comm, cmesh=cm)
        fs = F.balance(fs, comm)  # fixpoint on a uniform mesh
        assert F.count_global(fs) == cm.num_trees * get_ops(d).num_elements(level)
        gh = F.ghost(fs, comm)
        assert F.validate(fs, gh)
        got = _ghost_sets(d, gh)
    want = _oracle_ghost_uniform(cm, fs)
    assert got == want
    _assert_cross_tree_present(gh, fs)


@pytest.mark.parametrize("d", [2, 3])
def test_uniform_ghost_bit_identical_across_backends(d):
    """reference and jnp produce byte-equal ghost arrays (pallas covered by
    the slow rows of test_uniform_ghost_matches_vertex_oracle)."""
    cm = C.cmesh_unit_cube(d)
    comm = F.SimComm(2)
    outs = {}
    for be in ("reference", "jnp"):
        with batch.use_backend(be):
            fs = F.new_uniform(d, cm.num_trees, 2, comm, cmesh=cm)
            fs = F.balance(fs, comm)
            gh = F.ghost(fs, comm)
        outs[be] = (fs, gh)
    fa, ga = outs["reference"]
    fb, gb = outs["jnp"]
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
    for a, b in zip(ga, gb):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k])


@pytest.mark.parametrize("d,base,deep", [(2, 2, 4), (3, 1, 3)])
def test_cross_tree_balance_and_adapted_ghost_oracle(d, base, deep):
    """Corner refinement in tree 0 must ripple ACROSS the tree face: balance
    terminates, every face-adjacent pair (found by the world-coordinate
    oracle) is within one level, and the adapted ghost layer equals the
    face-containment oracle."""
    cm = C.cmesh_unit_cube(d)
    comm = F.SimComm(2)
    fs = F.new_uniform(d, cm.num_trees, base, comm, cmesh=cm)

    def corner(tree, elems, cap=deep):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < cap)).astype(np.int32)

    fs = [F.adapt(f, corner, recursive=True) for f in fs]
    before = F.count_global(fs)
    fs = F.balance(fs, comm)  # raises if it does not converge
    assert F.count_global(fs) > before, "cross-tree ripple must insert elements"
    assert F.validate(fs)

    # 2:1 across every face-adjacent pair, tree faces included
    leaves = _world_leaves(cm, fs)
    touch = _bbox_touch(leaves)
    deepest_other = 0
    for i in range(len(leaves)):
        for j in range(i + 1, len(leaves)):
            if not touch[i, j]:
                continue
            li, lj = leaves[i][3], leaves[j][3]
            if abs(li - lj) <= 1:
                if leaves[i][1] != leaves[j][1]:
                    deepest_other = max(deepest_other, min(li, lj))
                continue
            assert not _face_adjacent(leaves[i][4], li, leaves[j][4], lj), (
                f"2:1 violated between leaves {i} and {j} "
                f"(levels {li} vs {lj}, trees {leaves[i][1]}/{leaves[j][1]})"
            )
    assert deepest_other > base, "refinement never crossed a tree face"

    gh = F.ghost(fs, comm)
    assert F.validate(fs, gh)
    assert _ghost_sets(d, gh) == _oracle_ghost_adapted(cm, fs)


@pytest.mark.parametrize("d", [2, 3])
def test_periodic_cube_has_no_boundary(d):
    """On the fully periodic unit cube every element face has a neighbor:
    iterate sees exactly (d+1)*n/2 face pairs and ghost wraps around."""
    cm = C.cmesh_unit_cube(d, periodic=(True,) * d)
    comm = F.SimComm(1)
    level = 2 if d == 2 else 1
    fs = F.new_uniform(d, cm.num_trees, level, comm, cmesh=cm)
    n = fs[0].num_local
    seen = {}
    F.iterate(fs[0], face_fn=lambda f, pairs: seen.setdefault("pairs", pairs))
    assert len(seen["pairs"]) == (d + 1) * n // 2
    s = fs[0].simplices()
    kinds = F.face_kinds(fs[0], s)  # all faces, one sweep
    assert kinds.shape == (d + 1, n)
    assert (kinds != F.FACE_DOMAIN_BOUNDARY).all()


def test_rotated_pair_pipeline():
    """The sigma = -1 domain (parallelogram of two triangles) goes through
    the full adapt/balance/ghost pipeline with a correct oracle ghost."""
    cm = C.cmesh_rotated_pair()
    comm = F.SimComm(2)
    fs = F.new_uniform(2, 2, 2, comm, cmesh=cm)

    def corner(tree, elems, cap=4):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < cap)).astype(np.int32)

    fs = [F.adapt(f, corner, recursive=True) for f in fs]
    fs = F.balance(fs, comm)
    assert F.validate(fs)
    gh = F.ghost(fs, comm)
    assert F.validate(fs, gh)
    assert _ghost_sets(2, gh) == _oracle_ghost_adapted(cm, fs)


def test_iterate_delivers_hanging_and_cross_tree_pairs():
    """On one rank, iterate's face pairs must be EXACTLY the set of
    face-adjacent leaf pairs of the world-coordinate oracle — same-level and
    hanging (coarse, fine), intra-tree and across the glued diagonal."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(1)
    o = get_ops(2)
    fs = F.new_uniform(2, 2, 2, comm, cmesh=cm)

    def corner(tree, elems, cap=4):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < cap)).astype(np.int32)

    fs = [F.adapt(f, corner, recursive=True) for f in fs]
    fs = F.balance(fs, comm)
    f = fs[0]
    seen = {}
    F.iterate(f, face_fn=lambda ff, pp: seen.setdefault("pairs", pp))
    pairs = seen["pairs"]

    # world verts per local element, in storage order, at the finest scale
    g = 1 << (o.L - int(f.level.max()))
    V = []
    for i in range(f.num_local):
        s1 = Simplex(f.anchor[i:i + 1], f.level[i:i + 1], f.stype[i:i + 1])
        V.append(cm.world_vertices(int(f.tree[i]), s1)[0] // g)
    want = set()
    for i in range(f.num_local):
        for j in range(i + 1, f.num_local):
            if _face_adjacent(V[i], int(f.level[i]), V[j], int(f.level[j])):
                want.add((i, j))
    got = {(min(int(a), int(b)), max(int(a), int(b))) for a, b, _, _ in pairs}
    assert got == want
    # hanging rows carry (fine i, coarse j) and levels differ by exactly 1
    mixed = 0
    for a, b, fa, fb in pairs.tolist():
        la, lb = int(f.level[a]), int(f.level[b])
        if la != lb:
            mixed += 1
            assert la == lb + 1, "fine side must come first, one level apart"
    assert mixed > 0, "adapted mesh must produce hanging pairs"


def test_iterate_cross_tree_pair_count():
    """2-tree square at uniform level 2: interior face pairs = (3n - B)/2
    with B boundary edges on the square's perimeter only."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(1)
    level = 2
    fs = F.new_uniform(2, 2, level, comm, cmesh=cm)
    n = fs[0].num_local
    seen = {}
    F.iterate(fs[0], face_fn=lambda f, pairs: seen.setdefault("pairs", pairs))
    boundary_edges = 4 * (1 << level)
    assert len(seen["pairs"]) == (3 * n - boundary_edges) // 2
    # without the cmesh the diagonal's 2^level pairs are lost
    fs0 = F.new_uniform(2, 2, level, comm)
    seen0 = {}
    F.iterate(fs0[0], face_fn=lambda f, pairs: seen0.setdefault("pairs", pairs))
    assert len(seen["pairs"]) - len(seen0["pairs"]) == (1 << level)


def test_disconnected_cmesh_matches_legacy():
    """A cmesh with no connections reproduces the legacy (cmesh=None)
    forest bit for bit through balance and ghost."""
    comm = F.SimComm(2)
    dc = C.cmesh_disconnected(3, 2)

    def corner(tree, elems, cap=3):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    outs = []
    for cmesh in (None, dc):
        fs = F.new_uniform(3, 2, 1, comm, cmesh=cmesh)
        fs = [F.adapt(f, corner, recursive=True) for f in fs]
        fs = F.balance(fs, comm)
        gh = F.ghost(fs, comm)
        outs.append((fs, gh))
    for a, b in zip(outs[0][0], outs[1][0]):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.tree, b.tree)
    for a, b in zip(outs[0][1], outs[1][1]):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k])


def test_multitree_smoke():
    """CI fast-tier smoke: 2-tree cube, adapt+balance+ghost on 2 ranks."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, 2, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: (np.asarray(e.anchor).sum(1) == 0).astype(np.int32))
          for f in fs]
    fs = F.balance(fs, comm)
    gh = F.ghost(fs, comm)
    assert F.validate(fs, gh)
    assert sum(len(g["level"]) for g in gh) > 0
