"""Property tests for the coarse-mesh layer (`repro.core.cmesh`).

The central invariants of inter-tree connectivity, checked on random
elements at random levels and types in d = 2 and 3 over every canonical
domain (unit cube, periodic cube, 2x1 brick, rotated pair):

  * an outside face-neighbor of a boundary element always lies on exactly
    one root facet, and its transform lands INSIDE the neighbor tree's root
    at the same level;
  * neighbor-of-neighbor across a tree face is the identity: transforming
    back through the partner connection reproduces the source bits exactly;
  * the gluing maps compose with their reverses to the identity;
  * arbitrary global-sign signed permutations round-trip through
    `tree_transform` and commute with taking vertex coordinates, while
    mixed-sign matrices are rejected (they do not preserve the Kuhn
    triangulation).

Runs with `hypothesis` when installed, else the offline shim `tests/_pbt.py`.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from functools import lru_cache

from repro.core import cmesh as C
from repro.core import get_ops
from repro.core import u64 as u64m
from repro.core.types import Simplex


@lru_cache(maxsize=None)
def _domains(d: int):
    doms = [
        C.cmesh_unit_cube(d),
        C.cmesh_unit_cube(d, periodic=(True,) * d),
        C.cmesh_brick(d, (2,) + (1,) * (d - 1)),
    ]
    if d == 2:
        doms.append(C.cmesh_rotated_pair())
    return doms


def _take(s: Simplex, idx) -> Simplex:
    return Simplex(s.anchor[idx], s.level[idx], s.stype[idx])


def _assert_simplex_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.anchor), np.asarray(b.anchor))
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.stype), np.asarray(b.stype))


@given(st.integers(2, 3), st.integers(0, 7), st.integers(1, 5),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_cross_tree_transform_properties(d, dom_idx, level, seed):
    """Inside-neighbor-root + same-level + exact round trip, batched over
    every boundary crossing found in a random element batch."""
    cm = _domains(d)[dom_idx % len(_domains(d))]
    o = get_ops(d)
    rng = np.random.default_rng(seed)
    tree = int(rng.integers(cm.num_trees))
    n = 64
    ids = rng.integers(0, o.num_elements(level), size=n).astype(np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.full(n, level, jnp.int32))
    crossings = 0
    for face in range(d + 1):
        nb, dual = o.face_neighbor(s, face)
        inside = np.asarray(o.is_inside_root(nb))
        out_idx = np.nonzero(~inside)[0]
        if not len(out_idx):
            continue
        rf = cm.root_face_of(_take(s, out_idx), face)
        # an outside neighbor's shared face lies on exactly one root facet
        assert (rf >= 0).all()
        for rfv in np.unique(rf):
            if cm.face_tree[tree, rfv] < 0:
                continue  # domain boundary
            idx = out_idx[rf == rfv]
            sub = _take(nb, idx)
            s2, t2 = cm.transform_across_face(sub, tree, int(rfv))
            crossings += len(idx)
            # same level, inside the neighbor tree's root
            np.testing.assert_array_equal(np.asarray(s2.level), np.asarray(sub.level))
            assert np.asarray(o.is_inside_root(s2)).all()
            # neighbor-of-neighbor across the tree face is the identity:
            # cross back over the renumbered dual face and transform through
            # the partner connection -> the source element, bit for bit
            dual2 = cm.face_facemap[tree, rfv][
                np.asarray(sub.stype), np.asarray(dual)[idx]
            ]
            back, _ = o.face_neighbor(s2, jnp.asarray(dual2))
            assert not np.asarray(o.is_inside_root(back)).any()
            rf_back = cm.root_face_of(s2, dual2)
            assert (rf_back == int(cm.face_face[tree, rfv])).all()
            src_again, t_back = cm.transform_across_face(
                back, t2, int(cm.face_face[tree, rfv])
            )
            assert t_back == tree
            _assert_simplex_equal(src_again, _take(s, idx))
    # at low levels a random batch always touches the boundary somewhere
    if level <= 2 and (cm.face_tree[tree] >= 0).any():
        assert crossings > 0


@pytest.mark.parametrize("d", [2, 3])
def test_gluings_compose_to_identity(d):
    """Matrix-level involution for every connection of every domain."""
    for cm in _domains(d):
        n_conn = 0
        for t1 in range(cm.num_trees):
            for f1 in range(d + 1):
                t2 = int(cm.face_tree[t1, f1])
                if t2 < 0:
                    continue
                n_conn += 1
                f2 = int(cm.face_face[t1, f1])
                assert int(cm.face_tree[t2, f2]) == t1
                M12 = cm.face_M[t1, f1].astype(np.int64)
                M21 = cm.face_M[t2, f2].astype(np.int64)
                np.testing.assert_array_equal(M21 @ M12, np.eye(d, dtype=np.int64))
                np.testing.assert_array_equal(
                    M21 @ cm.face_c[t1, f1] + cm.face_c[t2, f2], np.zeros(d, np.int64)
                )
                # typemap/facemap invert each other too
                tm12 = cm.face_typemap[t1, f1]
                tm21 = cm.face_typemap[t2, f2]
                np.testing.assert_array_equal(tm21[tm12], np.arange(len(tm12)))
                for b in range(len(tm12)):
                    vm12 = cm.face_facemap[t1, f1, b]
                    vm21 = cm.face_facemap[t2, f2, tm12[b]]
                    np.testing.assert_array_equal(vm21[vm12], np.arange(d + 1))
        assert n_conn > 0


@given(st.integers(2, 3), st.integers(0, 2**31 - 1), st.integers(1, 6),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_signed_perm_transform_roundtrip(d, seed, level, reflect):
    """tree_transform under a random global-sign signed permutation + lattice
    translation: inverts exactly and commutes with vertex coordinates."""
    o = get_ops(d)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(d)
    sigma = -1 if reflect else 1
    M = np.zeros((d, d), np.int64)
    M[np.arange(d), perm] = sigma
    tm, vm = C.signed_perm_maps(d, M)
    # keep true image coordinates within int32 so the wrap is the identity
    kmax = 1 if d == 2 else 2
    c = rng.integers(-kmax, kmax + 1, size=d).astype(np.int64) << o.L

    n = 32
    ids = rng.integers(0, o.num_elements(level), size=n).astype(np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.full(n, level, jnp.int32))
    s2 = o.tree_transform(s, M, C.wrap_i32(c), tm)

    Mi = M.T
    ci = -(M.T @ c)
    tmi, _ = C.signed_perm_maps(d, Mi)
    s3 = o.tree_transform(s2, Mi, C.wrap_i32(ci), tmi)
    _assert_simplex_equal(s3, s)

    # vertex commutation: coordinates transform by the same affine map,
    # with the vertex order given by the derived vertmap
    V = np.asarray(o.coordinates(s), np.int64)
    W = np.asarray(o.coordinates(s2), np.int64)
    img = V @ M.T + c
    b_arr = np.asarray(s.stype)
    for i in range(n):
        np.testing.assert_array_equal(img[i], W[i][vm[b_arr[i]]])


@pytest.mark.parametrize("d", [2, 3])
def test_mixed_sign_matrices_rejected(d):
    """Signed permutations with mixed signs flip the cube diagonal and do
    not preserve the Kuhn triangulation — the derivation must reject them."""
    M = np.eye(d, dtype=np.int64)
    M[0, 0] = -1
    with pytest.raises(ValueError, match="not an automorphism"):
        C.signed_perm_maps(d, M)


@pytest.mark.parametrize("d", [2, 3])
def test_root_face_classification(d):
    """Interior element faces match no facet plane; every facet of the root
    is hit by some boundary element face; disconnected cmesh says boundary."""
    cm = C.cmesh_unit_cube(d)
    o = get_ops(d)
    level = 2
    ids = np.arange(o.num_elements(level), dtype=np.uint64)
    s = o.from_linear_id(
        u64m.from_int(ids), jnp.full(len(ids), level, jnp.int32)
    )
    seen = set()
    for face in range(d + 1):
        nb, _ = o.face_neighbor(s, face)
        inside = np.asarray(o.is_inside_root(nb))
        rf = cm.root_face_of(s, face)
        # neighbor outside <=> the element's face lies on a root facet
        np.testing.assert_array_equal(rf >= 0, ~inside)
        seen.update(rf[rf >= 0].tolist())
    assert seen == set(range(d + 1))
    dc = C.cmesh_disconnected(d, 2)
    assert not any(dc.is_connected(t, f) for t in range(2) for f in range(d + 1))
