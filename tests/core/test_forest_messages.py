"""Message-based Balance/Ghost vs the retained global-table oracles.

The acceptance gate of the Comm refactor: on every multitree fixture — the
2-tree (d=2) and 6-tree (d=3) Kuhn cubes, the periodic brick, and the
reflected rotated pair — the marker-routed, boundary-only `balance`/`ghost`
must match `balance_oracle`/`ghost_oracle` element for element, across all
three batch backends, while moving strictly fewer bytes than the
allgathered-leaf-table baseline.  Plus the non-convergence diagnostics and
the partition edge cases that the marker routing depends on.
"""

import numpy as np
import pytest

from repro.core import batch
from repro.core import cmesh as C
from repro.core import forest as F

BACKENDS = ["reference", "jnp", pytest.param("pallas", marks=pytest.mark.slow)]


def _corner_cb(deep, tree0_only=True):
    def cb(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        m = (a.sum(1) == 0) & (l < deep)
        if tree0_only:
            m &= np.asarray(tree) == 0
        return m.astype(np.int32)
    return cb


FIXTURES = {
    # name: (d, cmesh factory, base level, deep level, ranks)
    # kuhn2_d2 deliberately needs a MULTI-round ripple across the glued
    # face, exercising the boundary-layer notifications round after round
    "kuhn2_d2": (2, lambda: C.cmesh_unit_cube(2), 1, 7, 2),
    "kuhn6_d3": (3, lambda: C.cmesh_unit_cube(3), 1, 3, 3),
    "periodic_d2": (2, lambda: C.cmesh_unit_cube(2, periodic=(True, True)), 2, 4, 2),
    "rotated_pair": (2, C.cmesh_rotated_pair, 2, 4, 2),
    "single_tree_d3": (3, lambda: None, 1, 3, 4),
}


def _run_pair(name, backend):
    d, mk_cmesh, base, deep, P = FIXTURES[name]
    cm = mk_cmesh()
    num_trees = cm.num_trees if cm is not None else 2
    with batch.use_backend(backend):
        comm_m, comm_o = F.SimComm(P), F.SimComm(P)
        fs = F.new_uniform(d, num_trees, base, comm_m, cmesh=cm)
        fs = [F.adapt(f, _corner_cb(deep), recursive=True) for f in fs]
        out_m = F.balance([f for f in fs], comm_m)
        out_o = F.balance_oracle([f for f in fs], comm_o)
        gh_m = F.ghost(out_m, comm_m)
        gh_o = F.ghost_oracle(out_o, comm_o)
    return comm_m, comm_o, out_m, out_o, gh_m, gh_o


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_balance_and_ghost_match_oracle(name, backend):
    """Element-for-element parity of the message path with the global-table
    oracle, per fixture and backend."""
    comm_m, comm_o, out_m, out_o, gh_m, gh_o = _run_pair(name, backend)
    for a, b in zip(out_m, out_o):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
    for a, b in zip(gh_m, gh_o):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k])
    assert F.validate(out_m, gh_m)


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_message_path_moves_fewer_bytes(name):
    """The point of the refactor: boundary-only exchanges beat the
    allgathered global leaf table on every fixture."""
    comm_m, comm_o, *_ = _run_pair(name, "reference")
    msg = comm_m.bytes_for("balance") + comm_m.bytes_for("ghost")
    orc = comm_o.bytes_for("balance_oracle") + comm_o.bytes_for("ghost_oracle")
    assert 0 < msg < orc, (msg, orc)


def test_balance_never_materializes_global_table():
    """Per-call wire volume stays far below one global table exchange: on a
    refined mesh the balance traffic must be o(N * entry bytes * (P-1))."""
    d, P, level = 3, 4, 3
    comm = F.SimComm(P)
    fs = F.new_uniform(d, 2, level, comm)
    fs = [F.adapt(f, _corner_cb(level + 2, tree0_only=False), recursive=True)
          for f in fs]
    out = F.balance(fs, comm)
    n = F.count_global(out)
    one_table_round = n * 13 * (P - 1)  # what ONE oracle allgather round ships
    assert comm.bytes_for("balance") < one_table_round


def test_balance_max_rounds_one_on_balanced_mesh():
    """A mesh that is already 2:1 balanced must come back unchanged from
    `balance(..., max_rounds=1)` — no `BalanceNonConvergence`: the round
    budget bounds *refinement* rounds, and zero are needed."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, 2, 2, comm, cmesh=cm)  # uniform == balanced
    out = F.balance([f for f in fs], comm, max_rounds=1)
    for a, b in zip(out, fs):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
    # single-rank world, single tree: same boundary semantics
    lc = F.LocalComm()
    fs1 = F.new_uniform(3, 1, 1, lc)
    out1 = F.balance(fs1, lc, max_rounds=1)
    np.testing.assert_array_equal(out1[0].keys, fs1[0].keys)


def test_balance_round_budget_boundary_is_exact():
    """Pin the converged-on-last-round vs exhausted boundary: with R* the
    exact convergence round of the multi-round kuhn2_d2 ripple, max_rounds
    = R* must succeed (bit-identical to the unconstrained run) and
    max_rounds = R* - 1 must raise."""
    d, mk_cmesh, base, deep, P = FIXTURES["kuhn2_d2"]
    cm = mk_cmesh()
    comm = F.SimComm(P)
    fs = F.new_uniform(d, cm.num_trees, base, comm, cmesh=cm)
    fs = [F.adapt(f, _corner_cb(deep), recursive=True) for f in fs]
    ref = F.balance([f for f in fs], F.SimComm(P))
    r_star = None
    for r in range(1, 65):
        try:
            out = F.balance([f for f in fs], F.SimComm(P), max_rounds=r)
        except F.BalanceNonConvergence as e:
            assert e.rounds == r
            continue
        r_star = r
        break
    assert r_star is not None and r_star > 1, "fixture must need a ripple"
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
    with pytest.raises(F.BalanceNonConvergence):
        F.balance([f for f in fs], F.SimComm(P), max_rounds=r_star - 1)


@pytest.mark.parametrize("name", ["kuhn2_d2", "single_tree_d3"])
def test_balance_serialized_matches_overlapped(name):
    """`overlap=False` (every collective completed at its post site — the
    benchmark baseline) is bit-identical to the double-buffered loop, and
    ships exactly the same bytes."""
    d, mk_cmesh, base, deep, P = FIXTURES[name]
    cm = mk_cmesh()
    num_trees = cm.num_trees if cm is not None else 2
    comm_o, comm_s = F.SimComm(P), F.SimComm(P)
    fs = F.new_uniform(d, num_trees, base, comm_o, cmesh=cm)
    fs = [F.adapt(f, _corner_cb(deep), recursive=True) for f in fs]
    out_o = F.balance([f for f in fs], comm_o, overlap=True)
    out_s = F.balance([f for f in fs], comm_s, overlap=False)
    for a, b in zip(out_o, out_s):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
    assert comm_o.bytes_for("balance") == comm_s.bytes_for("balance")
    assert comm_o.counters["balance"] == comm_s.counters["balance"]


def test_balance_nonconvergence_diagnostics():
    """A refinement pattern whose ripple needs several rounds (deep corner
    in tree 0 of the glued 2-tree square, rippling across the tree face)
    raises with round count and per-rank still-dirty counts when starved."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, 2, 1, comm, cmesh=cm)
    fs = [F.adapt(f, _corner_cb(7), recursive=True) for f in fs]
    with pytest.raises(F.BalanceNonConvergence) as ei:
        F.balance(fs, comm, max_rounds=1)
    err = ei.value
    assert err.rounds == 1
    assert len(err.dirty_per_rank) == comm.size
    assert sum(err.dirty_per_rank) > 0
    assert "still-dirty" in str(err) and "1 rounds" in str(err)
    # with the budget restored the same input converges to the oracle result
    out = F.balance(fs, comm)
    out_o = F.balance_oracle(fs, F.SimComm(2))
    for a, b in zip(out, out_o):
        np.testing.assert_array_equal(a.keys, b.keys)
    assert F.validate(out)


# -------------------------------------------------- partition edge cases
def test_partition_zero_weight_elements():
    comm = F.SimComm(3)
    fs = F.new_uniform(2, 2, 2, comm)
    before = F.count_global(fs)
    rng = np.random.default_rng(0)
    ws = [np.where(rng.random(f.num_local) < 0.5, 0.0, 1.0) for f in fs]
    out = F.partition(fs, comm, weights=ws)
    assert F.count_global(out) == before
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)


def test_partition_empty_ranks_after_repartition():
    """All weight on one element: some ranks end up empty, markers stay
    sorted, the count is conserved, and the forest stays valid."""
    comm = F.SimComm(4)
    fs = F.new_uniform(2, 1, 2, comm)
    before = F.count_global(fs)
    ws = [np.zeros(f.num_local) for f in fs]
    ws[0][0] = 1.0  # single heavy element
    out = F.partition(fs, comm, weights=ws)
    assert F.count_global(out) == before
    assert F.validate(out)
    assert any(f.num_local == 0 for f in out), "expected empty ranks"
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    bops = out[0].bops
    for p, f in enumerate(out):
        if f.num_local:
            assert (bops.owner_rank(f.tree, f.keys, mt, mk) == p).all()


def test_partition_single_element_forest():
    """One leaf, four ranks: three ranks empty, everything still routes."""
    comm = F.SimComm(4)
    fs = F.new_uniform(2, 1, 0, comm)  # a single level-0 leaf
    assert F.count_global(fs) == 1
    out = F.partition(fs, comm)
    assert F.count_global(out) == 1
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    # balance/ghost on the degenerate forest are communication no-ops
    bal = F.balance(out, comm)
    assert F.count_global(bal) == 1
    gh = F.ghost(bal, comm)
    assert all(len(g["level"]) == 0 for g in gh)


def test_pack_triples_wire_digest():
    """`_pack_triples` lexsorts column arrays instead of sorting Python
    tuples; the wire bytes must be bit-identical to the tuple-sort
    reimplementation AND to the pinned digest (any byte drift would break
    cross-version wire compatibility silently)."""
    import hashlib

    from repro.core.types import pack_wire

    rng = np.random.default_rng(42)
    t = rng.integers(0, 5, 200)
    k = rng.integers(0, 1 << 60, 200, dtype=np.uint64)
    l = rng.integers(0, 21, 200)
    triples = {(int(a), int(b), int(c)) for a, b, c in zip(t, k, l)}
    buf = F._pack_triples(triples)
    uniq = sorted(triples)
    want = pack_wire(np.array([x[0] for x in uniq], np.int32),
                     np.array([x[1] for x in uniq], np.uint64),
                     np.array([x[2] for x in uniq], np.int32))
    np.testing.assert_array_equal(buf, want)
    assert hashlib.sha256(buf.tobytes()).hexdigest() == (
        "f3abf7c3cc47ecbfa21ac0b48b95efddba23d7ef7acfdd42464ecc58893636cd")
    assert F._pack_triples(()).size == 0
    assert F._pack_triples(iter(triples)).tobytes() == buf.tobytes()
