"""Differential tests: the three element-ops backends agree bit-for-bit.

`reference` (eager SimplexOps), `jnp` (jitted + padded), and `pallas`
(tiled kernels, interpret mode on CPU) must produce identical integers for
every op over random batches at d=2 and d=3 across levels 0..MAXLEVEL.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import rand_simplices
from repro.core import batch, get_ops
from repro.core import u64 as u64m

# pallas rows run the interpret-mode kernels: correct but compile-heavy on
# one CPU core, so they carry the `slow` marker (still in the full suite).
BACKENDS = ["jnp", pytest.param("pallas", marks=pytest.mark.slow)]

N = 64  # one padding bucket -> one jit/interpret compile per op


def assert_simplex_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.anchor), np.asarray(b.anchor))
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.stype), np.asarray(b.stype))


@pytest.fixture(params=[2, 3])
def d(request):
    return request.param


@pytest.mark.parametrize("backend", BACKENDS)
def test_parent_and_local_index_parity(d, backend):
    s = rand_simplices(d, N, seed=10 + d, min_level=1)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    assert_simplex_equal(got.parent(s), ref.parent(s))
    np.testing.assert_array_equal(
        np.asarray(got.local_index(s)), np.asarray(ref.local_index(s))
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_children_parity(d, backend):
    o = get_ops(d)
    s = rand_simplices(d, N, seed=20 + d, min_level=0, max_level=o.L - 1)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    assert_simplex_equal(got.children(s), ref.children(s))


@pytest.mark.parametrize("backend", BACKENDS)
def test_face_neighbor_and_inside_parity(d, backend):
    s = rand_simplices(d, N, seed=30 + d, min_level=0)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    for face in range(d + 1):
        nb_g, dual_g = got.face_neighbor(s, face)
        nb_r, dual_r = ref.face_neighbor(s, face)
        assert_simplex_equal(nb_g, nb_r)
        np.testing.assert_array_equal(np.asarray(dual_g), np.asarray(dual_r))
        # neighbors include outside-root elements: the interesting cases
        np.testing.assert_array_equal(
            np.asarray(got.is_inside_root(nb_g)), np.asarray(ref.is_inside_root(nb_r))
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_successor_parity(d, backend):
    s = rand_simplices(d, N, seed=40 + d, min_level=1, margin=1)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    assert_simplex_equal(got.successor(s), ref.successor(s))


@pytest.mark.parametrize("backend", BACKENDS)
def test_morton_key_decode_roundtrip_parity(d, backend):
    s = rand_simplices(d, N, seed=50 + d, min_level=0)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    kg, kr = got.morton_key(s), ref.morton_key(s)
    np.testing.assert_array_equal(np.asarray(kg.hi), np.asarray(kr.hi))
    np.testing.assert_array_equal(np.asarray(kg.lo), np.asarray(kr.lo))
    np.testing.assert_array_equal(got.morton_key_np(s), ref.morton_key_np(s))
    assert_simplex_equal(got.decode(kg, s.level), s)


@pytest.mark.parametrize("backend", BACKENDS)
def test_tree_transform_parity(d, backend):
    """The batched cross-tree transform (cmesh gluing map) is bit-identical
    across backends for every connection of the cube domain AND for a
    reflected (sigma = -1) synthetic map."""
    from repro.core import cmesh as C

    cm = C.cmesh_unit_cube(d)
    s = rand_simplices(d, N, seed=60 + d, min_level=1)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    tested = 0
    for t in range(cm.num_trees):
        for f in range(d + 1):
            if not cm.is_connected(t, f):
                continue
            M, c, tm = cm.face_M[t, f], cm.face_c[t, f], cm.face_typemap[t, f]
            assert_simplex_equal(
                got.tree_transform(s, M, c, tm), ref.tree_transform(s, M, c, tm)
            )
            tested += 1
    assert tested > 0
    # the reflected branch: full point reflection is a complex automorphism
    o = get_ops(d)
    M = -np.eye(d, dtype=np.int64)
    tm, _ = C.signed_perm_maps(d, M)
    c = np.full(d, 2, np.int64) << o.L
    assert_simplex_equal(
        got.tree_transform(s, M, c, tm), ref.tree_transform(s, M, c, tm)
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_owner_rank_parity(d, backend):
    """The marker-table searchsorted that routes Balance/Ghost queries is
    bit-identical across backends, including markers with duplicate entries
    (empty ranks) and keys outside every marker (clamped to rank 0)."""
    o = get_ops(d)
    rng = np.random.default_rng(40 + d)
    P = 7
    mt = np.sort(rng.integers(0, 4, P)).astype(np.int32)
    mk = rng.integers(0, 1 << (d * o.L), P).astype(np.uint64)
    order = np.lexsort((mk, mt))
    mt, mk = mt[order], mk[order]
    mt[3], mk[3] = mt[4], mk[4]  # duplicate marker: an empty rank
    t = rng.integers(0, 4, N).astype(np.int32)
    k = rng.integers(0, 1 << (d * o.L), N).astype(np.uint64)
    t[0], k[0] = 0, 0  # before every marker: clamps to 0
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, backend)
    np.testing.assert_array_equal(
        got.owner_rank(t, k, mt, mk), ref.owner_rank(t, k, mt, mk))


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_batch_all_ops(d, backend):
    o = get_ops(d)
    s = o.from_linear_id(u64m.from_int(np.zeros(0, np.uint64)), jnp.zeros(0, jnp.int32))
    b = batch.get_batch_ops(d, backend)
    assert b.morton_key_np(s).shape == (0,)
    assert b.parent(s).level.shape == (0,)
    assert b.children(s).level.shape == (0, o.nc)
    assert b.successor(s).level.shape == (0,)
    assert np.asarray(b.is_inside_root(s)).shape == (0,)
    nb, dual = b.face_neighbor(s, 0)
    assert nb.level.shape == (0,)
    sw = b.face_sweep(s)
    assert sw.neighbor.anchor.shape == (d + 1, 0, d)
    assert sw.key.hi.shape == (d + 1, 0)
    assert b.tree_transform(
        s, np.eye(d, dtype=np.int64), np.zeros(d, np.int64), np.arange(o.nt)
    ).level.shape == (0,)
    assert b.owner_rank(
        np.zeros(0, np.int32), np.zeros(0, np.uint64),
        np.zeros(1, np.int32), np.zeros(1, np.uint64),
    ).shape == (0,)


def test_backend_knob_env_and_context(monkeypatch):
    monkeypatch.setattr(batch, "_active", None)
    monkeypatch.setenv("REPRO_BACKEND", "jnp")
    assert batch.get_backend() == "jnp"
    with batch.use_backend("reference"):
        assert batch.get_backend() == "reference"
        assert batch.get_batch_ops(2).backend == "reference"
    assert batch.get_backend() == "jnp"


def test_backend_knob_unknown_falls_back(monkeypatch):
    monkeypatch.setattr(batch, "_active", None)
    monkeypatch.setenv("REPRO_BACKEND", "tpu-v7")
    with pytest.warns(UserWarning, match="unknown element-ops backend"):
        assert batch.get_backend() == "reference"
    with pytest.warns(UserWarning):
        batch.set_backend("nope")
    assert batch.get_backend() == "reference"
    batch.set_backend("reference")


def test_level_sweep_full_range_jnp(d):
    """Every level 0..MAXLEVEL appears at least once in a parity sweep."""
    o = get_ops(d)
    lv = jnp.asarray(np.arange(o.L + 1, dtype=np.int32))
    ids = u64m.from_int(np.zeros(o.L + 1, np.uint64))
    s = o.from_linear_id(ids, lv)
    ref = batch.get_batch_ops(d, "reference")
    got = batch.get_batch_ops(d, "jnp")
    np.testing.assert_array_equal(got.morton_key_np(s), ref.morton_key_np(s))
    assert_simplex_equal(got.decode(got.morton_key(s), lv), s)
    np.testing.assert_array_equal(
        np.asarray(got.is_inside_root(s)), np.asarray(ref.is_inside_root(s))
    )
