"""Tests for SFC-partition-based load balancing (placement.py)."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from repro.core import placement as P


@given(st.lists(st.floats(0.0, 100.0), min_size=8, max_size=256), st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_target_ranks_contiguous_monotone(ws, nr):
    w = jnp.asarray(np.array(ws, np.float32))
    t = np.asarray(P.target_ranks(w, nr))
    assert (np.diff(t) >= 0).all()
    assert t.min() >= 0 and t.max() <= nr - 1


def test_uniform_weights_perfectly_balanced():
    w = jnp.ones(128)
    t = np.asarray(P.target_ranks(w, 8))
    counts = np.bincount(t, minlength=8)
    assert (counts == 16).all()
    off = np.asarray(P.partition_offsets(w, 8))
    np.testing.assert_array_equal(off, np.arange(9) * 16)


def test_weighted_imbalance_bounded():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.exponential(1.0, size=4096).astype(np.float32))
    t = P.target_ranks(w, 16)
    imb = float(P.imbalance(w, t, 16))
    # SFC partition guarantees load <= mean + max_item; here items are small
    assert imb < 1.10


def test_expert_placement_vs_naive():
    """Skewed expert loads: SFC-weighted placement beats uniform blocking."""
    rng = np.random.default_rng(1)
    loads = jnp.asarray((rng.zipf(1.5, size=256) % 1000 + 1).astype(np.float32))
    dev, imb = P.expert_placement(loads, 16)
    naive = jnp.repeat(jnp.arange(16), 256 // 16)
    imb_naive = float(P.imbalance(loads, naive, 16))
    assert float(imb) <= imb_naive + 1e-6
    # contiguity
    assert (np.diff(np.asarray(dev)) >= 0).all()


def test_document_partition_token_balance():
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(100, 4096, size=2048).astype(np.float32))
    rank, imb = P.document_partition(toks, 32)
    assert float(imb) < 1.05


def test_page_order_is_permutation_and_local():
    order = np.asarray(P.page_order(16, 8))
    flat = order.reshape(-1)
    assert sorted(flat.tolist()) == list(range(16 * 8))
    # locality: consecutive pages of one request are on average closer in the
    # physical order than under row-major layout across requests
    d_sfc = np.abs(np.diff(order, axis=1)).mean()
    rowmajor = np.arange(16 * 8).reshape(8, 16).T.reshape(8, 16)  # request-major
    d_naive = np.abs(np.diff(rowmajor, axis=1)).mean()
    assert d_sfc < d_naive
