"""Comm-layer unit tests: surface conformance, byte metering, wire formats,
marker routing (owner_rank + partition_markers)."""

import time

import numpy as np
import pytest

from repro.core import batch
from repro.core import forest as F
from repro.core.comm import (
    LatencyComm, LocalComm, SimComm, decode_payload, encode_payload,
    payload_nbytes,
)
from repro.core.types import pack_wire, unpack_wire


# ------------------------------------------------------------------ surface
def test_simcomm_collectives_roundtrip():
    comm = SimComm(3)
    assert comm.size == 3 and list(comm.local_ranks) == [0, 1, 2]
    assert comm.allgather([10, 11, 12]) == [10, 11, 12]
    send = [[f"{p}->{q}" for q in range(3)] for p in range(3)]
    recv = comm.alltoallv(send)
    for q in range(3):
        assert recv[q] == [f"{p}->{q}" for p in range(3)]


def test_localcomm_is_single_rank_identity():
    comm = LocalComm()
    assert comm.size == 1 and list(comm.local_ranks) == [0]
    x = np.arange(5)
    out = comm.allgather([x])
    assert len(out) == 1 and (out[0] == x).all()
    assert comm.alltoallv([[x]])[0][0] is x
    # nothing crosses a rank boundary in a single-rank world
    assert comm.bytes_for() == 0


def test_byte_counters_and_phases():
    comm = SimComm(4)
    x = np.zeros(10, np.uint8)  # 10 bytes
    with comm.phase("alpha"):
        comm.allgather([x, x, x, x])
    # each rank ships its payload to the 3 others
    assert comm.counters["alpha"]["allgather_bytes"] == 10 * 3 * 4
    with comm.phase("beta"):
        send = [[np.zeros(q, np.uint8) for q in range(4)] for _ in range(4)]
        comm.alltoallv(send)
    # rank p sends q bytes to q for q != p: sum over p of (0+1+2+3 - p)
    want = sum(sum(q for q in range(4) if q != p) for p in range(4))
    assert comm.counters["beta"]["alltoallv_bytes"] == want
    assert comm.bytes_for("alpha") == 120
    assert comm.bytes_for() == 120 + want
    comm.reset_counters()
    assert comm.bytes_for() == 0


def test_payload_nbytes_nested():
    obj = {"a": np.zeros((2, 3), np.int32), "b": [np.zeros(5, np.uint8), 7]}
    # 1-byte keys + 24-byte array + 5-byte array + 8-byte scalar
    assert payload_nbytes(obj) == 1 + 24 + 1 + 5 + 8


# ------------------------------------------------------------------ handles
def test_nonblocking_handles_match_blocking():
    """iallgather/ialltoallv deliver exactly what the blocking calls do;
    wait() is idempotent and SimComm handles complete immediately."""
    comm = SimComm(3)
    h = comm.iallgather([10, 11, 12])
    assert h.done()
    assert h.wait() == [10, 11, 12]
    assert h.wait() == [10, 11, 12]  # idempotent
    send = [[f"{p}->{q}" for q in range(3)] for p in range(3)]
    hv = comm.ialltoallv(send)
    assert hv.wait() == comm.alltoallv(send)


def test_bytes_metered_at_post_time():
    """A collective's bytes land in the phase active when it was POSTED,
    not when it was waited — how the overlapped balance keeps attribution."""
    comm = SimComm(2)
    x = np.zeros(16, np.uint8)
    with comm.phase("posted"):
        h = comm.iallgather([x, x])
    with comm.phase("waited"):
        h.wait()
    assert comm.bytes_for("posted") == 16 * 2
    assert comm.bytes_for("waited") == 0


def test_latencycomm_handles_mature_in_background():
    """LatencyComm: a handle is not done before the latency elapses, and a
    blocking call (post + wait) pays the full round trip.  The latency is
    generous (250 ms) so a loaded CI runner's scheduling stall between the
    post and the first poll cannot mature the handle early."""
    comm = LatencyComm(2, latency_s=0.25)
    t0 = time.monotonic()
    h = comm.iallgather([1, 2])
    if time.monotonic() - t0 < 0.2:  # poll promptly enough to be meaningful
        assert not h.done()
    time.sleep(0.3)
    assert h.done()
    assert h.wait() == [1, 2]  # already matured: no further sleep
    assert time.monotonic() - t0 < 2.0
    t0 = time.monotonic()
    assert comm.allgather([3, 4]) == [3, 4]
    assert time.monotonic() - t0 >= 0.25


# --------------------------------------------------------------- wire codec
def test_encode_decode_payload_roundtrip():
    obj = {
        "arrays": (np.arange(7, dtype=np.uint64) * 2**40,
                   np.zeros((0, 3), np.int32)),
        "scalars": [None, True, False, -5, 2**70, 1.5, "text", b"\x00\xff"],
        3: {"nested": np.float32(2.0).item()},
    }
    out = decode_payload(encode_payload(obj))
    assert out["scalars"] == obj["scalars"]
    assert out[3] == {"nested": 2.0}
    a0, a1 = out["arrays"]
    np.testing.assert_array_equal(a0, obj["arrays"][0])
    assert a1.shape == (0, 3) and a1.dtype == np.int32


def test_pack_wire_roundtrip_and_size():
    t = np.array([0, 5, 3], np.int32)
    k = np.array([0, 2**62, 12345], np.uint64)
    l = np.array([0, 21, 7], np.int32)
    buf = pack_wire(t, k, l)
    assert buf.dtype == np.uint8 and buf.nbytes == 3 * 13  # Remark 20 triple
    tt, kk, ll = unpack_wire(buf)
    np.testing.assert_array_equal(tt, t)
    np.testing.assert_array_equal(kk, k)
    np.testing.assert_array_equal(ll, l)
    quad = pack_wire(t, k, l, extra=[1, 0, 3])
    assert quad.nbytes == 3 * 14
    _, _, _, ee = unpack_wire(quad, with_extra=True)
    np.testing.assert_array_equal(ee, [1, 0, 3])


# ------------------------------------------------------------ marker routing
@pytest.mark.parametrize("backend", ["reference", "jnp",
                                     pytest.param("pallas", marks=pytest.mark.slow)])
def test_owner_rank_matches_bruteforce(backend):
    rng = np.random.default_rng(7)
    P = 6
    mt = np.sort(rng.integers(0, 3, P)).astype(np.int32)
    mk = rng.integers(0, 2**60, P).astype(np.uint64)
    order = np.lexsort((mk, mt))
    mt, mk = mt[order], mk[order]
    t = rng.integers(0, 3, 500).astype(np.int32)
    k = rng.integers(0, 2**60, 500).astype(np.uint64)
    want = np.array(
        [max(sum(1 for j in range(P) if (mt[j], mk[j]) <= (ti, ki)) - 1, 0)
         for ti, ki in zip(t.tolist(), k.tolist())], np.int32)
    with batch.use_backend(backend):
        got = batch.get_batch_ops(3).owner_rank(t, k, mt, mk)
    np.testing.assert_array_equal(got, want)


def test_partition_markers_fill_empty_ranks():
    """Empty ranks inherit the next non-empty marker so the table stays
    lex-sorted and routes to the actual owners."""
    comm = SimComm(4)
    fs = F.new_uniform(2, 1, 1, comm)  # 4 elements over 4 ranks
    # concentrate everything on ranks 1..2 by reslicing manually
    A = np.concatenate([f.anchor for f in fs])
    L = np.concatenate([f.level for f in fs])
    B = np.concatenate([f.stype for f in fs])
    T = np.concatenate([f.tree for f in fs])
    fs2 = [
        fs[0].replace_elements(A[:0], L[:0], B[:0], T[:0]),
        fs[1].replace_elements(A[:3], L[:3], B[:3], T[:3]),
        fs[2].replace_elements(A[3:], L[3:], B[3:], T[3:]),
        fs[3].replace_elements(A[:0], L[:0], B[:0], T[:0]),
    ]
    mt, mk = F.partition_markers(fs2, comm)
    # sorted lexicographically
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    # rank 0 (empty) inherits rank 1's marker; trailing empty gets sentinel
    assert (mt[0], mk[0]) == (mt[1], mk[1])
    assert mt[3] == fs2[0].num_trees
    # routing: every element resolves to the rank that stores it
    bops = batch.get_batch_ops(2)
    for p, f in enumerate(fs2):
        if f.num_local == 0:
            continue
        own = bops.owner_rank(f.tree, f.keys, mt, mk)
        assert (own == p).all()


def test_owner_rank_marker_cache_not_stale_after_mutation():
    """Regression: the pad+upload memo must key on marker CONTENT.  The old
    identity key (`id(mt), id(mk)`) kept serving the stale device copy when
    a table was mutated in place — same identity, different content."""
    rng = np.random.default_rng(3)
    P = 5
    mt = np.sort(rng.integers(0, 3, P)).astype(np.int32)
    mk = rng.integers(0, 2**60, P).astype(np.uint64)
    order = np.lexsort((mk, mt))
    mt, mk = mt[order], mk[order]
    t = rng.integers(0, 3, 64).astype(np.int32)
    k = rng.integers(0, 2**60, 64).astype(np.uint64)

    def brute(mt_, mk_):
        le = (mt_[None, :] < t[:, None]) | (
            (mt_[None, :] == t[:, None]) & (mk_[None, :] <= k[:, None]))
        return np.maximum(le.sum(axis=1).astype(np.int32) - 1, 0)

    with batch.use_backend("jnp"):
        bops = batch.get_batch_ops(3)
        np.testing.assert_array_equal(bops.owner_rank(t, k, mt, mk), brute(mt, mk))
        # repartition in place: same identity, different content
        mk2 = np.sort(rng.integers(0, 2**60, P).astype(np.uint64))
        mt[:] = 1
        mk[:] = mk2
        np.testing.assert_array_equal(bops.owner_rank(t, k, mt, mk), brute(mt, mk))
        # fresh arrays with equal content still hit the memo correctly
        np.testing.assert_array_equal(
            bops.owner_rank(t, k, mt.copy(), mk.copy()), brute(mt, mk))


def test_count_global_with_comm():
    comm = SimComm(3)
    fs = F.new_uniform(2, 2, 2, comm)
    assert F.count_global(fs) == F.count_global(fs, comm)
