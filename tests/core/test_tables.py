"""Cross-check the derived SFC tables against the paper's printed tables.

Every legible entry of the paper's Tables 1-8 / Fig. 8 is transcribed here.
NOTE on Table 2 (3D), rows b=1 and b=3: the printed T_4/T_5 entries in the
paper are inconsistent with the paper's own Definition 13 and its Table 6
(see DESIGN.md "Paper errata"); the values asserted here are the ones
implied by Definition 13 + Table 1 + Table 6, which our derivation produces.
"""

import numpy as np
import pytest

from repro.core.tables import get_tables


# ----------------------------------------------------------------- 2D tables
def test_table1_2d_children_types():
    t = get_tables(2)
    assert t.child_type.tolist() == [[0, 0, 0, 1], [1, 1, 1, 0]]


def test_table2_2d_local_indices():
    t = get_tables(2)
    assert t.bey_to_local.tolist() == [[0, 1, 3, 2], [0, 2, 3, 1]]


def test_fig8_2d_parent_type():
    t = get_tables(2)
    # rows: cube-id c = 0..3; cols: type b = 0,1
    assert t.parent_type.tolist() == [[0, 1], [0, 0], [1, 1], [0, 1]]


def test_table3_2d_face_neighbors():
    t = get_tables(2)
    # N.b = 1 - T.b and dual face f~ = 2 - f (paper Table 3)
    for b in range(2):
        for f in range(3):
            assert t.neighbor_type[b, f] == 1 - b
            assert t.neighbor_face[b, f] == 2 - f
    # offsets: b=0: f0 -> x+h, f1 -> 0, f2 -> y-h; b=1: f0 -> y+h, f2 -> x-h
    assert t.neighbor_offset[0].tolist() == [[1, 0], [0, 0], [0, -1]]
    assert t.neighbor_offset[1].tolist() == [[0, 1], [0, 0], [-1, 0]]


def test_tables678_2d():
    t = get_tables(2)
    # Table 6: I_loc by (cube-id, own type); paper prints rows b, cols c.
    assert t.local_index.T.tolist() == [[0, 1, 1, 3], [0, 2, 2, 3]]
    # Table 7: cube-id of TM-child iloc for parent type P.b
    assert t.cube_id_of_local.tolist() == [[0, 1, 1, 3], [0, 2, 2, 3]]
    # Table 8: type of TM-child iloc for parent type P.b
    assert t.type_of_local.tolist() == [[0, 0, 1, 0], [1, 0, 1, 1]]


# ----------------------------------------------------------------- 3D tables
def test_table1_3d_children_types():
    t = get_tables(3)
    want = [
        [0, 0, 0, 0, 4, 5, 2, 1],
        [1, 1, 1, 1, 3, 2, 5, 0],
        [2, 2, 2, 2, 0, 1, 4, 3],
        [3, 3, 3, 3, 5, 4, 1, 2],
        [4, 4, 4, 4, 2, 3, 0, 5],
        [5, 5, 5, 5, 1, 0, 3, 4],
    ]
    assert t.child_type.tolist() == want


def test_table2_3d_local_indices():
    t = get_tables(3)
    # Rows b=1,3: paper-printed T_4/T_5 entries are (2,3); Definition 13 with
    # Table 1 gives (3,2) — matching the paper's own Table 6.  See module doc.
    want = [
        [0, 1, 4, 7, 2, 3, 6, 5],
        [0, 1, 5, 7, 3, 2, 6, 4],
        [0, 3, 4, 7, 1, 2, 6, 5],
        [0, 1, 6, 7, 3, 2, 4, 5],
        [0, 3, 5, 7, 1, 2, 4, 6],
        [0, 3, 6, 7, 2, 1, 4, 5],
    ]
    assert t.bey_to_local.tolist() == want


def test_fig8_3d_parent_type():
    t = get_tables(3)
    want = [
        [0, 1, 2, 3, 4, 5],
        [0, 1, 1, 1, 0, 0],
        [2, 2, 2, 3, 3, 3],
        [1, 1, 2, 2, 2, 1],
        [5, 5, 4, 4, 4, 5],
        [0, 0, 0, 5, 5, 5],
        [4, 3, 3, 3, 4, 4],
        [0, 1, 2, 3, 4, 5],
    ]
    assert t.parent_type.tolist() == want


def test_table4_3d_face_neighbors():
    t = get_tables(3)
    # types
    assert t.neighbor_type.tolist() == [
        [4, 5, 1, 2],
        [3, 2, 0, 5],
        [0, 1, 3, 4],
        [5, 4, 2, 1],
        [2, 3, 5, 0],
        [1, 0, 4, 3],
    ]
    # dual faces: always (3, 1, 2, 0)
    assert t.neighbor_face.tolist() == [[3, 1, 2, 0]] * 6
    # anchor offsets (units of h), from paper Table 4
    assert t.neighbor_offset[0].tolist() == [[1, 0, 0], [0, 0, 0], [0, 0, 0], [0, -1, 0]]
    assert t.neighbor_offset[1].tolist() == [[1, 0, 0], [0, 0, 0], [0, 0, 0], [0, 0, -1]]
    assert t.neighbor_offset[2].tolist() == [[0, 1, 0], [0, 0, 0], [0, 0, 0], [0, 0, -1]]
    assert t.neighbor_offset[3].tolist() == [[0, 1, 0], [0, 0, 0], [0, 0, 0], [-1, 0, 0]]
    assert t.neighbor_offset[4].tolist() == [[0, 0, 1], [0, 0, 0], [0, 0, 0], [-1, 0, 0]]
    assert t.neighbor_offset[5].tolist() == [[0, 0, 1], [0, 0, 0], [0, 0, 0], [0, -1, 0]]


def test_table5_3d_outside_perm():
    t = get_tables(3)
    # (x_i, x_j, x_k) per type; axes 0=x, 1=y, 2=z (paper Table 5)
    want = [[0, 1, 2], [0, 2, 1], [1, 2, 0], [1, 0, 2], [2, 0, 1], [2, 1, 0]]
    assert t.outside_perm.tolist() == want


def test_table6_3d_local_index():
    t = get_tables(3)
    want_rows_b = [
        [0, 1, 1, 4, 1, 4, 4, 7],
        [0, 1, 2, 5, 2, 5, 4, 7],
        [0, 2, 3, 4, 1, 6, 5, 7],
        [0, 3, 1, 5, 2, 4, 6, 7],
        [0, 2, 2, 6, 3, 5, 5, 7],
        [0, 3, 3, 6, 3, 6, 6, 7],
    ]
    assert t.local_index.T.tolist() == want_rows_b


def test_table7_3d_cube_id_of_local():
    t = get_tables(3)
    want = [
        [0, 1, 1, 1, 5, 5, 5, 7],
        [0, 1, 1, 1, 3, 3, 3, 7],
        [0, 2, 2, 2, 3, 3, 3, 7],
        [0, 2, 2, 2, 6, 6, 6, 7],
        [0, 4, 4, 4, 6, 6, 6, 7],
        [0, 4, 4, 4, 5, 5, 5, 7],
    ]
    assert t.cube_id_of_local.tolist() == want


def test_prop23_diag_types():
    # (52g): anchor on the main diagonal -> outside iff N.b != T.b
    for d in (2, 3):
        t = get_tables(d)
        n = t.num_types
        want = 1 - np.eye(n, dtype=np.int8)
        if d == 3:
            assert np.array_equal(t.outside_types_diag, want)


def test_prop23_e1_e2_root_types():
    """Paper Sec 4.4: a tet with anchor in E_1 (x=z) can have types {0,1,2};
    in E_2 (y=z) types {0,4,5} (for the type-0 root)."""
    t = get_tables(3)
    inside_ik = {b for b in range(6) if t.outside_types_ik[0, b] == 0}
    inside_kj = {b for b in range(6) if t.outside_types_kj[0, b] == 0}
    assert inside_ik == {0, 1, 2}
    assert inside_kj == {0, 4, 5}


def test_sigma_is_permutation():
    for d in (2, 3):
        t = get_tables(d)
        for b in range(t.num_types):
            assert sorted(t.bey_to_local[b].tolist()) == list(range(t.num_children))
            # inverse property
            for i in range(t.num_children):
                assert t.local_to_bey[b, t.bey_to_local[b, i]] == i


def test_corner_children_keep_type():
    """Paper Table 1 caption: corner children T_0..T_d have the parent type."""
    for d in (2, 3):
        t = get_tables(d)
        for b in range(t.num_types):
            for i in range(d + 1):
                assert t.child_type[b, i] == b
