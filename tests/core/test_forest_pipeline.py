"""Forest invariants through the full pipeline, per rank count and backend:

new_uniform -> adapt -> partition -> balance on 1, 2, and 4 simulated ranks,
for d=2 and d=3, under every element-ops backend.  Checks `validate()`,
exact `count_global` refinement arithmetic, ascending (tree, TM-index) leaf
order, and bit-identical results across backends.
"""

import numpy as np
import pytest

from repro.core import batch
from repro.core import forest as F
from repro.core import get_ops

BACKENDS = ["reference", "jnp", pytest.param("pallas", marks=pytest.mark.slow)]


def corner_cb(tree, elems):
    """Refine every element whose anchor is the origin corner (one per tree
    at each level, so the arithmetic below stays exact)."""
    a = np.asarray(elems.anchor)
    return (a.sum(axis=1) == 0).astype(np.int32)


def _run_pipeline(d, P, level=2, trees=2):
    o = get_ops(d)
    comm = F.SimComm(P)
    fs = F.new_uniform(d, trees, level, comm)
    n0 = F.count_global(fs)
    assert n0 == trees * o.num_elements(level)
    assert F.validate(fs)

    # adapt: each refined element is replaced by 2^d children
    n_refined = sum(int(corner_cb(f.tree, f.simplices()).sum()) for f in fs)
    fs = [F.adapt(f, corner_cb) for f in fs]
    assert F.count_global(fs) == n0 + n_refined * (o.nc - 1)
    assert F.validate(fs)

    fs = F.partition(fs, comm)
    assert F.count_global(fs) == n0 + n_refined * (o.nc - 1)  # pure redistribution
    counts = [f.num_local for f in fs]
    assert max(counts) - min(counts) <= 1
    assert F.validate(fs)

    fs = F.balance(fs, comm)
    assert F.count_global(fs) >= n0 + n_refined * (o.nc - 1)
    assert F.validate(fs)

    # leaves ascending in (tree, TM-index) order, per rank and globally
    prev = (-1, -1)
    for f in fs:
        for t, k in zip(f.tree.tolist(), f.keys.tolist()):
            assert (t, k) > prev, "leaves not in ascending (tree, key) order"
            prev = (t, k)
    return fs


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("P", [1, 2, 4])
@pytest.mark.parametrize("d", [2, 3])
def test_pipeline_invariants(d, P, backend):
    # pallas interpret mode pays a per-shape compile on CPU: shrink the mesh
    # (the invariants are size-independent; parity at scale is benchmarked).
    kw = dict(level=1, trees=1) if backend == "pallas" else {}
    with batch.use_backend(backend):
        _run_pipeline(d, P, **kw)


@pytest.mark.parametrize("d", [2, 3])
def test_pipeline_bit_identical_across_backends(d):
    """Acceptance: adapt and balance produce bit-identical forests under all
    backends (pallas covered by the slow-marked pipeline runs above plus the
    kernel-level parity suite)."""
    sigs = {}
    for backend in ("reference", "jnp"):
        with batch.use_backend(backend):
            fs = _run_pipeline(d, P=2)
            sigs[backend] = [
                (f.keys.copy(), f.level.copy(), f.tree.copy(), f.anchor.copy(),
                 f.stype.copy())
                for f in fs
            ]
    for fa, fb in zip(sigs["reference"], sigs["jnp"]):
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(a, b)


@pytest.mark.slow
@pytest.mark.parametrize("d", [2, 3])
def test_pipeline_bit_identical_pallas(d):
    sigs = {}
    for backend in ("reference", "pallas"):
        with batch.use_backend(backend):
            fs = _run_pipeline(d, P=1, level=1, trees=1)
            sigs[backend] = [(f.keys.copy(), f.level.copy()) for f in fs]
    for fa, fb in zip(sigs["reference"], sigs["pallas"]):
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("d", [2, 3])
def test_deep_refinement_balance_grows(d):
    """Recursive corner refinement by 2 levels forces balance to insert
    elements (2:1 across faces), and the result stays valid."""
    comm = F.SimComm(2)
    fs = F.new_uniform(d, 1, 1, comm)

    def deep_cb(tree, elems, cap=3):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(axis=1) == 0) & (l < cap)).astype(np.int32)

    fs = [F.adapt(f, deep_cb, recursive=True) for f in fs]
    before = F.count_global(fs)
    fs = F.balance(fs, comm)
    assert F.count_global(fs) > before
    assert F.validate(fs)
