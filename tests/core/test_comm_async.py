"""Async Comm surface: handle semantics, both DistComm transports offline
(fake MPI module / fake KV client), wire-format parity between the bindings,
and the completion-order-randomized Balance determinism property test.

The DistComm transports are exercised WITHOUT a real runtime: a dict-backed
fake of the jax.distributed KV client and an in-memory mailbox fake of the
mpi4py surface the binding uses (Isend/Irecv over BYTE buffers + Request
Waitall/Testall).  Posting both ranks before waiting either mirrors the
nonblocking protocol exactly, single threaded.  The parity test pins the
satellite bugfix: both bindings move exactly the `encode_payload` buffers
(equal `wire_digest()`), never pickle.
"""

import hashlib
import random
import struct

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: bounded random sampling
    from _pbt import given, settings, strategies as st

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import (
    CommHandle, DistComm, LatencyComm, SimComm, encode_payload,
)


# --------------------------------------------------------------- fake KV
class FakeKVClient:
    """Dict-backed stand-in for the jax.distributed coordination client.

    Single-threaded harness contract: every rank posts before any rank
    waits, so blocking gets always find their key (a miss is a protocol
    bug, surfaced as KeyError — which is also what the `_kv_ready` poll
    catches to report not-ready).  Deletes tombstone instead of destroy
    (the graveyard), so a late reader of an already-cleaned key is a
    visible protocol bug rather than silent data loss — with the
    reader-side cleanup each payload key has exactly ONE reader, so the
    graveyard must never actually be read from.  `calls` counts every
    client round-trip, which is what the wait-after-done regression test
    measures."""

    def __init__(self):
        self.store: dict = {}
        self.graveyard: dict = {}
        self.barriers: list[str] = []
        self.calls = 0

    def key_value_set(self, k, v):
        self.calls += 1
        self.store[k] = v

    def key_value_set_bytes(self, k, v):
        self.calls += 1
        self.store[k] = bytes(v)

    def blocking_key_value_get(self, k, timeout_ms):
        self.calls += 1
        return self.store[k] if k in self.store else self.graveyard[k]

    blocking_key_value_get_bytes = blocking_key_value_get

    def key_value_delete(self, k):
        self.calls += 1
        if k in self.store:
            self.graveyard[k] = self.store.pop(k)

    def wait_at_barrier(self, name, timeout_ms):
        self.calls += 1
        self.barriers.append(name)


# -------------------------------------------------------------- fake MPI
class _FakeReq:
    def __init__(self, deliver=None, test=None):
        self._deliver = deliver
        self._test = test
        self._done = deliver is None

    def Wait(self):
        if not self._done:
            self._deliver()
            self._done = True


class _FakeRequestNS:
    @staticmethod
    def Waitall(reqs):
        for r in reqs:
            r.Wait()

    @staticmethod
    def Testall(reqs):
        # MPI semantics: a successful Testall COMPLETES the requests
        # (buffers are filled) — the DistComm poll path relies on it
        if all(r._done or (r._test is not None and r._test()) for r in reqs):
            for r in reqs:
                r.Wait()
            return True
        return False


class FakeMPIModule:
    BYTE = "BYTE"
    INT64_T = "INT64_T"
    Request = _FakeRequestNS


class FakeMPIComm:
    """Mailbox-backed mpi4py communicator fake: p2p messages keyed by
    (dst, src, tag), FIFO per key, buffers copied at send time; native
    nonblocking collectives as shared slots keyed by each rank's posting
    counter (MPI matches collectives by posting order), completing once
    every rank has contributed.  A Wait on a collective some rank has not
    joined raises — the single-threaded analogue of a deadlock."""

    def __init__(self, rank, size, mailbox):
        self._rank, self._size, self._box = rank, size, mailbox
        self._ncoll = 0

    def Get_rank(self):
        return self._rank

    def Get_size(self):
        return self._size

    def Isend(self, spec, dest, tag):
        buf, _ = spec
        self._box.setdefault((dest, self._rank, tag), []).append(
            np.array(buf, copy=True))
        return _FakeReq()

    def Irecv(self, spec, source, tag):
        buf, _ = spec
        key = (self._rank, source, tag)

        def deliver():
            q = self._box.get(key)
            if not q:
                raise RuntimeError(f"no message posted for {key}")
            msg = q.pop(0)
            buf[: len(msg)] = msg

        return _FakeReq(deliver, test=lambda: bool(self._box.get(key)))

    def _collective(self, sendbuf, deliver_all):
        slot = self._box.setdefault(("coll", self._ncoll), {})
        self._ncoll += 1
        slot[self._rank] = np.array(sendbuf, copy=True)

        def deliver():
            if len(slot) < self._size:
                raise RuntimeError(
                    "collective waited before every rank posted it "
                    "(single-threaded fake: drive the peers' polls first)")
            deliver_all(slot)

        return _FakeReq(deliver, test=lambda: len(slot) == self._size)

    def Iallgather(self, sendspec, recvspec):
        sbuf, rbuf = sendspec[0], recvspec[0]
        n = len(sbuf)

        def deliver_all(slot):
            for r, part in slot.items():
                rbuf[r * n:(r + 1) * n] = part

        return self._collective(sbuf, deliver_all)

    def Iallgatherv(self, sendspec, recvspec):
        sbuf = sendspec[0]
        rbuf, counts, displs, _ = recvspec

        def deliver_all(slot):
            for r, part in slot.items():
                rbuf[displs[r]:displs[r] + counts[r]] = part

        return self._collective(sbuf, deliver_all)


def _mpi_pair():
    box: dict = {}
    return [
        DistComm._testing_instance(
            r, 2, mpi=FakeMPIComm(r, 2, box), MPI=FakeMPIModule)
        for r in range(2)
    ]


def _kv_pair():
    client = FakeKVClient()
    return [DistComm._testing_instance(r, 2, client=client) for r in range(2)]


PAYLOAD = [
    {"a": np.arange(7, dtype=np.uint64) * 2**40, "b": [None, True, -5, 1.5]},
    (np.zeros((0, 3), np.int32), b"\x00\xff", "text"),
]


def _expected_digest(blob_seq):
    """Digest of (peer, len, bytes) records — the documented wire_digest
    format — recomputed from raw encode_payload output."""
    h = hashlib.sha256()
    for q, blob in blob_seq:
        h.update(struct.pack("<II", q, len(blob)))
        h.update(blob)
    return h.hexdigest()


@pytest.mark.parametrize("pair_fn", [_mpi_pair, _kv_pair],
                         ids=["mpi", "kv"])
def test_distcomm_transport_collectives(pair_fn):
    """allgather/alltoallv through each fake transport match SimComm, with
    the nonblocking post-both-then-wait-both protocol."""
    comms = pair_fn()
    sim = SimComm(2)
    xs = [PAYLOAD[0], PAYLOAD[1]]
    hs = [comms[r].iallgather([xs[r]]) for r in range(2)]
    # the MPI allgather is a two-phase native collective (sizes, then
    # payload): each rank's poll posts its payload contribution once the
    # size collective is in, so drive both polls before waiting either —
    # the single-threaded fake cannot block for a peer's progress
    for h in hs:
        h.done()
    want = sim.allgather(list(xs))
    for r in range(2):
        got = hs[r].wait()
        assert len(got) == 2
        np.testing.assert_array_equal(got[0]["a"], want[0]["a"])
        np.testing.assert_array_equal(got[1][0], want[1][0])
        assert got[1][1] == want[1][1] and got[1][2] == want[1][2]
    rows = [[(r, q, np.full(3, 10 * r + q, np.int32)) for q in range(2)]
            for r in range(2)]
    hs = [comms[r].ialltoallv([rows[r]]) for r in range(2)]
    wantv = sim.alltoallv(list(rows))
    for r in range(2):
        got = hs[r].wait()[0]
        for p in range(2):
            assert got[p][:2] == wantv[r][p][:2]
            np.testing.assert_array_equal(got[p][2], wantv[r][p][2])


def test_distcomm_wire_parity_between_bindings():
    """The satellite bugfix pinned: mpi4py and KV-store bindings move
    byte-identical wire payloads — the packed `encode_payload` buffers —
    for the same collective sequence (equal running wire digests, matching
    a digest recomputed from encode_payload directly: no pickle)."""
    mpi_pair, kv_pair = _mpi_pair(), _kv_pair()
    per_rank_expect = []
    for r in range(2):
        x = PAYLOAD[r]
        row = [PAYLOAD[0], None]
        blob_ag = encode_payload(x)
        peer = 1 - r
        per_rank_expect.append(_expected_digest(
            [(peer, blob_ag), (peer, encode_payload(row[peer]))]))
        for comms in (mpi_pair, kv_pair):
            comms[r].iallgather([x])  # handles waited below, posts hash now
    for comms in (mpi_pair, kv_pair):
        hs = [comms[r].ialltoallv([[PAYLOAD[0], None]]) for r in range(2)]
        for h in hs:
            h.wait()
    for r in range(2):
        d_mpi = mpi_pair[r].wire_digest()
        d_kv = kv_pair[r].wire_digest()
        assert d_mpi == d_kv == per_rank_expect[r]


def test_distcomm_mpi_poll_drives_progress():
    """`done()` on the MPI binding is a real progress driver: False before
    the peer posts, and for the native-collective allgather each rank's
    poll posts its payload Iallgatherv once the size collective is in —
    after one poll round on both ranks the exchange is complete and
    `wait()` does not block."""
    comms = _mpi_pair()
    h0 = comms[0].iallgather([7])
    assert not h0.done()  # peer's size contribution not posted yet
    h1 = comms[1].iallgather([8])
    h0.done(), h1.done()  # each poll posts its rank's payload contribution
    assert h0.done() and h1.done()
    assert h0.wait() == [7, 8] and h1.wait() == [7, 8]


def test_distcomm_mpi_allgather_uses_native_collectives():
    """The O(P^2) bugfix pinned: an allgather posts NO point-to-point
    messages — everything rides the two native collectives (size
    Iallgather + payload Iallgatherv) — while alltoallv still uses the
    sparse p2p path."""
    box: dict = {}
    comms = [DistComm._testing_instance(
        r, 2, mpi=FakeMPIComm(r, 2, box), MPI=FakeMPIModule)
        for r in range(2)]
    hs = [comms[r].iallgather([r]) for r in range(2)]
    for h in hs:
        h.done()
    assert [h.wait() for h in hs] == [[0, 1], [0, 1]]
    assert all(k[0] == "coll" for k in box), f"p2p keys leaked: {sorted(box)}"
    rows = [[None, "x"], ["y", None]]
    hs = [comms[r].ialltoallv([rows[r]]) for r in range(2)]
    for h in hs:
        h.wait()
    assert any(k[0] != "coll" for k in box), "alltoallv should stay p2p"


def test_distcomm_kv_poll_and_cleanup():
    """`done()` is a real poll on the KV binding (false before the peer
    posts, true after), completed generations delete their keys — each key
    removed by its single reader right after the fetch — and NO barrier is
    ever taken (the old pre-cleanup barrier sat on the wait critical
    path)."""
    client = FakeKVClient()
    c0, c1 = (DistComm._testing_instance(r, 2, client=client)
              for r in range(2))
    h0 = c0.iallgather([1])
    assert not h0.done()  # rank 1 has not posted its payload key yet
    h1 = c1.iallgather([2])
    assert h0.done() and h1.done()
    assert h0.wait() == [1, 2] and h1.wait() == [1, 2]
    assert not client.store, f"leaked KV keys: {sorted(client.store)}"
    assert client.barriers == [], "cleanup must not synchronize on a barrier"


def test_distcomm_kv_wait_after_done_is_free():
    """The hot-path regression pinned: once a handle polls `done() ==
    True`, its `wait()` performs ZERO KV round-trips — the poll already
    fetched, cached, and cleaned every peer payload."""
    client = FakeKVClient()
    c0, c1 = (DistComm._testing_instance(r, 2, client=client)
              for r in range(2))
    h0 = c0.iallgather([10])
    h1 = c1.iallgather([20])
    assert h0.done() and h1.done()
    snapshot = client.calls
    assert h0.wait() == [10, 20] and h1.wait() == [10, 20]
    assert client.calls == snapshot, (
        f"wait() after done() hit the KV store {client.calls - snapshot} "
        "times")


def test_distcomm_namespace_isolates_keys():
    """Two DistComm instances over one coordinator (overlapped + serialized
    benchmark runs) must not collide: namespaces split the KV keyspace."""
    client = FakeKVClient()
    a = [DistComm._testing_instance(r, 2, client=client, namespace="a.")
         for r in range(2)]
    b = [DistComm._testing_instance(r, 2, client=client, namespace="b.")
         for r in range(2)]
    ha = [a[r].iallgather([("A", r)]) for r in range(2)]
    hb = [b[r].iallgather([("B", r)]) for r in range(2)]
    assert ha[0].wait() == [("A", 0), ("A", 1)]
    assert hb[0].wait() == [("B", 0), ("B", 1)]
    ha[1].wait(), hb[1].wait()
    assert not client.store
    assert {k.split("/")[1] for k in client.graveyard} == {"a.0", "b.0"}


# ------------------------------------------- completion-order determinism
class JitterComm(SimComm):
    """SimComm whose nonblocking handles mature out of order: waiting any
    handle first completes a random subset of the other in-flight exchanges
    (seeded), simulating a transport that delivers in arbitrary order.  The
    collectives' RESULTS are unchanged — the shim checks that the overlapped
    Balance protocol never depends on completion order."""

    def __init__(self, num_ranks: int, seed: int = 0):
        super().__init__(num_ranks)
        self._rng = random.Random(seed)
        self._inflight: list = []

    def _defer(self, result) -> CommHandle:
        box: dict = {}

        def mature():
            box["r"] = result
            if mature in self._inflight:
                self._inflight.remove(mature)

        self._inflight.append(mature)

        def complete():
            others = [m for m in self._inflight if m is not mature]
            self._rng.shuffle(others)
            for m in others[: self._rng.randint(0, len(others))]:
                m()
            if "r" not in box:
                mature()
            return box["r"]

        return CommHandle(complete, poll=lambda: "r" in box)

    def _iallgather(self, per_local):
        return self._defer(self._allgather(per_local))

    def _ialltoallv(self, send):
        return self._defer(self._alltoallv(send))


def _jitter_fixture():
    cm = C.cmesh_unit_cube(2)
    comm = SimComm(2)
    fs = F.new_uniform(2, 2, 1, comm, cmesh=cm)

    def corner(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < 5)).astype(np.int32)

    return [F.adapt(f, corner, recursive=True) for f in fs]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_balance_completion_order_invariant(seed):
    """Property: under randomized handle-completion interleavings the
    overlapped balance is bit-identical to the serialized round loop."""
    fs = _jitter_fixture()
    out_j = F.balance([f for f in fs], JitterComm(2, seed), overlap=True)
    out_s = F.balance([f for f in fs], SimComm(2), overlap=False)
    for a, b in zip(out_j, out_s):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_ghost_completion_order_invariant(seed):
    """Property: the double-buffered ghost is bit-identical to the
    serialized baseline — ghost layers, owners, AND payload bytes — under
    randomized handle-completion interleavings."""
    fs = F.balance(_jitter_fixture(), SimComm(2))
    cj, cs = JitterComm(2, seed), SimComm(2)
    out_j = F.ghost(fs, cj, overlap=True)
    out_s = F.ghost(fs, cs, overlap=False)
    for a, b in zip(out_j, out_s):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert cj.bytes_for("ghost") == cs.bytes_for("ghost")


def test_balance_latencycomm_matches_simcomm():
    """LatencyComm changes timing only: balance over it is bit-identical to
    SimComm, overlapped and serialized."""
    fs = _jitter_fixture()
    ref = F.balance([f for f in fs], SimComm(2))
    for ov in (True, False):
        out = F.balance([f for f in fs], LatencyComm(2, 1e-4), overlap=ov)
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(a.keys, b.keys)
