"""Hypothesis property tests for forest invariants under random adaptation."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from repro.core import forest as F


@given(st.integers(2, 3), st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=6, deadline=None)
def test_random_adapt_preserves_invariants(d, seed, passes):
    """Any sequence of random refine/coarsen flags keeps the forest valid:
    TM-sorted, non-overlapping, inside root, volume-complete."""
    comm = F.SimComm(2)
    fs = F.new_uniform(d, 2, 2, comm)
    rng = np.random.default_rng(seed)
    for _ in range(passes):
        def cb(tree, elems, r=rng):
            return r.integers(-1, 2, size=len(tree)).astype(np.int32)
        fs = [F.adapt(f, cb) for f in fs]
        assert F.validate(fs)
    fs = F.partition(fs, comm)
    assert F.validate(fs)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_partition_weighted_random_preserves_set(seed):
    """Weighted partition is a pure redistribution: the global (tree, key)
    multiset is unchanged and loads are balanced."""
    comm = F.SimComm(4)
    fs = F.new_uniform(3, 2, 2, comm)
    rng = np.random.default_rng(seed)
    fs = [F.adapt(f, lambda t, e: rng.integers(0, 2, size=len(t)).astype(np.int32))
          for f in fs]
    before = sorted(zip(np.concatenate([f.tree for f in fs]).tolist(),
                        np.concatenate([f.keys for f in fs]).tolist()))
    ws = [rng.uniform(0.1, 10.0, size=f.num_local) for f in fs]
    out = F.partition(fs, comm, weights=ws)
    after = sorted(zip(np.concatenate([f.tree for f in out]).tolist(),
                       np.concatenate([f.keys for f in out]).tolist()))
    assert before == after
    assert F.validate(out)


@given(st.integers(2, 3), st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_balance_idempotent(d, seed):
    """balance(balance(x)) == balance(x)."""
    comm = F.SimComm(1)
    fs = F.new_uniform(d, 1, 1, comm)
    rng = np.random.default_rng(seed)
    for _ in range(3):
        fs = [F.adapt(f, lambda t, e: (rng.random(len(t)) < 0.3).astype(np.int32))
              for f in fs]
    b1 = F.balance(fs, comm)
    b2 = F.balance(b1, comm)
    np.testing.assert_array_equal(b1[0].keys, b2[0].keys)
    np.testing.assert_array_equal(b1[0].level, b2[0].level)
