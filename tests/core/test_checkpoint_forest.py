"""Forest checkpointing: packed blobs + markers, elastic rank-count restore."""

import numpy as np
import pytest

from repro.checkpoint import load_forest, save_forest
from repro.checkpoint.store import latest_step
from repro.core import cmesh as C
from repro.core import forest as F


def _adapted_forest(comm, d=3, trees=2, level=2, cmesh=None):
    fs = F.new_uniform(d, trees, level, comm, cmesh=cmesh)

    def cb(tree, elems):
        a = np.asarray(elems.anchor)
        return (a.sum(1) == 0).astype(np.int32)

    return [F.adapt(f, cb) for f in fs]


def test_save_restore_same_rank_count_is_exact(tmp_path):
    comm = F.SimComm(4)
    fs = _adapted_forest(comm)
    save_forest(tmp_path, fs, comm, step=7)
    assert latest_step(tmp_path) == 7
    out = load_forest(tmp_path, F.SimComm(4))
    assert len(out) == 4
    for a, b in zip(fs, out):
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
        np.testing.assert_array_equal(a.keys, b.keys)
        assert (a.rank, a.num_ranks) == (b.rank, b.num_ranks)
    assert F.validate(out)


@pytest.mark.parametrize("p_save,p_load", [(4, 2), (2, 4)])
def test_elastic_restore_across_rank_counts(tmp_path, p_save, p_load):
    """ROADMAP item: restore onto a different rank count — same global leaf
    sequence, valid partition, and the restored forest keeps working."""
    comm = F.SimComm(p_save)
    fs = _adapted_forest(comm)
    save_forest(tmp_path, fs, comm, step=0)
    comm2 = F.SimComm(p_load)
    out = load_forest(tmp_path, comm2)
    assert len(out) == p_load
    assert F.count_global(out) == F.count_global(fs)
    assert F.validate(out)
    # the global (tree, key) sequence is preserved exactly
    np.testing.assert_array_equal(
        np.concatenate([f.keys for f in out]),
        np.concatenate([f.keys for f in fs]))
    np.testing.assert_array_equal(
        np.concatenate([f.tree for f in out]),
        np.concatenate([f.tree for f in fs]))
    # and the restored forest is a working forest: balance + ghost run clean
    out = F.balance(out, comm2)
    gh = F.ghost(out, comm2)
    assert F.validate(out, gh)


def test_restore_with_empty_ranks_reproduces_markers(tmp_path):
    """A partition with empty ranks round-trips exactly at equal P."""
    comm = F.SimComm(4)
    fs = F.new_uniform(2, 1, 2, comm)
    ws = [np.zeros(f.num_local) for f in fs]
    ws[0][:] = 0.0
    ws[0][0] = 1.0
    fs = F.partition(fs, comm, weights=ws)  # some ranks end up empty
    assert any(f.num_local == 0 for f in fs)
    save_forest(tmp_path, fs, comm, step=1)
    out = load_forest(tmp_path, F.SimComm(4))
    for a, b in zip(fs, out):
        assert a.num_local == b.num_local
        np.testing.assert_array_equal(a.keys, b.keys)


@pytest.mark.parametrize("p_save,p_load", [(4, 2), (2, 4), (4, 4)])
def test_restore_then_repartition_round_trip(tmp_path, p_save, p_load):
    """The elasticity loop behind a rank-count change in a long-running
    service: save at P, restore at P', `repartition` on measured weights —
    the global leaf sequence survives every hop, the final layout is
    weight-balanced, and the forest keeps working."""
    comm = F.SimComm(p_save)
    fs = _adapted_forest(comm)
    save_forest(tmp_path, fs, comm, step=0)
    comm2 = F.SimComm(p_load)
    out = load_forest(tmp_path, comm2)
    ws = [1.0 + (f.keys % np.uint64(5)).astype(np.float64) for f in out]
    out = F.repartition(out, comm2, weights=ws)
    assert F.count_global(out) == F.count_global(fs)
    assert F.validate(out)
    np.testing.assert_array_equal(
        np.concatenate([f.keys for f in out]),
        np.concatenate([f.keys for f in fs]))
    np.testing.assert_array_equal(
        np.concatenate([f.tree for f in out]),
        np.concatenate([f.tree for f in fs]))
    loads = [float(w.sum()) for w in
             [1.0 + (f.keys % np.uint64(5)).astype(np.float64) for f in out]]
    assert max(loads) / (sum(loads) / p_load) < 1.5
    out = F.balance(out, comm2)
    gh = F.ghost(out, comm2)
    assert F.validate(out, gh)


def test_weighted_restore_matches_repartition(tmp_path):
    """`load_forest(weights=...)` lands directly on the layout that a plain
    restore followed by `repartition` reaches: identical per-rank slices
    (both routes split via `placement.target_ranks_np` over the same
    global prefix sums)."""
    comm = F.SimComm(4)
    fs = _adapted_forest(comm)
    save_forest(tmp_path, fs, comm, step=0)
    comm2 = F.SimComm(2)
    plain = load_forest(tmp_path, comm2)
    w_global = 1.0 + (np.concatenate([f.keys for f in plain])
                      % np.uint64(7)).astype(np.float64)
    direct = load_forest(tmp_path, F.SimComm(2), weights=w_global)
    bounds = np.cumsum([0] + [f.num_local for f in plain])
    via_repart = F.repartition(
        plain, comm2,
        weights=[w_global[a:b] for a, b in zip(bounds[:-1], bounds[1:])])
    for a, b in zip(direct, via_repart):
        assert a.num_local == b.num_local
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
    assert F.validate(direct)


def test_restore_carries_cmesh(tmp_path):
    """The coarse mesh is a derived structure: the loader re-attaches it and
    cross-tree ghost works on the restored forest."""
    cm = C.cmesh_unit_cube(2)
    comm = F.SimComm(2)
    fs = _adapted_forest(comm, d=2, trees=cm.num_trees, cmesh=cm)
    fs = F.balance(fs, comm)
    save_forest(tmp_path, fs, comm, step=0)
    out = load_forest(tmp_path, F.SimComm(2), cmesh=cm)
    gh_a = F.ghost(fs, F.SimComm(2))
    gh_b = F.ghost(out, F.SimComm(2))
    for a, b in zip(gh_a, gh_b):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k])
