"""Dynamic repartition (element migration): oracle differentials, overlap
bit-identity under completion-order jitter, the post-migration empty-rank
edge cases, and the adapt -> repartition -> balance loop's imbalance gate.

The migration engine ships Remark-20 wire triples between ranks; its ground
truth is the single-rank world, where repartition is the identity on the
global leaf sequence.  Every differential here therefore compares the
CONCATENATED per-rank arrays against a `LocalComm` run of the same
deterministic construction — same leaves, same order, any P.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline box: bounded random sampling shim (tests/_pbt.py)
    from _pbt import given, settings, strategies as st

from test_comm_async import JitterComm

from repro.core import cmesh as C
from repro.core import forest as F


def _det_cb(cap):
    """Adapt callback that is a pure function of element identity, so runs
    under different rank counts refine identically."""
    def cb(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        t = np.asarray(tree)
        return (((a.sum(1) + 3 * t) % 3 == 0) & (l < cap)).astype(np.int32)
    return cb


def _det_weights(f):
    """Per-element weights derived from element identity (key + tree), so
    every rank layout derives the same global weight sequence."""
    return 1.0 + (f.keys % np.uint64(7)).astype(np.float64) \
        + (f.tree % 3).astype(np.float64)


def _global(fs):
    return (np.concatenate([f.tree for f in fs]),
            np.concatenate([f.keys for f in fs]),
            np.concatenate([f.level for f in fs]),
            np.concatenate([f.anchor for f in fs]),
            np.concatenate([f.stype for f in fs]))


@given(st.integers(2, 3), st.integers(1, 6), st.integers(2, 4))
@settings(max_examples=6, deadline=None)
def test_repartition_matches_single_rank_oracle(d, cap, P):
    """Differential vs the single-rank world: after the same deterministic
    adapt, repartition at any P leaves the concatenated global sequence
    element-for-element equal to the LocalComm run — anchors and stypes
    included, i.e. the wire decode reproduced what raw arrays would have
    shipped."""
    comm = F.SimComm(P)
    fs = F.new_uniform(d, 2, 1, comm)
    fs = [F.adapt(f, _det_cb(cap), recursive=True) for f in fs]
    out = F.repartition(fs, comm, weights=[_det_weights(f) for f in fs])
    lc = F.LocalComm()
    ref = F.new_uniform(d, 2, 1, lc)
    ref = [F.adapt(f, _det_cb(cap), recursive=True) for f in ref]
    ref = F.repartition(ref, lc, weights=[_det_weights(f) for f in ref])
    for got, want in zip(_global(out), _global(ref)):
        np.testing.assert_array_equal(got, want)
    assert F.validate(out)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_repartition_overlap_bit_identity_under_jitter(seed):
    """Property: under randomized handle-completion interleavings the
    overlapped migration is bit-identical to the serialized one, and ships
    exactly the same bytes."""
    rng = np.random.default_rng(seed)
    comm_j, comm_s = JitterComm(4, seed), F.SimComm(4)
    fs = F.new_uniform(2, 2, 2, comm_j)
    fs = [F.adapt(f, lambda t, e: rng.integers(0, 2, size=len(t)).astype(np.int32))
          for f in fs]
    ws = [rng.uniform(0.0, 5.0, size=f.num_local) for f in fs]
    out_j = F.repartition(fs, comm_j, weights=ws, overlap=True)
    out_s = F.repartition(fs, comm_s, weights=ws, overlap=False)
    for a, b in zip(out_j, out_s):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)
        np.testing.assert_array_equal(a.tree, b.tree)
    assert comm_j.bytes_for("repartition") == comm_s.bytes_for("repartition")
    assert comm_j.counters["repartition"] == comm_s.counters["repartition"]


# ------------------------------------------- empty-rank / marker edge cases
def test_repartition_all_weight_on_one_rank():
    """All weight held by rank 0's elements: they spread across the world,
    every zero-weight element lands on the last rank, markers stay lex
    sorted, and `owner_rank` routes every element to its holder."""
    comm = F.SimComm(4)
    fs = F.new_uniform(2, 2, 2, comm)
    before = F.count_global(fs)
    ws = [np.ones(f.num_local) if i == 0 else np.zeros(f.num_local)
          for i, f in enumerate(fs)]
    out = F.repartition(fs, comm, weights=ws)
    assert F.count_global(out) == before
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    bops = out[0].bops
    for p, f in enumerate(out):
        if f.num_local:
            assert (bops.owner_rank(f.tree, f.keys, mt, mk) == p).all()


def test_repartition_single_heavy_element_empties_ranks():
    """One heavy element among zeros: ranks whose weight share rounds to
    zero elements go empty, and the marker table still routes (the
    empty-rank fill inherits the next non-empty rank's marker)."""
    comm = F.SimComm(4)
    fs = F.new_uniform(2, 1, 2, comm)
    ws = [np.zeros(f.num_local) for f in fs]
    ws[0][0] = 1.0
    out = F.repartition(fs, comm, weights=ws)
    assert any(f.num_local == 0 for f in out), "expected empty ranks"
    assert F.count_global(out) == F.count_global(fs)
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    bops = out[0].bops
    for p, f in enumerate(out):
        if f.num_local:
            assert (bops.owner_rank(f.tree, f.keys, mt, mk) == p).all()


def test_repartition_zero_weight_elements_conserve_the_set():
    comm = F.SimComm(3)
    fs = F.new_uniform(2, 2, 2, comm)
    rng = np.random.default_rng(7)
    ws = [np.where(rng.random(f.num_local) < 0.5, 0.0, 1.0) for f in fs]
    before = sorted(zip(np.concatenate([f.tree for f in fs]).tolist(),
                        np.concatenate([f.keys for f in fs]).tolist()))
    out = F.repartition(fs, comm, weights=ws)
    after = sorted(zip(np.concatenate([f.tree for f in out]).tolist(),
                       np.concatenate([f.keys for f in out]).tolist()))
    assert before == after
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)


def test_repartition_more_ranks_than_elements():
    """P > num_elements: most ranks are empty, markers stay monotone, and
    the degenerate forest keeps working (balance/ghost are no-ops)."""
    comm = F.SimComm(8)
    fs = F.new_uniform(2, 1, 0, comm)  # a single level-0 leaf, 8 ranks
    assert F.count_global(fs) == 1
    out = F.repartition(fs, comm)
    assert F.count_global(out) == 1
    assert F.validate(out)
    mt, mk = F.partition_markers(out, comm)
    lex = list(zip(mt.tolist(), mk.tolist()))
    assert lex == sorted(lex)
    bal = F.balance(out, comm)
    assert F.count_global(bal) == 1
    gh = F.ghost(bal, comm)
    assert all(len(g["level"]) == 0 for g in gh)


def test_repartition_rejects_bad_weights():
    comm = F.SimComm(2)
    fs = F.new_uniform(2, 1, 1, comm)
    with pytest.raises(ValueError, match="one weight per local element"):
        F.repartition(fs, comm, weights=[np.ones(1), np.ones(1)])
    with pytest.raises(ValueError, match="nonnegative"):
        F.repartition(
            fs, comm, weights=[-np.ones(f.num_local) for f in fs])


# --------------------------------------------- the adapt/repartition loop
def test_skewed_adapt_repartition_balance_loop():
    """The tentpole's in-process acceptance shape: a skewed adapt on the
    Kuhn-brick weak-scaling mesh drives imbalance to ~P; `repartition`
    brings max/mean element imbalance under 1.1 without changing the
    global leaf set; `balance` + `ghost` then run clean on the migrated
    layout (derived structures are recomputed, not carried over)."""
    P = 4
    comm = F.SimComm(P)
    cm = C.cmesh_brick(2, (P, 1))

    def skew(tree, elems):
        l = np.asarray(elems.level)
        return ((np.asarray(tree) < 2) & (l < 4)).astype(np.int32)

    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, skew, recursive=True) for f in fs]
    before = F.load_imbalance(fs, comm)
    assert before > 1.5, f"fixture must be skewed, got {before}"
    glob_before = sorted(zip(np.concatenate([f.tree for f in fs]).tolist(),
                             np.concatenate([f.keys for f in fs]).tolist()))
    out = F.repartition(fs, comm)
    after = F.load_imbalance(out, comm)
    assert after <= 1.1, f"imbalance {after} > 1.1 after repartition"
    glob_after = sorted(zip(np.concatenate([f.tree for f in out]).tolist(),
                            np.concatenate([f.keys for f in out]).tolist()))
    assert glob_before == glob_after
    out = F.balance(out, comm)
    gh = F.ghost(out, comm)
    assert F.validate(out, gh)
    assert comm.bytes_for("repartition") > 0  # migration was metered


def test_partition_delegates_to_migration_engine():
    """`partition` is the same engine under its own phase label: results
    equal `repartition`, bytes metered under "partition"."""
    comm_a, comm_b = F.SimComm(3), F.SimComm(3)
    fs = F.new_uniform(2, 2, 2, comm_a)
    rng = np.random.default_rng(3)
    ws = [rng.uniform(0.5, 2.0, size=f.num_local) for f in fs]
    out_a = F.partition(fs, comm_a, weights=ws)
    out_b = F.repartition(fs, comm_b, weights=ws)
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.tree, b.tree)
    assert comm_a.bytes_for("partition") > 0
    assert comm_a.bytes_for("partition") == comm_b.bytes_for("repartition")


def test_repartition_wire_is_packed_triples():
    """Migration ships the Remark-20 13-byte wire triples, not raw SoA
    arrays: moving n elements costs ~13n bytes plus the weight-total
    allgather, far under the 24n+ of (anchor, level, stype, tree)."""
    comm = F.SimComm(2)
    fs = F.new_uniform(3, 2, 2, comm)
    # all weight on rank 1: rank 0's whole half migrates, n/2 elements
    ws = [np.zeros(fs[0].num_local), np.ones(fs[1].num_local)]
    n_move = fs[0].num_local + fs[1].num_local // 2  # re-split of rank 1's run
    F.repartition(fs, comm, weights=ws)
    bytes_moved = comm.bytes_for("repartition")
    assert bytes_moved < n_move * 24, (bytes_moved, n_move)
