"""`run_ranks` hard wall-clock timeout + expected-failure fleets.

Fast (no jax.distributed): the scripts are plain Python, so these tests
exercise exactly the harness logic — one shared deadline for the whole
fleet, straggler kill + reap, per-rank state/stderr in the raised
`RankTimeoutError`, and the `check=False` triple contract the recovery
tests rely on when a crash is the expected outcome.
"""

import time

import pytest

from repro.core.errors import RankTimeoutError
from repro.launch.multiproc import run_ranks

# argv = [coordinator_port, rank, *extra] — these scripts ignore the port.
HANG_ODD = r"""
import sys, time
rank = int(sys.argv[2])
if rank % 2:
    print("hanging", rank, flush=True)
    sys.stderr.write(f"rank {rank} entering infinite wait\n")
    sys.stderr.flush()
    time.sleep(3600)
print("done", rank, flush=True)
"""

EXIT_RANK = r"""
import sys
rank = int(sys.argv[2])
sys.stderr.write(f"rank {rank} failing on purpose\n")
print("ran", rank, flush=True)
sys.exit(rank)
"""


def test_wall_clock_timeout_kills_stragglers_and_diagnoses():
    t0 = time.monotonic()
    with pytest.raises(RankTimeoutError) as ei:
        run_ranks(HANG_ODD, 2, timeout=3.0)
    wall = time.monotonic() - t0
    # one HARD deadline for the fleet, not per-rank budgets that stack
    assert wall < 30.0
    e = ei.value
    assert set(e.per_rank) == {0, 1}
    state0, _ = e.per_rank[0]
    state1, tail1 = e.per_rank[1]
    assert state0 == "exited 0"
    assert state1 == "killed after wall-clock timeout"
    assert "entering infinite wait" in tail1  # stderr captured, not lost
    msg = str(e)
    assert "1 of 2 rank(s) still running" in msg
    assert "rank 1: killed after wall-clock timeout" in msg


def test_timeout_with_all_ranks_hung():
    with pytest.raises(RankTimeoutError) as ei:
        run_ranks("import time\ntime.sleep(3600)\n", 2, timeout=2.0)
    assert all(st == "killed after wall-clock timeout"
               for st, _ in ei.value.per_rank.values())


def test_check_false_returns_per_rank_triples():
    res = run_ranks(EXIT_RANK, 3, timeout=60.0, check=False)
    assert [rc for _, _, rc in res] == [0, 1, 2]
    for pid, (out, err, _rc) in enumerate(res):
        assert f"ran {pid}" in out
        assert f"rank {pid} failing on purpose" in err


def test_check_true_raises_naming_failed_rank():
    with pytest.raises(RuntimeError, match=r"rank 1 exited 1"):
        run_ranks(EXIT_RANK, 2, timeout=60.0)


def test_fast_fleet_returns_pairs_under_check():
    outs = run_ranks("import sys\nprint('ok', sys.argv[2])\n", 2, timeout=60.0)
    assert [len(o) for o in outs] == [2, 2]  # historical (stdout, stderr)
    assert "ok 0" in outs[0][0] and "ok 1" in outs[1][0]
