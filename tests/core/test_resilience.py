"""Chaos injection, deadline/timeout, crash recovery — in-process.

The seeded-fault contract, proved on the reference brick pipeline
(2x1 Kuhn brick, corner adapt to level 4, balance):

  * byte faults (corrupt/truncate/duplicate) at real rates are ALWAYS
    detected by the production unframe/decode path and retried — the
    chaos run ends bit-identical to the fault-free run, never silently
    wrong, with every injection counted and every retry metered;
  * a persistently bad link exhausts the bounded retry budget and
    raises the typed detection error — no unbounded loop;
  * a stalled rank surfaces through the deadline machinery as a
    `CommTimeoutError` naming the phase;
  * `BalanceNonConvergence` carries the round budget and per-rank
    still-dirty counts;
  * crash mid-balance + `Autosaver` checkpoint + `recover` at reduced P
    completes element-for-element identical to a fresh small-world run;
  * a corrupted checkpoint blob is rejected (`CheckpointIntegrityError`),
    never restored.
"""

import numpy as np
import pytest

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.errors import (
    CheckpointIntegrityError,
    CommTimeoutError,
    InjectedCrash,
    WireIntegrityError,
)
from repro.core.resilience import Autosaver, ChaosComm, ChaosConfig, recover
from repro.checkpoint.forest_io import save_forest

CHAOS_RATES = dict(p_corrupt=0.2, p_truncate=0.1, p_duplicate=0.1,
                   p_delay=0.05)


def _corner(tree, elems, cap=4):
    a = np.asarray(elems.anchor)
    l = np.asarray(elems.level)
    return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)


def _adapted(comm, cm):
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    return [F.adapt(f, _corner, recursive=True) for f in fs]


def _world(fs):
    """Global (rank-major == SFC-order) concatenation: partition-layout
    independent, so elastic restores compare against fresh runs."""
    return {k: np.concatenate([np.asarray(getattr(f, k)) for f in fs])
            for k in ("tree", "anchor", "level", "stype")}


def _assert_world_equal(a, b):
    for k in ("tree", "anchor", "level", "stype"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_chaos_byte_faults_always_detected_and_bit_identical():
    cm = C.cmesh_brick(2, (2, 1))
    cc = F.SimComm(4)
    clean = F.balance(_adapted(cc, cm), cc)

    ch = ChaosComm(F.SimComm(4), seed=7, **CHAOS_RATES)
    noisy = F.balance(_adapted(ch, cm), ch)

    _assert_world_equal(_world(noisy), _world(clean))
    inj = ch.injected()
    assert inj > 0, "rates this high must inject on this pipeline"
    # NEVER a silently wrong forest: every injected byte fault was caught
    # by the production unframe/decode path, and each transient fault cost
    # exactly one bounded redelivery
    assert ch.fault_counts["detected"] == inj
    assert ch.fault_counts["retries"] == inj
    assert ch.fault_counts["delay"] > 0  # reordering was exercised too


def test_chaos_shares_meters_with_inner_comm():
    """Wrapping must not perturb byte attribution: the chaos run's phase
    meters equal the fault-free run's (faults mutate copies AFTER the
    inner comm metered the pristine post)."""
    cm = C.cmesh_brick(2, (2, 1))
    cc = F.SimComm(4)
    F.balance(_adapted(cc, cm), cc)

    inner = F.SimComm(4)
    ch = ChaosComm(inner, seed=7, **CHAOS_RATES)
    F.balance(_adapted(ch, cm), ch)

    assert ch.counters is inner.counters  # one table, not a fork
    assert set(ch.counters) == set(cc.counters)
    assert ch.counters == cc.counters
    assert ch.size == 4 and ch.P == 4 and len(ch.local_ranks) == 4
    assert isinstance(ch.wire_digest(), str) and ch.wire_digest()


def test_chaos_seed_reproducibility():
    cm = C.cmesh_brick(2, (2, 1))
    counts = []
    for _ in range(2):
        ch = ChaosComm(F.SimComm(4), seed=7, **CHAOS_RATES)
        F.balance(_adapted(ch, cm), ch)
        counts.append(dict(ch.fault_counts))
    assert counts[0] == counts[1]
    ch2 = ChaosComm(F.SimComm(4), seed=8, **CHAOS_RATES)
    F.balance(_adapted(ch2, cm), ch2)
    assert dict(ch2.fault_counts) != counts[0]  # the seed IS the scenario


def test_chaos_persistent_fault_exhausts_bounded_retries():
    """A rotten link (fault re-rolled on every redelivery at rate 1.0)
    must exhaust `max_retries` and re-raise the detection error — the
    retry loop is bounded, and the meters show exactly the budget."""
    ch = ChaosComm(F.SimComm(2), config=ChaosConfig(
        seed=0, p_corrupt=1.0, persistent_faults=True, max_retries=3))
    with pytest.raises(WireIntegrityError):
        ch.allgather([np.arange(4, dtype=np.int64), "payload"])
    assert ch.fault_counts["corrupt"] == ch.cfg.max_retries + 1
    assert ch.fault_counts["detected"] == ch.cfg.max_retries + 1
    assert ch.fault_counts["retries"] == ch.cfg.max_retries


def test_chaos_stall_surfaces_as_phase_named_timeout():
    cm = C.cmesh_brick(2, (2, 1))
    ch = ChaosComm(F.SimComm(4), stall_after=2, phases=("balance",))
    ch.set_deadline(0.3)
    fs = _adapted(ch, cm)
    with pytest.raises(CommTimeoutError) as ei:
        F.balance(fs, ch)
    e = ei.value
    assert e.phase == "balance"
    assert e.seq > 2  # the stalled collective, past the stall_after budget
    assert e.elapsed_s > 0
    assert e.retries > 0  # the backoff loop actually polled
    assert "balance" in str(e) and "timed out" in str(e)
    assert ch.fault_counts["stall"] >= 1


def test_chaos_crash_at_collective():
    cm = C.cmesh_brick(2, (2, 1))
    ch = ChaosComm(F.SimComm(4), crash_at=3, crash_ranks=(3,),
                   phases=("balance",))
    fs = _adapted(ch, cm)  # partition/adapt phases are not eligible
    with pytest.raises(InjectedCrash) as ei:
        F.balance(fs, ch)
    assert ei.value.phase == "balance"
    assert ei.value.seq == 3
    assert ei.value.rank == 3
    assert ch.fault_counts["crash"] == 1


def test_balance_nonconvergence_diagnostics():
    cm = C.cmesh_brick(2, (2, 1))
    comm = F.SimComm(4)
    # a deeper corner (level-2 -> level-5 gap) needs 3 ripple rounds, so a
    # 1-round budget must fail with the diagnostic payload
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: _corner(t, e, cap=5), recursive=True)
          for f in fs]
    with pytest.raises(F.BalanceNonConvergence) as ei:
        F.balance(fs, comm, max_rounds=1)
    e = ei.value
    assert e.rounds == 1
    assert len(e.dirty_per_rank) == 4
    assert sum(e.dirty_per_rank) > 0
    assert "did not converge after 1 rounds" in str(e)
    assert str(e.dirty_per_rank) in str(e)  # per-rank counts in the message


def test_crash_autosave_recover_matches_fresh_small_world(tmp_path):
    """The in-process twin of the subprocess kill-one-rank acceptance run:
    crash rank 3 mid-balance at P=4, recover the Autosaver checkpoint on a
    fresh P=3 world, finish the balance — the result must equal a from-
    scratch P=3 run element for element (the global SFC sequence is
    partition-independent, so worlds are compared globally)."""
    cm = C.cmesh_brick(2, (2, 1))
    ckpt = tmp_path / "autosave"

    ch = ChaosComm(F.SimComm(4), crash_at=3, crash_ranks=(3,),
                   phases=("balance",))
    saver = Autosaver(ckpt).install()
    try:
        fs = _adapted(ch, cm)
        with pytest.raises(InjectedCrash):
            F.balance(fs, ch)
    finally:
        saver.uninstall()
    assert saver.saved_steps == [0]  # balance:begin snapshot landed pre-crash

    c3 = F.SimComm(3)
    rec = recover(ckpt, c3, cmesh=cm)  # elastic: 4-rank save -> 3-rank world
    assert len(rec) == 3
    done = F.balance(rec, c3)

    c3f = F.SimComm(3)
    fresh = F.balance(_adapted(c3f, cm), c3f)
    _assert_world_equal(_world(done), _world(fresh))
    assert len(_world(done)["level"]) == len(_world(fresh)["level"])


def test_autosaver_every_and_events(tmp_path):
    cm = C.cmesh_brick(2, (2, 1))
    comm = F.SimComm(2)
    saver = Autosaver(tmp_path / "ck", every=2).install()
    try:
        fs = _adapted(comm, cm)
        fs = F.balance(fs, comm)     # count 1 -> saves step 0
        fs = F.balance(fs, comm)     # count 2 -> skipped (every=2)
        fs = F.balance(fs, comm)     # count 3 -> saves step 1
    finally:
        saver.uninstall()
    assert saver.saved_steps == [0, 1]
    assert not F.RESILIENCE_HOOKS  # uninstall really removed it


def test_corrupted_checkpoint_blob_is_rejected(tmp_path):
    cm = C.cmesh_brick(2, (2, 1))
    comm = F.SimComm(2)
    fs = F.balance(_adapted(comm, cm), comm)
    save_forest(tmp_path / "ck", fs, comm, step=0)

    blobs = sorted((tmp_path / "ck" / "step_0").glob("arr_*.npy"),
                   key=lambda p: p.stat().st_size)
    victim = blobs[-1]  # the largest column: certainly real payload bytes
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF  # flip a data byte (the .npy header is at the front)
    victim.write_bytes(bytes(raw))

    with pytest.raises(CheckpointIntegrityError, match="integrity|unreadable"):
        recover(tmp_path / "ck", F.SimComm(2), cmesh=cm)
    # verify=False skips the CRC pass — the corruption then has to get
    # past validate(), which is off too; this knob exists for forensics
    # only, so just prove it is reachable without the typed error
    try:
        recover(tmp_path / "ck", F.SimComm(2), cmesh=cm, verify=False)
    except CheckpointIntegrityError:  # pragma: no cover - depends on byte hit
        pytest.fail("verify=False must not run integrity checks")
    except Exception:
        pass  # a decode crash without verification is acceptable here
