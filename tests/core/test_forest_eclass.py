"""Element-class polymorphism at the forest layer.

Pure-hex forests run the complete New/Adapt/Partition/Balance/Ghost
pipeline against the generalized oracles on every backend, the mixed-class
fixture (hex brick next to a Kuhn tet cube, `cmesh_hybrid_pair`) runs it at
P=2 with per-class oracle parity, and the fused-sweep dispatch meters prove
the per-class drivers cost exactly one dispatch per class per eval layer —
no extra sweeps from mixing classes in one mesh.
"""

import numpy as np
import pytest

from repro.checkpoint import load_forest, save_forest
from repro.core import batch
from repro.core import cmesh as C
from repro.core import forest as F
from repro.core import get_ops
from repro.core.errors import CheckpointIntegrityError
from repro.core.types import ECLASS_HEX, ECLASS_SIMPLEX

BACKENDS = ["reference", "jnp", pytest.param("pallas", marks=pytest.mark.slow)]


def corner_cb(tree, elems, cap=99):
    a = np.asarray(elems.anchor)
    l = np.asarray(elems.level)
    return ((a.sum(axis=1) == 0) & (l < cap)).astype(np.int32)


def _assert_forests_equal(fa, fb):
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.tree, b.tree)
        np.testing.assert_array_equal(a.level, b.level)
        np.testing.assert_array_equal(a.anchor, b.anchor)
        np.testing.assert_array_equal(a.stype, b.stype)


def _assert_ghosts_equal(ga, gb):
    assert len(ga) == len(gb)
    for a, b in zip(ga, gb):
        for k in ("anchor", "level", "stype", "tree", "owner"):
            np.testing.assert_array_equal(a[k], b[k])


# --------------------------------------------------------- pure-hex pipeline
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("d", [2, 3])
def test_hex_pipeline_vs_oracles(d, backend):
    """Acceptance: a pure-hex forest (multi-tree brick) completes the whole
    pipeline on each backend, and the message-based balance/ghost match the
    generalized global-table oracles element for element."""
    shape = (2, 1) if d == 2 else (2, 1, 1)
    level = 1 if backend == "pallas" else 2 if d == 2 else 1
    cm = C.cmesh_hex_brick(d, shape)
    comm = F.SimComm(2)
    with batch.use_backend(backend):
        fs = F.new_uniform(d, cm.num_trees, level, comm, cmesh=cm)
        assert F.count_global(fs) == cm.num_trees * get_ops(d, ECLASS_HEX).num_elements(level)
        fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=level + 2),
                      recursive=True) for f in fs]
        fs = F.partition(fs, comm)
        bal = F.balance(fs, comm)
        assert F.validate(bal)
        _assert_forests_equal(bal, F.balance_oracle(fs, comm))
        gh = F.ghost(bal, comm)
        assert F.validate(bal, gh)
        _assert_ghosts_equal(gh, F.ghost_oracle(bal, comm))


@pytest.mark.parametrize("d", [2, 3])
def test_hex_pipeline_bit_identical_across_backends(d):
    """reference and jnp produce byte-equal pure-hex forests and ghost
    layers (pallas covered by the slow rows above)."""
    cm = C.cmesh_hex_brick(d, (2,) + (1,) * (d - 1))
    comm = F.SimComm(2)
    outs = {}
    for be in ("reference", "jnp"):
        with batch.use_backend(be):
            fs = F.new_uniform(d, cm.num_trees, 1, comm, cmesh=cm)
            fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=3),
                          recursive=True) for f in fs]
            fs = F.balance(fs, comm)
            gh = F.ghost(fs, comm)
        outs[be] = (fs, gh)
    _assert_forests_equal(outs["reference"][0], outs["jnp"][0])
    _assert_ghosts_equal(outs["reference"][1], outs["jnp"][1])


def test_hex_periodic_brick_iterate_pair_count():
    """Fully periodic 2D hex brick at uniform level 2: every face pairs, so
    iterate sees exactly nf*n/2 = 2*n face pairs."""
    cm = C.cmesh_hex_brick(2, (2, 2), periodic=(True, True))
    comm = F.SimComm(1)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    n = fs[0].num_local
    seen = {}
    F.iterate(fs[0], face_fn=lambda f, pairs: seen.setdefault("pairs", pairs))
    assert len(seen["pairs"]) == 2 * n


# ------------------------------------------------------- mixed-class fixture
@pytest.mark.parametrize("d", [2, 3])
def test_mixed_class_pipeline_p2(d):
    """Acceptance: the hybrid fixture (hex cube next to a Kuhn tet cube)
    runs the full pipeline at P=2; balance and ghost match their oracles
    per class, and the merged forest validates with its ghost layer."""
    cm = C.cmesh_hybrid_pair(d)
    comm = F.SimComm(2)
    level = 2 if d == 2 else 1
    fs = F.new_uniform(d, cm.num_trees, level, comm, cmesh=cm)
    o = get_ops(d)
    assert F.count_global(fs) == cm.num_trees * o.num_elements(level)
    assert F.validate(fs)

    fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=level + 2),
                  recursive=True) for f in fs]
    fs = F.partition(fs, comm)
    assert F.validate(fs)
    # both classes actually refined: the hex tree and some simplex tree
    # carry elements above the base level
    lv_by_ec = {ec: [] for ec in (ECLASS_HEX, ECLASS_SIMPLEX)}
    for f in fs:
        te = cm.tree_eclass[f.tree]
        for ec in lv_by_ec:
            lv_by_ec[ec].extend(np.asarray(f.level)[te == ec].tolist())
    assert max(lv_by_ec[ECLASS_HEX]) > level
    assert max(lv_by_ec[ECLASS_SIMPLEX]) > level

    bal = F.balance(fs, comm)
    assert F.validate(bal)
    _assert_forests_equal(bal, F.balance_oracle(fs, comm))
    gh = F.ghost(bal, comm)
    assert F.validate(bal, gh)
    _assert_ghosts_equal(gh, F.ghost_oracle(bal, comm))

    # iterate: elem_fn sees every local element once; face pairs exist and
    # never straddle the cross-class tree face (a domain boundary)
    for f in bal:
        seen = {}
        F.iterate(f, elem_fn=lambda t, e: seen.setdefault("n", len(np.asarray(t))),
                  face_fn=lambda ff, pairs: seen.setdefault("pairs", pairs))
        assert seen["n"] == f.num_local
        te = cm.tree_eclass[f.tree]
        for i, j, _, _ in seen.get("pairs", np.zeros((0, 4), np.int64)):
            assert te[int(i)] == te[int(j)], "face pair straddles classes"


def test_mixed_class_repartition_roundtrip():
    """Weighted repartition of the mixed fixture migrates class-tagged wire
    triples and reassembles both classes bit for bit."""
    cm = C.cmesh_hybrid_pair(2)
    comm = F.SimComm(3)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=4), recursive=True)
          for f in fs]
    before = {(int(t), int(k)) for f in fs
              for t, k in zip(f.tree.tolist(), f.keys.tolist())}
    # skew weights so elements actually move
    ws = [np.linspace(1, 5, f.num_local) for f in fs]
    out = F.repartition(fs, comm, weights=ws)
    assert F.validate(out)
    after = {(int(t), int(k)) for f in out
             for t, k in zip(f.tree.tolist(), f.keys.tolist())}
    assert before == after


# ------------------------------------------------- dispatch-count accounting
def test_mixed_class_dispatch_is_per_class_sum():
    """The per-class drivers cost exactly one fused face_sweep/eval_route
    dispatch per class per eval layer: running balance/ghost on the mixed
    mesh meters the same dispatch counts as running each class group's
    sub-forest through the single-class impl directly."""
    cm = C.cmesh_hybrid_pair(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=4), recursive=True)
          for f in fs]

    KEYS = ("face_sweep", "eval_route")

    def meter(fn):
        batch.reset_dispatch_counts()
        fn()
        c = batch.dispatch_counts()
        return {k: c.get(k, 0) for k in KEYS}

    mixed_bal = meter(lambda: F.balance(fs, comm))
    mixed_gh = meter(lambda: F.ghost(F.balance(fs, comm), comm))

    per_class_bal = {k: 0 for k in KEYS}
    per_class_gh = {k: 0 for k in KEYS}
    for ec in cm.eclasses:
        sub = F._class_subforests(fs, ec)
        c = meter(lambda: F._balance_impl(sub, comm, eclass=ec))
        for k in KEYS:
            per_class_bal[k] += c[k]
        bal_sub = F._balance_impl(sub, comm, eclass=ec)
        c = meter(lambda: F._ghost_impl(bal_sub, comm, True, ec))
        for k in KEYS:
            per_class_gh[k] += c[k]

    assert mixed_bal == per_class_bal
    # the mixed ghost run meters balance + ghost; subtract the balance part
    gh_only = {k: mixed_gh[k] - mixed_bal[k] for k in KEYS}
    assert gh_only == per_class_gh
    assert per_class_gh["face_sweep"] > 0


# ----------------------------------------------------- checkpoint round-trip
def test_hex_checkpoint_roundtrip_elastic(tmp_path):
    """Pure-hex checkpoints (4d+1 B at-rest rows, no stype column) restore
    bit for bit at the same P and re-split cleanly at a different P."""
    cm = C.cmesh_hex_brick(2, (2, 1))
    comm = F.SimComm(2)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=4), recursive=True)
          for f in fs]
    fs = F.balance(fs, comm)
    save_forest(tmp_path, fs, comm, step=0)

    same = load_forest(tmp_path, F.SimComm(2), cmesh=cm)
    _assert_forests_equal(same, fs)
    elastic = load_forest(tmp_path, F.SimComm(3), cmesh=cm)
    assert F.validate(elastic)
    assert F.count_global(elastic) == F.count_global(fs)

    # a non-simplex checkpoint cannot decode without its cmesh
    with pytest.raises(CheckpointIntegrityError):
        load_forest(tmp_path, F.SimComm(2))


def test_mixed_checkpoint_roundtrip(tmp_path):
    cm = C.cmesh_hybrid_pair(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, lambda t, e: corner_cb(t, e, cap=4), recursive=True)
          for f in fs]
    fs = F.balance(fs, comm)
    save_forest(tmp_path, fs, comm, step=3)

    same = load_forest(tmp_path, F.SimComm(2), cmesh=cm)
    _assert_forests_equal(same, fs)
    elastic = load_forest(tmp_path, F.SimComm(4), cmesh=cm)
    assert F.validate(elastic)
    assert F.count_global(elastic) == F.count_global(fs)
    with pytest.raises(CheckpointIntegrityError):
        load_forest(tmp_path, F.SimComm(2))
