"""Hex (plain-Morton) element class: reference invariants, backend parity,
and empty batches.

The hex class is the second element class behind the `(d, eclass)` ops
seam: `HexOps` is the eager oracle, and the jnp/pallas backends (pallas in
interpret mode on CPU) must reproduce its integers bit for bit over random
batches at d=2 and d=3 — the same differential contract the simplex class
pins in test_batch_backends.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import rand_simplices
from repro.core import batch, get_ops
from repro.core import u64 as u64m
from repro.core.types import ECLASS_HEX, Simplex

BACKENDS = ["jnp", pytest.param("pallas", marks=pytest.mark.slow)]

N = 64


def assert_simplex_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.anchor), np.asarray(b.anchor))
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.stype), np.asarray(b.stype))


@pytest.fixture(params=[2, 3])
def d(request):
    return request.param


def hexes(d, n=N, seed=0, **kw):
    kw.setdefault("min_level", 1)
    return rand_simplices(d, n, seed=seed, eclass=ECLASS_HEX, **kw)


# ------------------------------------------------------ reference invariants
def test_hex_ops_shape_constants(d):
    o = get_ops(d, ECLASS_HEX)
    assert o.eclass == ECLASS_HEX
    assert o.nt == 1 and o.nc == 2 ** d and o.nf == 2 * d
    assert o.num_corners == 2 ** d
    assert np.asarray(o.face_corner_indices).shape == (2 * d, 2 ** (d - 1))
    # same MAXLEVEL and element counts as the simplex curve: the SFC
    # interval arithmetic (spans, markers, repartition) is class-generic
    os_ = get_ops(d)
    assert o.L == os_.L
    assert o.num_elements(3) == os_.num_elements(3)


def test_hex_parent_child_roundtrip(d):
    o = get_ops(d, ECLASS_HEX)
    s = hexes(d, seed=d, max_level=o.L - 1)
    kids = o.children_tm(s)
    for j in range(o.nc):
        kid = Simplex(kids.anchor[:, j], kids.level[:, j], kids.stype[:, j])
        par = o.parent(kid)
        np.testing.assert_array_equal(np.asarray(par.anchor), np.asarray(s.anchor))
        np.testing.assert_array_equal(
            np.asarray(o.local_index(kid)), np.full(N, j))


def test_hex_morton_key_roundtrip(d):
    o = get_ops(d, ECLASS_HEX)
    s = hexes(d, seed=d + 10, min_level=0)
    key = o.morton_key(s)
    back = o.decode_key(key, s.level)
    assert_simplex_equal(back, s)
    assert not np.asarray(s.stype).any()


def test_hex_face_neighbor_involution(d):
    """neighbor(neighbor) is the identity, and dual = face ^ 1."""
    o = get_ops(d, ECLASS_HEX)
    s = hexes(d, seed=d + 20)
    for f in range(o.nf):
        nb, dual = o.face_neighbor(s, f)
        np.testing.assert_array_equal(np.asarray(dual), np.full(N, f ^ 1))
        back, dual2 = o.face_neighbor(nb, f ^ 1)
        assert_simplex_equal(back, s)
        np.testing.assert_array_equal(np.asarray(dual2), np.full(N, f))


# ---------------------------------------------------------- backend parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_hex_morton_key_decode_parity(d, backend):
    s = hexes(d, seed=1, min_level=0)
    ref = batch.get_batch_ops(d, "reference", eclass=ECLASS_HEX)
    got = batch.get_batch_ops(d, backend, eclass=ECLASS_HEX)
    np.testing.assert_array_equal(got.morton_key_np(s), ref.morton_key_np(s))
    key = u64m.from_int(ref.morton_key_np(s))
    assert_simplex_equal(got.decode(key, s.level), ref.decode(key, s.level))


@pytest.mark.parametrize("backend", BACKENDS)
def test_hex_parent_children_successor_parity(d, backend):
    o = get_ops(d, ECLASS_HEX)
    s = hexes(d, seed=2, margin=2, max_level=o.L - 1)
    ref = batch.get_batch_ops(d, "reference", eclass=ECLASS_HEX)
    got = batch.get_batch_ops(d, backend, eclass=ECLASS_HEX)
    par_r, il_r = ref.parent_and_local_index(s)
    par_g, il_g = got.parent_and_local_index(s)
    assert_simplex_equal(par_g, par_r)
    np.testing.assert_array_equal(np.asarray(il_g), np.asarray(il_r))
    assert_simplex_equal(got.children(s), ref.children(s))
    assert_simplex_equal(got.successor(s), ref.successor(s))
    np.testing.assert_array_equal(
        np.asarray(got.is_inside_root(s)), np.asarray(ref.is_inside_root(s)))


@pytest.mark.parametrize("backend", BACKENDS)
def test_hex_face_sweep_parity(d, backend):
    """The fused all-faces sweep carries 2d face rows for hexes and must be
    bit-identical across backends (pallas runs the interpret-mode kernels)."""
    s = hexes(d, seed=3, min_level=0)
    ref = batch.get_batch_ops(d, "reference", eclass=ECLASS_HEX)
    got = batch.get_batch_ops(d, backend, eclass=ECLASS_HEX)
    assert ref.nf == got.nf == 2 * d
    sw_r, sw_g = ref.face_sweep(s), got.face_sweep(s)
    assert sw_g.neighbor.anchor.shape == (2 * d, N, d)
    assert_simplex_equal(sw_g.neighbor, sw_r.neighbor)
    np.testing.assert_array_equal(np.asarray(sw_g.dual), np.asarray(sw_r.dual))
    np.testing.assert_array_equal(
        np.asarray(sw_g.inside), np.asarray(sw_r.inside))
    np.testing.assert_array_equal(u64m.to_np(sw_g.key), u64m.to_np(sw_r.key))


@pytest.mark.parametrize("backend", BACKENDS)
def test_hex_tree_transform_parity(d, backend):
    # a signed-permutation embedding (reflect axis 0, swap with axis 1)
    M = np.eye(d, dtype=np.int64)
    M[0, 0] = 0
    M[0, 1] = -1
    M[1, 1] = 0
    M[1, 0] = 1
    c = np.array([1 << get_ops(d).L] + [0] * (d - 1), np.int64)
    tmap = np.zeros(1, np.int64)  # hex typemap: the single type maps to 0
    s = hexes(d, seed=4)
    ref = batch.get_batch_ops(d, "reference", eclass=ECLASS_HEX)
    got = batch.get_batch_ops(d, backend, eclass=ECLASS_HEX)
    assert_simplex_equal(
        got.tree_transform(s, M, c, tmap), ref.tree_transform(s, M, c, tmap))


# ------------------------------------------------------------- empty batches
@pytest.mark.parametrize("backend", ["reference"] + BACKENDS)
def test_hex_empty_batch_all_ops(d, backend):
    o = get_ops(d, ECLASS_HEX)
    s = o.from_linear_id(u64m.from_int(np.zeros(0, np.uint64)),
                         jnp.zeros(0, jnp.int32))
    b = batch.get_batch_ops(d, backend, eclass=ECLASS_HEX)
    assert b.morton_key_np(s).shape == (0,)
    assert b.parent(s).level.shape == (0,)
    assert b.children(s).level.shape == (0, o.nc)
    assert b.successor(s).level.shape == (0,)
    assert np.asarray(b.is_inside_root(s)).shape == (0,)
    nb, dual = b.face_neighbor(s, 0)
    assert nb.level.shape == (0,)
    sw = b.face_sweep(s)
    assert sw.neighbor.anchor.shape == (2 * d, 0, d)
    assert sw.key.hi.shape == (2 * d, 0)
    assert b.tree_transform(
        s, np.eye(d, dtype=np.int64), np.zeros(d, np.int64), np.arange(o.nt)
    ).level.shape == (0,)
    assert b.owner_rank(
        np.zeros(0, np.int32), np.zeros(0, np.uint64),
        np.zeros(1, np.int32), np.zeros(1, np.uint64),
    ).shape == (0,)
