"""Pallas flash attention vs the plain-attention oracle (interpret mode)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import _plain_attention

# interpret-mode attention sweeps: minutes on one CPU core
pytestmark = pytest.mark.slow


CASES = [
    # (B, S, H, KV, hd, dtype, window)
    (1, 256, 2, 2, 32, jnp.float32, None),
    (2, 256, 4, 2, 64, jnp.float32, None),
    (1, 512, 4, 1, 32, jnp.float32, None),     # MQA
    (2, 256, 4, 4, 32, jnp.bfloat16, None),
    (1, 512, 2, 2, 32, jnp.float32, 100),      # sliding window
]


@pytest.mark.parametrize("B,S,H,KV,hd,dtype,window", CASES)
def test_flash_matches_plain(B, S, H, KV, hd, dtype, window):
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    want = _plain_attention(q, k, v, causal=True, window=window, q_offset=0,
                            scale=1 / math.sqrt(hd))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_block_sizes():
    B, S, H, KV, hd = 1, 512, 2, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    ref = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    for bq, bk in ((256, 128), (128, 256), (512, 512)):
        out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
