"""Pallas SFC kernels vs pure-jnp oracles: shape/level sweeps, exact equality."""

import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import rand_simplices
from repro.core import u64 as u64m
from repro.core.ops import get_ops
from repro.kernels import ops as kops
from repro.kernels import ref as kref


SHAPES = [7, 250]  # small: interpret-mode compiles are expensive on 1 CPU core


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", SHAPES)
def test_morton_key_kernel(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n, max_level=o.L)
    hi, lo = kops.morton_key(d, s)
    # oracle needs the padded key of the element itself
    want = o.morton_key(s)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", SHAPES)
def test_decode_kernel_roundtrip(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n + 1, max_level=o.L)
    key = o.morton_key(s)
    out = kops.decode(d, key, s.level)
    np.testing.assert_array_equal(np.asarray(out.anchor), np.asarray(s.anchor))
    np.testing.assert_array_equal(np.asarray(out.stype), np.asarray(s.stype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_face_neighbor_kernel(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n + 2, max_level=o.L)
    for f in range(d + 1):
        nb, dual = kops.face_neighbor(d, s, f)
        want_nb, want_dual = o.face_neighbor(s, jnp.int32(f))
        np.testing.assert_array_equal(np.asarray(nb.anchor), np.asarray(want_nb.anchor))
        np.testing.assert_array_equal(np.asarray(nb.stype), np.asarray(want_nb.stype))
        np.testing.assert_array_equal(np.asarray(dual), np.asarray(want_dual))


@pytest.mark.slow
@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_successor_kernel(d, n):
    o = get_ops(d)
    rng = np.random.default_rng(n + 3)
    lv = rng.integers(1, 7, size=n)
    ids = np.array([rng.integers(0, o.num_elements(l) - 1) for l in lv], np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.asarray(lv, jnp.int32))
    out = kops.successor(d, s)
    want = o.successor(s)
    np.testing.assert_array_equal(np.asarray(out.anchor), np.asarray(want.anchor))
    np.testing.assert_array_equal(np.asarray(out.stype), np.asarray(want.stype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_parent_kernel(d, n):
    o = get_ops(d)
    rng = np.random.default_rng(n + 4)
    lv = rng.integers(1, o.L + 1, size=n)
    ids = np.array([rng.integers(0, min(o.num_elements(l), 2**62)) for l in lv], np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.asarray(lv, jnp.int32))
    p = kops.parent(d, s)
    want = o.parent(s)
    np.testing.assert_array_equal(np.asarray(p.anchor), np.asarray(want.anchor))
    np.testing.assert_array_equal(np.asarray(p.level), np.asarray(want.level))
    np.testing.assert_array_equal(np.asarray(p.stype), np.asarray(want.stype))
    iloc = kops.local_index(d, s)
    np.testing.assert_array_equal(np.asarray(iloc), np.asarray(o.local_index(s)))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_children_kernel(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n + 5, max_level=o.L - 1)
    kids = kops.children(d, s)
    want = o.children_tm(s)
    np.testing.assert_array_equal(np.asarray(kids.anchor), np.asarray(want.anchor))
    np.testing.assert_array_equal(np.asarray(kids.level), np.asarray(want.level))
    np.testing.assert_array_equal(np.asarray(kids.stype), np.asarray(want.stype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_inside_root_kernel(d, n):
    """Face neighbors step outside the root: the interesting inputs."""
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n + 6, max_level=o.L)
    got = kops.is_inside_root(d, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(o.is_inside_root(s)))
    for f in range(d + 1):
        nb, _ = o.face_neighbor(s, jnp.int32(f))
        got = kops.is_inside_root(d, nb)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(o.is_inside_root(nb)))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_face_sweep_kernel(d, n):
    """The fused all-faces kernel equals its composed oracle on every output
    tile (neighbor coords/type, dual, inside mask, morton-key words)."""
    o = get_ops(d)
    s = rand_simplices(d, n, seed=n + 7, max_level=o.L)
    fields = [s.anchor[..., k] for k in range(d)]
    want = kref.face_sweep_ref(d, *fields, s.level, s.stype)
    nb, dual, inside, key = kops.face_sweep(d, s)
    got = (
        *[nb.anchor[..., k].T for k in range(d)], nb.stype.T, dual.T,
        inside.astype(jnp.int32).T, key.hi.T, key.lo.T,
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", SHAPES)
def test_owner_rank_kernel(d, n):
    from repro.core.batch import _pad_markers

    o = get_ops(d)
    rng = np.random.default_rng(n + 9)
    P = 5
    mt = np.sort(rng.integers(0, 3, P)).astype(np.int32)
    mk = rng.integers(0, 1 << (d * o.L), P).astype(np.uint64)
    order = np.lexsort((mk, mt))
    mt_p, mk_p = _pad_markers(mt[order], mk[order])
    mkey = u64m.from_int(mk_p)
    t = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    key = u64m.from_int(rng.integers(0, 1 << (d * o.L), n).astype(np.uint64))
    got = kops.owner_rank(key, t, (jnp.asarray(mt_p), mkey))
    want = kref.owner_rank_ref(
        np.asarray(t), np.asarray(key.hi), np.asarray(key.lo),
        mt_p, np.asarray(mkey.hi), np.asarray(mkey.lo))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
@pytest.mark.parametrize("d", [2, 3])
def test_kernel_block_sizes(d):
    o = get_ops(d)
    s = rand_simplices(d, 100, seed=99, max_level=o.L)
    for block in (64, 256):
        hi, lo = kops.morton_key(d, s, block)
        want = o.morton_key(s)
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))


@pytest.mark.parametrize("d", [2, 3])
def test_ref_module_consistency(d):
    """kernels.ref (the documented oracle) equals core.ops on raw arrays."""
    o = get_ops(d)
    s = rand_simplices(d, 256, seed=5, max_level=o.L)
    fields = [s.anchor[..., k] for k in range(d)]
    hi, lo = kref.morton_key_ref(d, *fields, s.stype)
    want = o.morton_key(s)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))
    outs = kref.decode_ref(d, hi, lo, s.level)
    np.testing.assert_array_equal(np.asarray(outs[d]), np.asarray(s.stype))
    raw = (*fields, s.level, s.stype)
    pouts = kref.parent_ref(d, *raw)
    want_p = o.parent(s)
    np.testing.assert_array_equal(np.asarray(pouts[d]), np.asarray(want_p.stype))
    np.testing.assert_array_equal(np.asarray(pouts[d + 1]), np.asarray(o.local_index(s)))
    couts = kref.children_ref(d, *raw)
    np.testing.assert_array_equal(np.asarray(couts[d]), np.asarray(o.children_tm(s).stype))
    np.testing.assert_array_equal(
        np.asarray(kref.is_inside_root_ref(d, *raw)), np.asarray(o.is_inside_root(s))
    )
    souts = kref.face_sweep_ref(d, *raw)
    for f in range(d + 1):
        nb, dual = o.face_neighbor(s, jnp.int32(f))
        np.testing.assert_array_equal(np.asarray(souts[d][..., f]), np.asarray(nb.stype))
        np.testing.assert_array_equal(np.asarray(souts[d + 1][..., f]), np.asarray(dual))
        np.testing.assert_array_equal(
            np.asarray(souts[d + 2][..., f]).astype(bool),
            np.asarray(o.is_inside_root(nb)))
        want_k = o.morton_key(nb)
        np.testing.assert_array_equal(np.asarray(souts[d + 3][..., f]), np.asarray(want_k.hi))
        np.testing.assert_array_equal(np.asarray(souts[d + 4][..., f]), np.asarray(want_k.lo))
