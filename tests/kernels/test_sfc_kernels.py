"""Pallas SFC kernels vs pure-jnp oracles: shape/level sweeps, exact equality."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import u64 as u64m
from repro.core.ops import get_ops
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def rand_simplices(d, n, max_level, seed):
    o = get_ops(d)
    rng = np.random.default_rng(seed)
    lv = rng.integers(1, max_level + 1, size=n)
    ids = np.array([rng.integers(0, min(o.num_elements(l), 2**62)) for l in lv], np.uint64)
    return o.from_linear_id(u64m.from_int(ids), jnp.asarray(lv, jnp.int32))


SHAPES = [7, 250]  # small: interpret-mode compiles are expensive on 1 CPU core


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", SHAPES)
def test_morton_key_kernel(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, o.L, seed=n)
    hi, lo = kops.morton_key(d, s)
    # oracle needs the padded key of the element itself
    want = o.morton_key(s)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", SHAPES)
def test_decode_kernel_roundtrip(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, o.L, seed=n + 1)
    key = o.morton_key(s)
    out = kops.decode(d, key, s.level)
    np.testing.assert_array_equal(np.asarray(out.anchor), np.asarray(s.anchor))
    np.testing.assert_array_equal(np.asarray(out.stype), np.asarray(s.stype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_face_neighbor_kernel(d, n):
    o = get_ops(d)
    s = rand_simplices(d, n, o.L, seed=n + 2)
    for f in range(d + 1):
        nb, dual = kops.face_neighbor(d, s, f)
        want_nb, want_dual = o.face_neighbor(s, jnp.int32(f))
        np.testing.assert_array_equal(np.asarray(nb.anchor), np.asarray(want_nb.anchor))
        np.testing.assert_array_equal(np.asarray(nb.stype), np.asarray(want_nb.stype))
        np.testing.assert_array_equal(np.asarray(dual), np.asarray(want_dual))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [130])
def test_successor_kernel(d, n):
    o = get_ops(d)
    rng = np.random.default_rng(n + 3)
    lv = rng.integers(1, 7, size=n)
    ids = np.array([rng.integers(0, o.num_elements(l) - 1) for l in lv], np.uint64)
    s = o.from_linear_id(u64m.from_int(ids), jnp.asarray(lv, jnp.int32))
    out = kops.successor(d, s)
    want = o.successor(s)
    np.testing.assert_array_equal(np.asarray(out.anchor), np.asarray(want.anchor))
    np.testing.assert_array_equal(np.asarray(out.stype), np.asarray(want.stype))


@pytest.mark.parametrize("d", [2, 3])
def test_kernel_block_sizes(d):
    o = get_ops(d)
    s = rand_simplices(d, 100, o.L, seed=99)
    for block in (64, 256):
        hi, lo = kops.morton_key(d, s, block)
        want = o.morton_key(s)
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))


@pytest.mark.parametrize("d", [2, 3])
def test_ref_module_consistency(d):
    """kernels.ref (the documented oracle) equals core.ops on raw arrays."""
    o = get_ops(d)
    s = rand_simplices(d, 256, o.L, seed=5)
    fields = [s.anchor[..., k] for k in range(d)]
    hi, lo = kref.morton_key_ref(d, *fields, s.stype)
    want = o.morton_key(s)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))
    outs = kref.decode_ref(d, hi, lo, s.level)
    np.testing.assert_array_equal(np.asarray(outs[d]), np.asarray(s.stype))
