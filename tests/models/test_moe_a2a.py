"""shard_map all-to-all MoE dispatch == GSPMD scatter dispatch (8 devices)."""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[2]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import moe_layer
from repro.models import moe_a2a
from repro.models.lm import _moe_init if False else None
from repro.models import lm as lm_mod

cfg = ModelConfig(
    name="t", family="moe", num_layers=1, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, num_shared=1,
                  capacity_factor=4.0),  # E/k: lossless
)
key = jax.random.PRNGKey(0)
from repro.models.lm import _moe_init
p = _moe_init(cfg, key, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 64))

want, aux_want = moe_layer(cfg, p, x)

mesh = jax.make_mesh((2, 4), ("data", "model"))
moe_a2a.set_moe_impl(mesh=mesh, dp_axes=("data",), model_axis="model")
assert moe_a2a.a2a_available(cfg, 32)
# jax >= 0.6 spells the mesh context jax.set_mesh; older releases use the
# Mesh object itself as the context manager.
_set_mesh = getattr(jax, "set_mesh", None)
with (_set_mesh(mesh) if _set_mesh is not None else mesh):
    got, aux_got = jax.jit(lambda pp, xx: moe_a2a.moe_layer_a2a(cfg, pp, xx))(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
# aux loss is the per-shard estimator (mean over shards of E*sum(me*ce));
# it differs from the single-shard global formula by O(1/shards) variance
np.testing.assert_allclose(float(aux_got), float(aux_want), rtol=0.25)
print("moe a2a OK")
"""


@pytest.mark.slow  # 8-device x64 subprocess: ~8 min on one CPU core
def test_moe_a2a_matches_gspmd():
    script = SCRIPT.replace(
        "from repro.models.lm import _moe_init if False else None\n", "")
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900, env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "moe a2a OK" in r.stdout
