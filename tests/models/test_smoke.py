"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned architecture: one forward/loss evaluation (finite, right
shapes), and a prefill -> decode consistency check: decoding token-by-token
with the per-family cache must reproduce the full-sequence forward logits
(this exercises KV caches, SWA ring buffers, MLA absorbed decode, SSM/RG-LRU
state carry, and whisper cross-attention caches).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import decode_step, forward, init_cache, init_params, loss_fn
from repro.models.lm import unembed
import repro.models.layers as ly


def tiny(arch, dtype="float32"):
    return replace(reduced(get_config(arch)), dtype=dtype)


def make_batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(key, (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_loss_finite(arch):
    cfg = tiny(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key, B=2, S=32)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0
    # gradients exist and are finite on a couple of leaves
    g = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves[:5])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    cfg = tiny(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S, n_dec = 2, 24, 3
    batch = make_batch(cfg, key, B, S)
    hidden, _, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    full_logits = np.asarray(unembed(cfg, params, hidden).astype(jnp.float32))
    P = cfg.num_patches if (cfg.family == "vlm" and "patches" in batch) else 0

    # prefill first S - n_dec tokens, then decode the rest step by step
    Sp = S - n_dec
    cache = init_cache(cfg, B, S + P + 8)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :Sp]
    _, _, cache = jax.jit(lambda p, b, c: forward(cfg, p, b, cache=c, cache_pos=0))(
        params, pre, cache)
    step = jax.jit(lambda p, c, t, k: decode_step(cfg, p, c, t, k))
    for k in range(Sp, S):
        # note: vlm decode positions continue after the patch prefix
        logits, cache = step(params, cache, batch["tokens"][:, k : k + 1],
                             jnp.int32(k + P))
        want = full_logits[:, P + k]
        np.testing.assert_allclose(np.asarray(logits), want, rtol=2e-4, atol=2e-4)


def test_param_count_analytic_close():
    """Analytic param_count tracks the real initialised tree within 10%."""
    for arch in ARCH_NAMES:
        cfg = tiny(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        n_real = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        n_est = cfg.param_count()
        assert abs(n_real - n_est) / n_real < 0.15, (arch, n_real, n_est)


def test_blocked_attention_matches_plain():
    """The block-triangular online-softmax attention is exact."""
    key = jax.random.PRNGKey(2)
    B, S, H, KV, hd = 2, 512, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    import math
    plain = ly._plain_attention(q, k, v, causal=True, window=None, q_offset=0,
                                scale=1 / math.sqrt(hd))
    blocked = ly._blocked_causal_attention(q, k, v, window=None,
                                           scale=1 / math.sqrt(hd), chunk=128)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(plain), rtol=2e-5, atol=2e-5)
    # sliding window variant
    plain_w = ly._plain_attention(q, k, v, causal=True, window=100, q_offset=0,
                                  scale=1 / math.sqrt(hd))
    blocked_w = ly._blocked_causal_attention(q, k, v, window=100,
                                             scale=1 / math.sqrt(hd), chunk=128)
    np.testing.assert_allclose(np.asarray(blocked_w), np.asarray(plain_w), rtol=2e-5, atol=2e-5)


def test_train_step_decreases_loss():
    """A few AdamW steps on a tiny dense model reduce training loss."""
    from repro.optim import adamw_update, apply_updates, init_opt_state
    cfg = tiny("olmo-1b")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key, B=4, S=32)
    state = init_opt_state(params, "adamw")

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch),
                                              has_aux=True)(params)
        updates, state = adamw_update(grads, state, params, lr=3e-3)
        return apply_updates(params, updates), state, loss

    losses = []
    for _ in range(8):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses
