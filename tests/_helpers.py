"""Shared test helpers (tests/ is on sys.path via the root conftest)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import u64 as u64m
from repro.core.ops import get_ops


def rand_simplices(d, n, seed, min_level=1, max_level=None, margin=0, eclass=0):
    """Random valid elements by decoding random consecutive indices.

    `margin` keeps ids away from the end of the level range (so e.g.
    `successor` stays inside the tree).  Ids are clamped to 2^62 to stay
    below the uint64 emulation's comfortable range at d=3, MAXLEVEL.
    With `eclass=1` the ids decode along the plain-Morton hex curve instead
    (same container type; the stype lane is identically 0).
    """
    o = get_ops(d, eclass)
    max_level = o.L if max_level is None else max_level
    rng = np.random.default_rng(seed)
    lv = rng.integers(min_level, max_level + 1, size=n)
    ids = np.array(
        [rng.integers(0, max(1, min(o.num_elements(l), 2**62) - margin)) for l in lv],
        np.uint64,
    )
    return o.from_linear_id(u64m.from_int(ids), jnp.asarray(lv, jnp.int32))
