"""End-to-end system behaviour tests."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_quickstart_example_runs():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "quickstart.py")],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "roundtrip on rank 0: True" in r.stdout


@pytest.mark.slow
def test_amr_fractal_example_counts():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "amr_fractal.py")],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.count("True") >= 3  # measured == analytic at k=1,2,3


@pytest.mark.slow
def test_train_example_tiny_runs_and_restarts(tmp_path):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    args = [sys.executable, str(ROOT / "examples" / "train_lm.py"),
            "--preset", "tiny", "--steps", "6", "--ckpt-every", "3",
            "--ckpt-dir", str(tmp_path / "ck")]
    r = subprocess.run(args, capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout
    # resume past the end: restarts from step 6's checkpoint
    args[args.index("6")] = "8"
    r2 = subprocess.run(args, capture_output=True, text=True, timeout=900, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "steps 6..7" in r2.stdout


def test_dryrun_results_wellformed_if_present():
    d = ROOT / "results" / "dryrun"
    if not d.exists() or not list(d.glob("*.json")):
        pytest.skip("dry-run results not generated on this machine")
    cells = [json.loads(p.read_text()) for p in d.glob("*.json")]
    ok = [c for c in cells if c.get("status") == "ok"]
    assert ok, "no successful dry-run cells"
    for c in ok:
        r = c["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0 and r["collective_s"] >= 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_fraction"] <= 1
        assert c["hlo_cost"]["flops_per_device"] > 0
    # every architecture has at least one ok cell
    archs = {c["arch"] for c in ok}
    assert len(archs) == 10, archs


def test_hlo_cost_model_counts_loops():
    """The loop-aware cost model multiplies while bodies by trip counts."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_cost import analyze

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    hlo = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    res = analyze(hlo, num_partitions=1)
    want = 2 * 64 * 64 * 64 * 7
    assert abs(res["flops"] - want) / want < 0.01, res["flops"]


@pytest.mark.slow
def test_fem_diffusion_example():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "fem_diffusion.py")],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "conservation + decay verified" in r.stdout
