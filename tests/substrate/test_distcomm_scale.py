"""DistComm at P=4: four REAL processes over jax.distributed.

The ROADMAP scale item beyond the 2-process binding proof: each subprocess
initializes `jax.distributed` against a shared coordinator and runs the
message-based pipeline on one rank of a FOUR-rank world, on the weak-scaling
domain the `--suite scale` benchmark uses (a glued 2D Kuhn brick with one
cube column per rank, corner refinement in every tree so each rank does the
same work and the 2:1 ripple crosses every inter-cell face).

Pinned here, per rank:
  * overlapped (double-buffered) balance == serialized balance, bit for bit,
    on separate namespaced DistComm instances sharing one coordinator;
  * equal `wire_digest()` for the two runs — overlap changes scheduling,
    never bytes;
  * nonblocking handle semantics over the real KV transport (post, poll,
    wait);
and on rank 0: the gathered world equals the in-process `SimComm(4)` run of
the same pipeline, element for element.

The second test is the dynamic-repartition acceptance run on the same
world size: a skewed adapt (only the first cube cell refines) followed by
`Forest.repartition` must end with max/mean element imbalance <= 1.1,
overlapped == serialized with wire-digest parity, and the gathered world
element-for-element identical to the single-rank oracle.
"""

import pytest

from repro.launch.multiproc import run_ranks

SCRIPT = r"""
import sys
import numpy as np
import jax

port, pid = sys.argv[1], int(sys.argv[2])
P = 4
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.launch.multiproc import WEAK_BRICK_SETUP

comm = comm_ov = DistComm(timeout_s=240, namespace="ov.")
comm_ser = DistComm(timeout_s=240, namespace="ser.")
comm_h = DistComm(timeout_s=240, namespace="h.")  # keeps comm's digest pure
assert comm.size == P and comm.rank == pid

# nonblocking handles over the real KV transport: post, poll, wait
h = comm_h.iallgather([np.full(2, comm_h.rank, np.int32)])
h.done()  # poll is allowed (and harmless) before peers post
got = h.wait()
assert [int(g[0]) for g in got] == list(range(P))
print(f"rank {pid}: handles OK", flush=True)

level = 2
exec(WEAK_BRICK_SETUP)  # the benchmark's weak-scaling domain: corner, cm, fs0
assert len(fs0) == 1 and fs0[0].rank == pid

fs = F.balance([f for f in fs0], comm, overlap=True)
fs_ser = F.balance([f for f in fs0], comm_ser, overlap=False)
np.testing.assert_array_equal(fs[0].keys, fs_ser[0].keys)
np.testing.assert_array_equal(fs[0].level, fs_ser[0].level)
np.testing.assert_array_equal(fs[0].tree, fs_ser[0].tree)
assert comm.wire_digest() == comm_ser.wire_digest(), \
    "overlap changed the wire bytes"
print(f"rank {pid}: overlap == serialized", flush=True)

gh = F.ghost(fs, comm)
n_global = F.count_global(fs, comm)
fs = F.partition(fs, comm)
assert F.count_global(fs, comm) == n_global

blob = (fs[0].anchor, fs[0].level, fs[0].stype, fs[0].tree,
        gh[0]["anchor"], gh[0]["level"], gh[0]["tree"], gh[0]["owner"])
world = comm.allgather([blob])
if pid == 0:
    sim = F.SimComm(P)
    sfs = F.new_uniform(2, cm.num_trees, level, sim, cmesh=cm)
    sfs = [F.adapt(f, corner, recursive=True) for f in sfs]
    sfs = F.balance(sfs, sim)
    sgh = F.ghost(sfs, sim)
    sfs = F.partition(sfs, sim)
    assert F.count_global(sfs) == n_global
    for p in range(P):
        a, l, b, t, ga, gl, gt, go = world[p]
        np.testing.assert_array_equal(a, sfs[p].anchor)
        np.testing.assert_array_equal(l, sfs[p].level)
        np.testing.assert_array_equal(t, sfs[p].tree)
        np.testing.assert_array_equal(ga, sgh[p]["anchor"])
        np.testing.assert_array_equal(gl, sgh[p]["level"])
        np.testing.assert_array_equal(go, sgh[p]["owner"])
    print("rank 0: DistComm(P=4) == SimComm(4)", flush=True)
comm.barrier()
print(f"rank {pid}: pipeline OK", flush=True)
"""


@pytest.mark.slow
def test_distcomm_four_process_pipeline():
    outs = run_ranks(SCRIPT, 4)
    for pid, (out, _err) in enumerate(outs):
        assert f"rank {pid}: handles OK" in out
        assert f"rank {pid}: overlap == serialized" in out
        assert f"rank {pid}: pipeline OK" in out
    assert "rank 0: DistComm(P=4) == SimComm(4)" in outs[0][0]


REPART_SCRIPT = r"""
import sys
import numpy as np
import jax

port, pid = sys.argv[1], int(sys.argv[2])
P = 4
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.launch.multiproc import SKEW_BRICK_SETUP

comm_ov = DistComm(timeout_s=240, namespace="rp.ov.")
comm_ser = DistComm(timeout_s=240, namespace="rp.ser.")
comm_h = DistComm(timeout_s=240, namespace="rp.h.")  # keeps digests pure
exec(SKEW_BRICK_SETUP)  # the skewed-adapt domain: skew, cm, fs0

imb_before = F.load_imbalance(fs0, comm_h)
assert imb_before > 1.5, f"fixture must be skewed, got {imb_before}"

out = fs0[0].repartition(comm_ov)
out_ser = fs0[0].repartition(comm_ser, overlap=False)
np.testing.assert_array_equal(out.keys, out_ser.keys)
np.testing.assert_array_equal(out.level, out_ser.level)
np.testing.assert_array_equal(out.tree, out_ser.tree)
assert comm_ov.wire_digest() == comm_ser.wire_digest(), \
    "overlap changed the migration bytes"
print(f"rank {pid}: overlap == serialized", flush=True)

imb_after = F.load_imbalance([out], comm_h)
assert imb_after <= 1.1, f"imbalance {imb_after} > 1.1 after repartition"
bal = F.balance([out], comm_ov)
gh = F.ghost(bal, comm_ov)

blob = (out.tree, out.keys, out.level, out.anchor, out.stype)
world = comm_h.allgather([blob])
if pid == 0:
    # single-rank oracle: same domain + skewed adapt under LocalComm,
    # where repartition is the identity on the global leaf sequence
    ns = {"np": np, "C": C, "F": F, "P": P, "comm_ov": F.LocalComm()}
    exec(SKEW_BRICK_SETUP, ns)
    ref = F.repartition(ns["fs0"], ns["comm_ov"])
    for i, name in enumerate(("tree", "keys", "level", "anchor", "stype")):
        np.testing.assert_array_equal(
            np.concatenate([w[i] for w in world]),
            np.concatenate([getattr(f, name) for f in ref]))
    print("rank 0: repartition == single-rank oracle", flush=True)
comm_h.barrier()
print(f"rank {pid}: repartition OK", flush=True)
"""


@pytest.mark.slow
def test_distcomm_four_process_repartition():
    """The tentpole's acceptance run as a pinned test: P=4 real processes,
    skewed adapt, `Forest.repartition` (the one-rank-per-process form) —
    post-migration element imbalance <= 1.1, overlap == serialized with
    wire-digest parity, and the gathered world element-for-element equal
    to the single-rank oracle."""
    outs = run_ranks(REPART_SCRIPT, 4)
    for pid, (out, _err) in enumerate(outs):
        assert f"rank {pid}: overlap == serialized" in out
        assert f"rank {pid}: repartition OK" in out
    assert "rank 0: repartition == single-rank oracle" in outs[0][0]
