"""DistComm binding test: two REAL processes over jax.distributed.

Each subprocess initializes `jax.distributed` against a shared coordinator,
builds a `DistComm` (one rank per process, payloads through the
coordination-service KV store), and runs the full message-based pipeline —
new_uniform / adapt / balance / ghost / partition / count_global — on its
single local rank.  Rank 0 then compares the distributed result against the
same pipeline under the in-process `SimComm(2)`: the SPMD forest code must
produce bit-identical forests and ghost layers under either hosting.
"""

import pytest

from repro.launch.multiproc import run_ranks

SCRIPT = r"""
import hashlib
import struct
import sys
import numpy as np
import jax

port, pid = sys.argv[1], int(sys.argv[2])
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=2, process_id=pid)

from repro.core import forest as F
from repro.core.comm import DistComm, encode_payload

comm = DistComm(timeout_s=120)
assert comm.size == 2 and comm.rank == pid
assert list(comm.local_ranks) == [pid]

# surface sanity: allgather + alltoallv of arrays through the KV store
x = np.full(3, comm.rank, np.int32)
got = comm.allgather([x])
assert [int(g[0]) for g in got] == [0, 1]
# wire-format parity: the transport moved EXACTLY the packed encode_payload
# buffer (never pickle) — the digest recomputes from the codec alone
blob = encode_payload(x)
h = hashlib.sha256()
h.update(struct.pack("<II", 1 - pid, len(blob)))
h.update(blob)
assert comm.wire_digest() == h.hexdigest(), "transport bytes != packed codec"
print(f"rank {pid}: wire format OK", flush=True)
recv = comm.alltoallv([[np.full(2, 10 * comm.rank + q, np.int32)
                        for q in range(2)]])
assert [int(r[0]) for r in recv[0]] == [10 * 0 + pid, 10 * 1 + pid]
print(f"rank {pid}: collectives OK", flush=True)

# the full message-based pipeline on one local rank per process
def corner(tree, elems, cap=4):
    a = np.asarray(elems.anchor)
    l = np.asarray(elems.level)
    return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

fs = F.new_uniform(2, 2, 2, comm)
assert len(fs) == 1 and fs[0].rank == pid
fs = [F.adapt(fs[0], corner, recursive=True)]
fs = F.balance(fs, comm)
gh = F.ghost(fs, comm)
n_global = F.count_global(fs, comm)
fs = F.partition(fs, comm)
assert F.count_global(fs, comm) == n_global

# rank 0 gathers everything and checks against the SimComm reference
blob = (fs[0].anchor, fs[0].level, fs[0].stype, fs[0].tree,
        gh[0]["anchor"], gh[0]["level"], gh[0]["stype"], gh[0]["tree"],
        gh[0]["owner"])
world = comm.allgather([blob])
if pid == 0:
    sim = F.SimComm(2)
    sfs = F.new_uniform(2, 2, 2, sim)
    sfs = [F.adapt(f, corner, recursive=True) for f in sfs]
    sfs = F.balance(sfs, sim)
    sgh = F.ghost(sfs, sim)
    sfs = F.partition(sfs, sim)
    assert F.count_global(sfs) == n_global
    for p in range(2):
        a, l, b, t, ga, gl, gb, gt, go = world[p]
        np.testing.assert_array_equal(a, sfs[p].anchor)
        np.testing.assert_array_equal(l, sfs[p].level)
        np.testing.assert_array_equal(t, sfs[p].tree)
        np.testing.assert_array_equal(ga, sgh[p]["anchor"])
        np.testing.assert_array_equal(gl, sgh[p]["level"])
        np.testing.assert_array_equal(go, sgh[p]["owner"])
    print("rank 0: DistComm == SimComm", flush=True)
comm.barrier()
print(f"rank {pid}: pipeline OK", flush=True)
"""


@pytest.mark.slow
def test_distcomm_two_process_pipeline():
    outs = run_ranks(SCRIPT, 2)
    for pid, (out, _err) in enumerate(outs):
        assert f"rank {pid}: wire format OK" in out
        assert f"rank {pid}: collectives OK" in out
        assert f"rank {pid}: pipeline OK" in out
    assert "rank 0: DistComm == SimComm" in outs[0][0]
