"""Substrate tests: data determinism, checkpoint/restart/elastic, optimizer
numerics, gradient compression, trainer fault tolerance."""

import os
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataPipeline, pack_documents
from repro.models import SHAPES, init_params, loss_fn
from repro.models.config import ShapeConfig
from repro.optim import (adamw_update, apply_updates, compressed_psum,
                         init_opt_state)
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def tiny_cfg():
    return replace(reduced(get_config("olmo-1b")), dtype="float32")


SHAPE = ShapeConfig("test", seq_len=32, global_batch=8, mode="train")


# ------------------------------------------------------------------- data
def test_pipeline_deterministic_and_seekable():
    cfg = tiny_cfg()
    p = DataPipeline(cfg, SHAPE, seed=7)
    b1 = p.batch(123)
    b2 = p.batch(123)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = p.batch(124)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(np.asarray(b1["tokens"]).max()) < cfg.vocab_size


def test_pipeline_elastic_reshard_covers_same_tokens():
    """Re-sharding 1 rank -> 2 ranks partitions the same global batch."""
    cfg = tiny_cfg()
    p1 = DataPipeline(cfg, SHAPE, seed=7, dp_rank=0, dp_size=1)
    full = np.asarray(p1.batch(5)["tokens"])
    halves = [np.asarray(p1.reshard(r, 2).batch(5)["tokens"]) for r in (0, 1)]
    assert full.shape[0] == 2 * halves[0].shape[0]
    # rank slices are disjoint deterministic streams of the right size
    assert halves[0].shape == halves[1].shape
    assert not np.array_equal(halves[0], halves[1])


def test_pack_documents_balances_tokens():
    rng = np.random.default_rng(0)
    lens = rng.integers(10, 500, size=200)
    rank_of, rows, imb = pack_documents(lens, seq_len=512, num_ranks=4)
    assert imb < 1.1
    # every token placed exactly once
    placed = np.zeros(len(lens), np.int64)
    for r in range(4):
        for (d, off, take, row, col) in rows[r]:
            placed[d] += take
    np.testing.assert_array_equal(placed, lens)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, "adamw")
    save_checkpoint(tmp_path, (params, opt), step=3)
    save_checkpoint(tmp_path, (params, opt), step=7)
    assert latest_step(tmp_path) == 7
    (p2, o2), manifest = restore_checkpoint(tmp_path, (params, opt))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # no .tmp dirs remain
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_checkpoint_bf16_leaves(tmp_path):
    tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5, "s": jnp.int8(3)}
    save_checkpoint(tmp_path, tree, step=0)
    out, _ = restore_checkpoint(tmp_path, tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32), 1.5)


def test_async_checkpointer(tmp_path):
    from repro.checkpoint import AsyncCheckpointer
    ck = AsyncCheckpointer(tmp_path)
    tree = {"w": jnp.arange(16.0)}
    ck.save(tree, step=1)
    ck.wait()
    out, m = restore_checkpoint(tmp_path, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(16.0))


# --------------------------------------------------------------- optimizer
def test_adamw_matches_reference_scalar():
    # hand-checked single-parameter AdamW
    p = {"w": jnp.asarray(2.0)}
    g = {"w": jnp.asarray(0.5)}
    st = init_opt_state(p, "adamw")
    upd, st = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.99, eps=0.0,
                           weight_decay=0.0)
    # step1: mhat = g, vhat = g^2 -> update = -lr * g/|g| = -0.1
    np.testing.assert_allclose(float(upd["w"]), -0.1, rtol=1e-5)


def test_adafactor_reduces_quadratic():
    from repro.optim import adafactor_update
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (8, 8))
    p = {"w": jnp.zeros((8, 8))}
    st = init_opt_state(p, "adafactor")

    def loss(pp):
        return jnp.sum((pp["w"] - W) ** 2)

    for _ in range(60):
        g = jax.grad(loss)(p)
        upd, st = adafactor_update(g, st, p, lr=0.3)
        p = apply_updates(p, upd)
    assert float(loss(p)) < 0.1 * float(jnp.sum(W * W))


# -------------------------------------------------------------- compression
def test_compressed_psum_error_feedback():
    """int8 psum with error feedback: mean over axis is recovered to ~1% and
    the residual shrinks the error over repeated rounds."""
    devs = jax.local_device_count()
    if devs < 2:
        pytest.skip("needs >= 2 devices (run under XLA_FLAGS host device count)")


def test_compress_roundtrip():
    from repro.optim import compress_int8, decompress_int8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s, pad = compress_int8(x)
    y = decompress_int8(q, s, pad, x.shape)
    err = np.abs(np.asarray(y - x)).max()
    assert err < np.abs(np.asarray(x)).max() / 100.0  # 1/127 per block scale


# ------------------------------------------------------------------ trainer
def test_trainer_checkpoint_restart_identical(tmp_path):
    """Kill training at step k, restart, and verify the loss trajectory is
    identical to an uninterrupted run (FT determinism)."""
    from repro.launch.train import make_train_step
    from repro.runtime import Trainer, TrainerConfig

    cfg = tiny_cfg()
    step_fn = jax.jit(make_train_step(cfg, num_micro=1, lr=1e-3))

    def mk(dirname, max_steps):
        return Trainer(cfg, SHAPE,
                       TrainerConfig(ckpt_dir=str(tmp_path / dirname),
                                     ckpt_every=5, max_steps=max_steps),
                       step_fn=step_fn, seed=3)

    # uninterrupted 10 steps
    t_full = mk("full", 10)
    _, _, log_full = t_full.run(jax.random.PRNGKey(1))

    # interrupted at 5, then resumed to 10 (same ckpt dir)
    t_a = mk("resume", 5)
    t_a.run(jax.random.PRNGKey(1))
    t_b = mk("resume", 10)
    _, _, log_b = t_b.run(jax.random.PRNGKey(1))
    assert [r["step"] for r in log_b] == [5, 6, 7, 8, 9]
    full_losses = {r["step"]: r["loss"] for r in log_full}
    for r in log_b:
        np.testing.assert_allclose(r["loss"], full_losses[r["step"]],
                                   rtol=1e-5, atol=1e-6)


def test_trainer_straggler_watchdog():
    from repro.runtime.trainer import StepWatchdog
    w = StepWatchdog(2.0)
    flagged = [w.record(i, 0.1) for i in range(10)]
    assert not any(flagged)
    assert w.record(10, 0.5)  # 5x median
