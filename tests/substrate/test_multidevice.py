"""Multi-device integration tests (8 virtual CPU devices via subprocess:
the device count must be set before jax initialises, so these run isolated).

Covers: int8 error-feedback psum numerics under shard_map, SFC partition
under pjit, and elastic checkpoint restore across different meshes.
"""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[2]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((8,), ("data",))

# ---- int8 error-feedback psum ----
from repro.optim import compressed_psum
x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)).astype(np.float32))

def f(xs, res):
    out, new_res = compressed_psum(xs[0], "data", residual=res[0])
    return out[None], new_res[None]

sharded = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                    out_specs=(P("data"), P("data")))
res = jnp.zeros_like(x)
got, res = sharded(x, res)
want = x.mean(axis=0)
err0 = float(jnp.abs(got[0] - want).max() / jnp.abs(want).max())
assert err0 < 0.02, err0
# error feedback: feeding the same x again, residual corrects the estimate
acc = got[0]
for _ in range(4):
    got, res = sharded(x, res)
    acc = acc + got[0]
err_avg = float(jnp.abs(acc / 5 - want).max() / jnp.abs(want).max())
assert err_avg < err0 + 1e-6, (err_avg, err0)
print("compressed_psum OK", err0, err_avg)

# ---- SFC partition under pjit ----
from repro.core.placement import target_ranks, imbalance
w = jnp.asarray(np.random.default_rng(1).exponential(1.0, 1024).astype(np.float32))
jt = jax.jit(lambda ww: target_ranks(ww, 8),
             in_shardings=NamedSharding(mesh, P("data")),
             out_shardings=NamedSharding(mesh, P("data")))
t = jt(w)
assert float(imbalance(w, t, 8)) < 1.15
print("pjit partition OK")

# ---- elastic checkpoint: save on (4,2) mesh, restore on (2,4) ----
from repro.checkpoint import save_checkpoint, restore_checkpoint
m1 = jax.make_mesh((4, 2), ("a", "b"))
m2 = jax.make_mesh((2, 4), ("a", "b"))
arr = jnp.arange(64.0).reshape(8, 8)
a1 = jax.device_put(arr, NamedSharding(m1, P("a", "b")))
import tempfile
d = tempfile.mkdtemp()
save_checkpoint(d, {"w": a1}, step=0)
out, _ = restore_checkpoint(d, {"w": a1},
                            shardings={"w": NamedSharding(m2, P("a", "b"))})
assert out["w"].sharding.mesh.shape == {"a": 2, "b": 4}
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(arr))
print("elastic checkpoint OK")
"""


@pytest.mark.slow
def test_multidevice_suite():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr[-3000:]
    for tag in ("compressed_psum OK", "pjit partition OK", "elastic checkpoint OK"):
        assert tag in r.stdout
