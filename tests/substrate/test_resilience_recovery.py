"""Kill-one-rank recovery at P=4: four REAL processes, one dies mid-balance.

The tentpole acceptance run.  Phase A launches a 4-rank `jax.distributed`
world where every rank installs an `Autosaver` hook and wraps its
`DistComm` in a `ChaosComm` whose only fault is crash-at-collective for
rank 3 with `hard_exit` — so rank 3 dies like a real process
(`os._exit(2)`, no Python unwind), NOT via a tidy exception.  The
survivors run with a wait deadline and must surface the death as a
`CommTimeoutError` that names the phase ("balance") and the missing peer
(3), then leave.  Rank 0 is never the victim: it hosts the coordinator.

Phase B is a FRESH 3-rank world (new coordinator, new KV namespace) that
`recover`s the Autosaver checkpoint elastically — written by 4 ranks,
restored onto 3 — finishes the interrupted balance, and gathers the
world: it must match a from-scratch in-process `SimComm(3)` run of the
same pipeline element for element.  Globally: the SFC leaf sequence is
partition-independent, so the concatenated world arrays are the
comparison, not per-rank slices.
"""

import pytest

from repro.launch.multiproc import run_ranks

# Both phases use the reference resilience scenario (same domain as
# tests/core/test_resilience.py): 2x1 Kuhn brick, corner adapt to level 4.
CRASH_SCRIPT = r"""
import os
import sys
import numpy as np
import jax

port, pid = sys.argv[1], int(sys.argv[2])
ckpt = sys.argv[3]
P = 4
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.core.errors import CommTimeoutError
from repro.core.resilience import Autosaver, ChaosComm

comm = DistComm(timeout_s=240, namespace="crash.", beacon=True)
chaos = ChaosComm(comm, crash_at=3, crash_ranks=(3,), phases=("balance",),
                  hard_exit=True)   # rank 3 dies like a real process
chaos.set_deadline(10.0)           # survivors' per-collective wait budget

def corner(tree, elems, cap=4):
    a = np.asarray(elems.anchor)
    l = np.asarray(elems.level)
    return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

cm = C.cmesh_brick(2, (2, 1))
fs0 = F.new_uniform(2, cm.num_trees, 2, chaos, cmesh=cm)
fs0 = [F.adapt(f, corner, recursive=True) for f in fs0]

saver = Autosaver(ckpt).install()
try:
    F.balance(fs0, chaos)          # rank 3 never returns from here
    print(f"rank {pid}: balance finished", flush=True)   # must not happen
    os._exit(4)
except CommTimeoutError as e:
    assert e.phase == "balance", e
    assert e.pending and 3 in e.pending, e
    print(f"rank {pid}: timeout phase={e.phase} pending={e.pending} "
          f"detail={e.detail}", flush=True)
    # os._exit: a clean interpreter exit would hang in jax.distributed
    # shutdown waiting for the dead rank
    os._exit(3)
"""

RECOVER_SCRIPT = r"""
import sys
import numpy as np
import jax

port, pid = sys.argv[1], int(sys.argv[2])
ckpt = sys.argv[3]
P = 3
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.core.resilience import recover

comm = DistComm(timeout_s=240, namespace="recover.")
cm = C.cmesh_brick(2, (2, 1))

fs = recover(ckpt, comm, cmesh=cm)   # 4-rank checkpoint -> 3-rank world
assert len(fs) == 1 and fs[0].rank == pid and fs[0].num_ranks == P
fs = F.balance(fs, comm)

blob = (fs[0].tree, fs[0].anchor, fs[0].level, fs[0].stype)
world = comm.allgather([blob])
if pid == 0:
    def corner(tree, elems, cap=4):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    sim = F.SimComm(P)
    sfs = F.new_uniform(2, cm.num_trees, 2, sim, cmesh=cm)
    sfs = [F.adapt(f, corner, recursive=True) for f in sfs]
    sfs = F.balance(sfs, sim)
    for i, name in enumerate(("tree", "anchor", "level", "stype")):
        np.testing.assert_array_equal(
            np.concatenate([w[i] for w in world]),
            np.concatenate([np.asarray(getattr(f, name)) for f in sfs]),
            err_msg=name)
    n = sum(len(w[0]) for w in world)
    print(f"rank 0: recovered P=3 == fresh P=3 ({n} elements)", flush=True)
comm.barrier()
print(f"rank {pid}: recovery OK", flush=True)
"""


@pytest.mark.slow
def test_kill_one_rank_recovery(tmp_path):
    ckpt = tmp_path / "autosave"

    # Phase A: rank 3 hard-dies at its 3rd balance collective.
    res = run_ranks(CRASH_SCRIPT, 4, extra_args=(ckpt,), timeout=300.0,
                    check=False)
    assert res[3][2] == 2, f"rank 3 must hard-exit(2): {res[3]}"
    for pid in range(3):
        out, err, rc = res[pid]
        assert rc == 3, f"survivor {pid} exited {rc}: {err[-2000:]}"
        assert f"rank {pid}: timeout phase=balance" in out
        assert "pending=[3]" in out
        assert "balance finished" not in out
    # the pre-phase snapshot landed before the crash
    assert (ckpt / "step_0" / "manifest.json").exists()

    # Phase B: fresh 3-rank world recovers it and finishes the job.
    outs = run_ranks(RECOVER_SCRIPT, 3, extra_args=(ckpt,), timeout=300.0)
    for pid, (out, _err) in enumerate(outs):
        assert f"rank {pid}: recovery OK" in out
    assert "recovered P=3 == fresh P=3" in outs[0][0]
