"""Offline fallback shim for the `hypothesis` subset used by this repo.

This box has no network access and no `hypothesis` wheel, yet the property
tests are the backbone of the SFC verification story.  The shim implements
the tiny `given/settings/strategies` surface the test modules use, backed by
bounded random sampling with a *fixed per-test seed* (derived from the test
name), so runs are deterministic and failures reproducible.

Usage (in test modules)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:          # offline: bounded random sampling
        from _pbt import given, settings, strategies as st

Supported strategies: ``integers``, ``floats``, ``booleans``, ``sampled_from``,
``lists``, ``tuples``, ``data``.  Supported settings: ``max_examples``
(capped by the ``PBT_MAX_EXAMPLES`` env var, default 25, to keep tier-1
fast), ``deadline`` (ignored — no per-example timing here).

This is intentionally NOT a shrinking/coverage-guided engine; it is a
deterministic sampler so the suite collects and runs with or without the
real hypothesis.
"""

from __future__ import annotations

import functools
import os
import random
import zlib

__all__ = ["given", "settings", "strategies", "st"]

# Global cap so the default tier-1 run finishes in minutes on one CPU core.
_MAX_EXAMPLES_CAP = int(os.environ.get("PBT_MAX_EXAMPLES", "25"))
_DEFAULT_MAX_EXAMPLES = 25


# ------------------------------------------------------------------ strategies
class Strategy:
    """A strategy is just `example(rng) -> value`."""

    def __init__(self, fn, name="strategy"):
        self._fn = fn
        self._name = name

    def example(self, rng: random.Random):
        return self._fn(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self._fn(rng)), f"{self._name}.map")

    def filter(self, pred, max_tries: int = 1000):
        def draw(rng):
            for _ in range(max_tries):
                v = self._fn(rng)
                if pred(v):
                    return v
            raise ValueError(f"{self._name}.filter: no example in {max_tries} tries")

        return Strategy(draw, f"{self._name}.filter")

    def __repr__(self):
        return f"<pbt {self._name}>"


class DataObject:
    """Stand-in for hypothesis' interactive `data()` draws."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy, label=None):
        return strategy.example(self._rng)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=None):
        if max_value is None:
            max_value = 2**63 - 1

        def draw(rng):
            # Bias toward boundaries: property bugs live at the edges.
            r = rng.random()
            if r < 0.05:
                return min_value
            if r < 0.10:
                return max_value
            return rng.randint(min_value, max_value)

        return Strategy(draw, f"integers({min_value},{max_value})")

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_ignored):
        def draw(rng):
            r = rng.random()
            if r < 0.05:
                return float(min_value)
            if r < 0.10:
                return float(max_value)
            return rng.uniform(min_value, max_value)

        return Strategy(draw, f"floats({min_value},{max_value})")

    @staticmethod
    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5, "booleans")

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return Strategy(lambda rng: seq[rng.randrange(len(seq))], "sampled_from")

    @staticmethod
    def lists(elements: Strategy, min_size=0, max_size=16):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return Strategy(draw, f"lists({min_size},{max_size})")

    @staticmethod
    def tuples(*strategies):
        return Strategy(lambda rng: tuple(s.example(rng) for s in strategies), "tuples")

    @staticmethod
    def data():
        return Strategy(lambda rng: DataObject(rng), "data")


strategies = _Strategies()
st = strategies


# ------------------------------------------------------------ given / settings
def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording example-count preferences (deadline is ignored)."""

    def deco(fn):
        fn._pbt_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strategies_args, **strategies_kwargs):
    """Run the wrapped test on `max_examples` deterministic random samples."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_pbt_settings", None) or getattr(
                fn, "_pbt_settings", {}
            )
            n = min(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES), _MAX_EXAMPLES_CAP)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n):
                rng = random.Random((seed << 20) + i)
                extra = [s.example(rng) for s in strategies_args]
                kw = {k: s.example(rng) for k, s in strategies_kwargs.items()}
                kw.update(kwargs)
                try:
                    fn(*args, *extra, **kw)
                except Exception as e:  # noqa: BLE001 - reraise with repro info
                    raise AssertionError(
                        f"pbt example {i}/{n} failed for {fn.__qualname__} "
                        f"with args={extra!r} kwargs={kw!r}: {e}"
                    ) from e

        # pytest must not inspect the original signature (it would treat the
        # strategy-filled params as fixtures): drop the __wrapped__ pointer
        # functools.wraps installed so the wrapper presents (*args, **kwargs).
        wrapper.__dict__.pop("__wrapped__", None)
        # Let an outer @settings(...) applied above @given take effect too.
        wrapper._pbt_settings = dict(getattr(fn, "_pbt_settings", {}))
        return wrapper

    return deco
