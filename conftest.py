"""Repo-level pytest config: import paths and property-test example caps.

* Puts `src/` on sys.path so `PYTHONPATH=src` is not required to run pytest.
* Puts `tests/` on sys.path so test modules can import the offline
  property-test shim (`tests/_pbt.py`) when `hypothesis` is unavailable.
* When real hypothesis IS installed, registers a `tier1` profile that caps
  example counts (same knob as the shim: PBT_MAX_EXAMPLES) so the default
  run finishes in minutes on a single CPU core.
"""

from __future__ import annotations

import os
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent
for _p in (_ROOT / "src", _ROOT / "tests", _ROOT):
    _s = str(_p)
    if _s not in sys.path:
        sys.path.insert(0, _s)

try:
    import hypothesis

    _cap = int(os.environ.get("PBT_MAX_EXAMPLES", "25"))
    hypothesis.settings.register_profile(
        "tier1", max_examples=_cap, deadline=None, derandomize=True
    )
    hypothesis.settings.load_profile("tier1")
except ImportError:
    pass
