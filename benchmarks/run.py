"""Benchmark harness — one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  fig11_new_scaling      paper Fig. 11: New runtime, linearity + level
                         independence (derived = ns/element ratio lvl6/lvl5,
                         ~1.0 means level-independent)
  fig11_new_ranks        paper Fig. 11 left: strong scaling over simulated
                         ranks (derived = parallel efficiency)
  fig12_adapt_fractal    paper Fig. 12: recursive fractal Adapt (derived =
                         measured/analytic element count, must be 1.0)
  partition_weighted     SFC weighted partition (derived = load imbalance)
  element_ops            vectorized per-element op latencies (derived =
                         ns/element)
  pallas_kernels         Pallas kernels in interpret mode vs jnp oracle
                         (derived = exactness)
  moe_placement          SFC expert placement quality (derived = imbalance
                         ratio naive/sfc)
  roofline_summary       reads results/dryrun/*.json (derived = roofline
                         fraction); run `python -m repro.launch.dryrun --all`
                         first
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROWS = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}", flush=True)


def _time(fn, n=3):
    fn()  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


def fig11_new_scaling():
    from repro.core import forest as F
    per_elem = {}
    for level in (4, 5, 6):
        us = _time(lambda: F.new_uniform_rank(3, 1, level, 0, 1), n=2)
        n_el = 8 ** level
        per_elem[level] = us * 1000.0 / n_el
        row(f"fig11_new_level{level}", us, f"{per_elem[level]:.1f}ns/elem")
    row("fig11_new_level_independence", 0.0,
        f"{per_elem[6] / per_elem[5]:.2f}x_per_elem_lvl6_vs_lvl5")


def fig11_new_ranks():
    from repro.core import forest as F
    base = None
    for P in (1, 2, 4, 8):
        comm = F.SimComm(P)
        us = _time(lambda: F.new_uniform(3, 2, 5, comm), n=2)
        if base is None:
            base = us
        # SimComm executes ranks sequentially: ideal efficiency keeps total
        # time flat (each rank builds 1/P of the elements)
        row(f"fig11_new_ranks{P}", us, f"eff={base / us:.2f}")


def fig12_adapt_fractal():
    from repro.core import forest as F
    from examples.amr_fractal import analytic_fractal_count, fractal_cb
    comm = F.SimComm(4)
    k, depth, trees = 2, 3, 4
    fs0 = F.new_uniform(3, trees, k, comm)

    def run():
        return [F.adapt(f, fractal_cb(k + depth), recursive=True) for f in fs0]

    us = _time(run, n=2)
    fs = run()
    got = F.count_global(fs)
    want = analytic_fractal_count(trees, k, depth)
    row("fig12_adapt_fractal", us, f"count_ratio={got / want:.6f}")
    row("fig12_adapt_fractal_elems", us / got * 1000, f"{got}elems_ns/elem")


def partition_weighted():
    from repro.core import forest as F
    comm = F.SimComm(8)
    fs = F.new_uniform(3, 2, 5, comm)

    def mkw(forests):
        return [2.0 ** f.level * (1.0 + 0.5 * np.sin(f.keys.astype(np.float64)))
                for f in forests]

    us = _time(lambda: F.partition(fs, comm, weights=mkw(fs)), n=2)
    out = F.partition(fs, comm, weights=mkw(fs))
    loads = [float(w.sum()) for w in mkw(out)]
    imb = max(loads) / (sum(loads) / len(loads))
    row("partition_weighted", us, f"imbalance={imb:.4f}")


def element_ops():
    import jax
    import jax.numpy as jnp
    from repro.core import ops3d, u64
    n = 100_000
    rng = np.random.default_rng(0)
    lv = jnp.asarray(rng.integers(1, ops3d.L, size=n), jnp.int32)
    ids = u64.from_int(rng.integers(0, 2 ** 40, size=n).astype(np.uint64))
    s = ops3d.from_linear_id(ids, lv)
    fns = {
        "morton_key": jax.jit(ops3d.morton_key),
        "encode_decode": jax.jit(lambda ss: ops3d.from_linear_id(ops3d.linear_id(ss), ss.level)),
        "face_neighbor": jax.jit(lambda ss: ops3d.face_neighbor(ss, jnp.int32(0))),
        "successor": jax.jit(ops3d.successor),
        "is_inside_root": jax.jit(ops3d.is_inside_root),
    }
    for name, fn in fns.items():
        us = _time(lambda: jax.block_until_ready(fn(s)), n=3)
        row(f"element_op_{name}", us, f"{us * 1000 / n:.1f}ns/elem")


def pallas_kernels():
    import jax.numpy as jnp
    from repro.core import ops3d, u64
    from repro.kernels import ops as kops
    n = 4096
    rng = np.random.default_rng(1)
    lv = jnp.asarray(rng.integers(1, ops3d.L, size=n), jnp.int32)
    ids = u64.from_int(rng.integers(0, 2 ** 40, size=n).astype(np.uint64))
    s = ops3d.from_linear_id(ids, lv)
    want = ops3d.morton_key(s)
    us = _time(lambda: kops.morton_key(3, s), n=2)
    hi, lo = kops.morton_key(3, s)
    exact = int((np.asarray(hi) == np.asarray(want.hi)).all()
                and (np.asarray(lo) == np.asarray(want.lo)).all())
    row("pallas_morton_key_interpret", us, f"exact={exact}")
    nb_k, dual_k = kops.face_neighbor(3, s, 0)
    nb_r, dual_r = ops3d.face_neighbor(s, jnp.int32(0))
    exact = int(np.array_equal(np.asarray(nb_k.anchor), np.asarray(nb_r.anchor)))
    row("pallas_face_neighbor_interpret", 0.0, f"exact={exact}")


def moe_placement():
    import jax.numpy as jnp
    from repro.core.placement import expert_placement, imbalance
    rng = np.random.default_rng(0)
    load = jnp.asarray((rng.zipf(1.3, size=256) % 4000 + 50).astype(np.float32))
    naive = jnp.repeat(jnp.arange(16), 16)
    us = _time(lambda: expert_placement(load, 16), n=3)
    dev, imb = expert_placement(load, 16)
    ratio = float(imbalance(load, naive, 16)) / float(imb)
    row("moe_sfc_placement", us, f"imbalance_gain={ratio:.2f}x")


def roofline_summary():
    d = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not d.exists():
        row("roofline_summary", 0.0, "missing:run_dryrun_first")
        return
    for p in sorted(d.glob("*__single.json")):
        j = json.loads(p.read_text())
        if j.get("status") != "ok":
            row(f"roofline_{p.stem}", 0.0, j.get("status", "?"))
            continue
        r = j["roofline"]
        row(f"roofline_{p.stem}", 0.0,
            f"frac={r['roofline_fraction']:.3f}:bound={r['bottleneck']}")


def main() -> None:
    print("name,us_per_call,derived")
    fig11_new_scaling()
    fig11_new_ranks()
    fig12_adapt_fractal()
    partition_weighted()
    element_ops()
    pallas_kernels()
    moe_placement()
    roofline_summary()


if __name__ == "__main__":
    main()
