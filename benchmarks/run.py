"""Benchmark harness — one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  fig11_new_scaling      paper Fig. 11: New runtime, linearity + level
                         independence (derived = ns/element ratio lvl6/lvl5,
                         ~1.0 means level-independent)
  fig11_new_ranks        paper Fig. 11 left: strong scaling over simulated
                         ranks (derived = parallel efficiency)
  fig12_adapt_fractal    paper Fig. 12: recursive fractal Adapt (derived =
                         measured/analytic element count, must be 1.0)
  partition_weighted     SFC weighted partition (derived = load imbalance)
  element_ops            vectorized per-element op latencies (derived =
                         ns/element)
  pallas_kernels         Pallas kernels in interpret mode vs jnp oracle
                         (derived = exactness)
  moe_placement          SFC expert placement quality (derived = imbalance
                         ratio naive/sfc)
  forest_backends        Adapt/Balance wall time per element-ops backend
                         (reference / jnp / pallas) at several mesh sizes;
                         asserts bit-identical forests and writes
                         BENCH_forest.json (derived = speedup vs reference)
  face_sweep             fused all-faces sweep vs the composed per-face ops
                         (per-backend timings, dispatch counts, Balance/Ghost
                         dispatch invariants; merges a "face_sweep" section
                         into BENCH_forest.json; derived = fused speedup)
  multitree              cross-tree Balance/Ghost on the 2-tree (2D) and
                         6-tree (3D) cube domains per backend; asserts
                         bit-identity and that refinement ripples across
                         tree faces (derived = cross-tree ghost fraction)
  hybrid                 element-class seam: per-class batched-op latencies
                         (simplex vs hex on the same batch size), a
                         hex-vs-simplex Balance at matched element count,
                         and the mixed-class fixture pipeline with
                         per-class oracle parity (merges a "hybrid"
                         section into BENCH_forest.json; derived =
                         hex/simplex time ratios)
  scale                  overlapped vs serialized Balance under simulated
                         round-trip latency (8k elements, asserts >= 1.3x
                         in the full run) plus REAL DistComm subprocess
                         weak scaling (P = 1/2/4, per-rank wire volume and
                         wall times; merges "overlap" and "scale" sections
                         into BENCH_forest.json)
  device_eval            device-resident fused Balance eval (sweep ->
                         need-mask -> query-build on device) vs the PR-4
                         host-eval baseline at the 8k acceptance mesh;
                         asserts the >=2x gate (full run), the O(1)
                         dispatch / <=2 host-materializations-per-round
                         budget, and zero jit retraces at warm buckets;
                         merges a "device_eval" section into
                         BENCH_forest.json
  repartition            dynamic repartition on the skewed-adapt Kuhn
                         brick: imbalance before/after, migrated wire
                         bytes, overlapped vs serialized wall time under
                         simulated latency, plus REAL DistComm
                         subprocesses (P=4, P=2 in tiny) asserting
                         imbalance <= 1.1 and element-for-element identity
                         with the single-rank oracle; merges a
                         "repartition" section into BENCH_forest.json
  chaos                  seeded fault injection on the resilience brick
                         (ChaosComm over SimComm(4)): per-fault-kind runs
                         must stay bit-identical to the clean run with
                         every injection detected and retries bounded; a
                         stalled rank must surface as a phase-named
                         CommTimeoutError; crash + Autosaver + recover at
                         P=3 must match the fresh P=3 run (merges a
                         "chaos" section into BENCH_forest.json; derived =
                         injected/detected counts and chaos overhead)
  roofline_summary       reads results/dryrun/*.json (derived = roofline
                         fraction); run `python -m repro.launch.dryrun --all`
                         first

CLI: --suite NAME[,NAME...] (default: all), --tiny (smallest sizes only,
for CI smoke runs).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROWS = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}", flush=True)


def _time(fn, n=3):
    fn()  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


def fig11_new_scaling():
    from repro.core import forest as F
    per_elem = {}
    for level in (4, 5, 6):
        us = _time(lambda: F.new_uniform_rank(3, 1, level, 0, 1), n=2)
        n_el = 8 ** level
        per_elem[level] = us * 1000.0 / n_el
        row(f"fig11_new_level{level}", us, f"{per_elem[level]:.1f}ns/elem")
    row("fig11_new_level_independence", 0.0,
        f"{per_elem[6] / per_elem[5]:.2f}x_per_elem_lvl6_vs_lvl5")


def fig11_new_ranks():
    from repro.core import forest as F
    base = None
    for P in (1, 2, 4, 8):
        comm = F.SimComm(P)
        us = _time(lambda: F.new_uniform(3, 2, 5, comm), n=2)
        if base is None:
            base = us
        # SimComm executes ranks sequentially: ideal efficiency keeps total
        # time flat (each rank builds 1/P of the elements)
        row(f"fig11_new_ranks{P}", us, f"eff={base / us:.2f}")


def fig12_adapt_fractal():
    from repro.core import forest as F
    from examples.amr_fractal import analytic_fractal_count, fractal_cb
    comm = F.SimComm(4)
    k, depth, trees = 2, 3, 4
    fs0 = F.new_uniform(3, trees, k, comm)

    def run():
        return [F.adapt(f, fractal_cb(k + depth), recursive=True) for f in fs0]

    us = _time(run, n=2)
    fs = run()
    got = F.count_global(fs)
    want = analytic_fractal_count(trees, k, depth)
    row("fig12_adapt_fractal", us, f"count_ratio={got / want:.6f}")
    row("fig12_adapt_fractal_elems", us / got * 1000, f"{got}elems_ns/elem")


def partition_weighted():
    from repro.core import forest as F
    comm = F.SimComm(8)
    fs = F.new_uniform(3, 2, 5, comm)

    def mkw(forests):
        return [2.0 ** f.level * (1.0 + 0.5 * np.sin(f.keys.astype(np.float64)))
                for f in forests]

    us = _time(lambda: F.partition(fs, comm, weights=mkw(fs)), n=2)
    out = F.partition(fs, comm, weights=mkw(fs))
    loads = [float(w.sum()) for w in mkw(out)]
    imb = max(loads) / (sum(loads) / len(loads))
    row("partition_weighted", us, f"imbalance={imb:.4f}")


def element_ops():
    import jax
    import jax.numpy as jnp
    from repro.core import ops3d, u64
    n = 100_000
    rng = np.random.default_rng(0)
    lv = jnp.asarray(rng.integers(1, ops3d.L, size=n), jnp.int32)
    ids = u64.from_int(rng.integers(0, 2 ** 40, size=n).astype(np.uint64))
    s = ops3d.from_linear_id(ids, lv)
    fns = {
        "morton_key": jax.jit(ops3d.morton_key),
        "encode_decode": jax.jit(lambda ss: ops3d.from_linear_id(ops3d.linear_id(ss), ss.level)),
        "face_neighbor": jax.jit(lambda ss: ops3d.face_neighbor(ss, jnp.int32(0))),
        "successor": jax.jit(ops3d.successor),
        "is_inside_root": jax.jit(ops3d.is_inside_root),
    }
    for name, fn in fns.items():
        us = _time(lambda: jax.block_until_ready(fn(s)), n=3)
        row(f"element_op_{name}", us, f"{us * 1000 / n:.1f}ns/elem")


def pallas_kernels(tiny: bool = False):
    import jax.numpy as jnp
    from repro.core import ops3d, u64
    from repro.kernels import ops as kops
    n = 256 if tiny else 4096
    rng = np.random.default_rng(1)
    lv = jnp.asarray(rng.integers(1, ops3d.L, size=n), jnp.int32)
    ids = u64.from_int(rng.integers(0, 2 ** 40, size=n).astype(np.uint64))
    s = ops3d.from_linear_id(ids, lv)
    block = min(1024, n)
    want = ops3d.morton_key(s)
    us = _time(lambda: kops.morton_key(3, s, block), n=2)
    hi, lo = kops.morton_key(3, s, block)
    exact = int((np.asarray(hi) == np.asarray(want.hi)).all()
                and (np.asarray(lo) == np.asarray(want.lo)).all())
    row("pallas_morton_key_interpret", us, f"exact={exact}")
    nb_k, dual_k = kops.face_neighbor(3, s, 0, block)
    nb_r, dual_r = ops3d.face_neighbor(s, jnp.int32(0))
    exact = int(np.array_equal(np.asarray(nb_k.anchor), np.asarray(nb_r.anchor)))
    row("pallas_face_neighbor_interpret", 0.0, f"exact={exact}")
    p_k = kops.parent(3, s, block)
    p_r = ops3d.parent(s)
    exact = int(np.array_equal(np.asarray(p_k.anchor), np.asarray(p_r.anchor))
                and np.array_equal(np.asarray(p_k.stype), np.asarray(p_r.stype)))
    row("pallas_parent_interpret", 0.0, f"exact={exact}")
    in_k = kops.is_inside_root(3, nb_k, block)
    in_r = ops3d.is_inside_root(nb_r)
    exact = int(np.array_equal(np.asarray(in_k), np.asarray(in_r)))
    row("pallas_is_inside_root_interpret", 0.0, f"exact={exact}")


def moe_placement():
    import jax.numpy as jnp
    from repro.core.placement import expert_placement, imbalance
    rng = np.random.default_rng(0)
    load = jnp.asarray((rng.zipf(1.3, size=256) % 4000 + 50).astype(np.float32))
    naive = jnp.repeat(jnp.arange(16), 16)
    us = _time(lambda: expert_placement(load, 16), n=3)
    dev, imb = expert_placement(load, 16)
    ratio = float(imbalance(load, naive, 16)) / float(imb)
    row("moe_sfc_placement", us, f"imbalance_gain={ratio:.2f}x")


def forest_backends(tiny: bool = False):
    """Adapt/Balance wall time per element-ops backend at several mesh sizes.

    Asserts bit-identical forests across backends and writes BENCH_forest.json
    (per size/backend timings + speedups vs the reference backend).
    """
    from repro.core import batch
    from repro.core import forest as F

    d = 3
    levels = [2] if tiny else [2, 3, 4]
    backends = ["reference", "jnp", "pallas"]
    # Interpret-mode Pallas on CPU pays a per-shape compile that dwarfs the
    # runtime; cap the pallas rows to the two smallest meshes (still "several
    # sizes"); on TPU all sizes run compiled.
    pallas_levels = set(levels[:2])
    report = {"suite": "forest_backends", "d": d, "trees": 2, "ranks": 4,
              "tiny": tiny, "sizes": []}

    for level in levels:
        comm = F.SimComm(4)
        base = F.new_uniform(d, 2, level, comm)
        n0 = F.count_global(base)

        def corner_cb(tree, elems, cap=level + 2):
            a = np.asarray(elems.anchor)
            l = np.asarray(elems.level)
            return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

        # wire volume: boundary-only message path vs the retained
        # allgathered-global-table oracle — backend invariant, measured once
        # per mesh size on fresh comms, with element-for-element parity
        fs0 = [F.adapt(f, corner_cb, recursive=True) for f in base]
        cm_msg, cm_orc = F.SimComm(4), F.SimComm(4)
        out_msg = F.balance([f for f in fs0], cm_msg)
        out_orc = F.balance_oracle([f for f in fs0], cm_orc)
        assert all(
            np.array_equal(a.keys, b.keys) and np.array_equal(a.level, b.level)
            and np.array_equal(a.tree, b.tree)
            for a, b in zip(out_msg, out_orc)
        ), f"message balance diverged from oracle at level {level}"
        F.ghost(out_msg, cm_msg)
        F.ghost_oracle(out_orc, cm_orc)
        comm_bytes = {
            "balance_message": cm_msg.bytes_for("balance"),
            "balance_allgather": cm_orc.bytes_for("balance_oracle"),
            "ghost_message": cm_msg.bytes_for("ghost"),
            "ghost_allgather": cm_orc.bytes_for("ghost_oracle"),
        }
        row(
            f"forest_comm_bytes_lvl{level}", 0.0,
            f"message={comm_bytes['balance_message'] + comm_bytes['ghost_message']}"
            f":allgather={comm_bytes['balance_allgather'] + comm_bytes['ghost_allgather']}",
        )

        entry = {"level": level, "elements": n0, "backends": {},
                 "comm_bytes": comm_bytes}
        ref_sig = None
        for be in backends:
            if be == "pallas" and level not in pallas_levels:
                entry["backends"][be] = {"skipped": "interpret-mode size cap on CPU"}
                continue
            with batch.use_backend(be):
                us_adapt = _time(
                    lambda: [F.adapt(f, corner_cb, recursive=True) for f in base], n=2
                )
                fs = [F.adapt(f, corner_cb, recursive=True) for f in base]
                us_bal = _time(lambda: F.balance(fs, comm), n=2)
                out = F.balance(fs, comm)
                sig = (
                    np.concatenate([f.keys for f in out]),
                    np.concatenate([f.level for f in out]),
                    np.concatenate([f.tree for f in out]),
                )
                if ref_sig is None:
                    ref_sig = sig
                identical = all(np.array_equal(a, b) for a, b in zip(sig, ref_sig))
                assert identical, f"backend {be} diverged from reference at level {level}"
                rec = {
                    "adapt_us": us_adapt,
                    "balance_us": us_bal,
                    "final_elements": F.count_global(out),
                    "identical_to_reference": identical,
                }
                entry["backends"][be] = rec
                row(f"forest_{be}_adapt_lvl{level}", us_adapt, f"n={n0}:identical={int(identical)}")
                row(f"forest_{be}_balance_lvl{level}", us_bal, f"n={n0}")
        ref = entry["backends"]["reference"]
        for be, rec in entry["backends"].items():
            if "adapt_us" in rec:
                rec["adapt_speedup_vs_reference"] = ref["adapt_us"] / rec["adapt_us"]
                rec["balance_speedup_vs_reference"] = ref["balance_us"] / rec["balance_us"]
        report["sizes"].append(entry)

    largest = report["sizes"][-1]
    best = max(
        rec["adapt_speedup_vs_reference"]
        for be, rec in largest["backends"].items()
        if be != "reference" and "adapt_speedup_vs_reference" in rec
    )
    row("forest_backends_largest_speedup", 0.0, f"{best:.2f}x_batched_vs_reference")
    report["largest_mesh_batched_speedup"] = best
    # wire-volume acceptance at the largest mesh (8k elements in the full
    # run): boundary-only exchanges must beat the allgathered leaf table
    cb = largest["comm_bytes"]
    msg = cb["balance_message"] + cb["ghost_message"]
    agg = cb["balance_allgather"] + cb["ghost_allgather"]
    assert msg < agg, f"boundary-only path moved MORE bytes ({msg} >= {agg})"
    report["largest_mesh_comm_bytes_message"] = msg
    report["largest_mesh_comm_bytes_allgather"] = agg
    row("forest_comm_bytes_win", 0.0,
        f"{agg / max(msg, 1):.1f}x_less_wire_than_allgather")
    # tiny (CI smoke) runs must not clobber the full benchmark artifact
    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    if out_path.exists():  # keep sibling suites' sections
        prev = json.loads(out_path.read_text())
        for key in ("face_sweep", "overlap", "scale", "repartition",
                    "device_eval", "chaos", "hybrid"):
            if key in prev:
                report[key] = prev[key]
    out_path.write_text(json.dumps(report, indent=2))
    row("forest_backends_json", 0.0, str(out_path))


def face_sweep(tiny: bool = False):
    """Fused all-faces sweep vs the composed per-face ops it replaced.

    Times one `face_sweep` dispatch against the 3 x (d+1) composed
    face_neighbor/is_inside_root/morton_key dispatches per backend, asserts
    bit-identity, measures BatchedOps dispatch counts for both paths and for
    a full message-based Balance/Ghost (which must issue face_sweep only —
    never per-face neighbor ops), and merges everything into
    BENCH_forest.json under the "face_sweep" key."""
    import jax
    from repro.core import batch, u64
    from repro.core import forest as F

    d = 3
    level = 2 if tiny else 4
    f = F.new_uniform_rank(d, 2, level, 0, 1)  # 2 trees: 8192 elements at lvl 4
    n = f.num_local
    s = f.simplices()
    report = {"d": d, "elements": n, "backends": {}}

    def composed(bops):
        outs = []
        for face in range(d + 1):
            nb, dual = bops.face_neighbor(s, face)
            outs.append((nb, dual, bops.is_inside_root(nb), bops.morton_key(nb)))
        return outs

    backends = ["reference", "jnp"] + (["pallas"] if tiny else [])
    for be in backends:
        bops = batch.get_batch_ops(d, be)
        us_comp = _time(lambda: jax.block_until_ready(composed(bops)), n=3)
        us_fused = _time(lambda: jax.block_until_ready(bops.face_sweep(s)), n=3)
        batch.reset_dispatch_counts()
        comp = composed(bops)
        n_comp = sum(batch.dispatch_counts().values())
        batch.reset_dispatch_counts()
        sw = bops.face_sweep(s)
        n_fused = sum(batch.dispatch_counts().values())
        # bit parity of the fused dispatch with the composed per-face ops
        for face, (nb, dual, inside, key) in enumerate(comp):
            assert np.array_equal(np.asarray(sw.neighbor.anchor[face]),
                                  np.asarray(nb.anchor))
            assert np.array_equal(np.asarray(sw.dual[face]), np.asarray(dual))
            assert np.array_equal(np.asarray(sw.inside[face]), np.asarray(inside))
            assert np.array_equal(u64.to_np(sw.key)[face], u64.to_np(key))
        report["backends"][be] = {
            "composed_us": us_comp, "fused_us": us_fused,
            "composed_dispatches": n_comp, "fused_dispatches": n_fused,
            "speedup": us_comp / us_fused,
        }
        row(f"face_sweep_{be}_fused", us_fused,
            f"{us_comp / us_fused:.2f}x_vs_composed:dispatches={n_fused}vs{n_comp}")
        assert n_fused == 1 and n_comp == 3 * (d + 1), (n_fused, n_comp)

    # dispatch-count invariant of the rewritten hot loops: one sweep per
    # eval layer, zero per-face neighbor dispatches, for a whole pipeline
    comm = F.SimComm(2)
    fs = F.new_uniform(d, 2, level, comm)

    def corner_cb(tree, elems, cap=level + 2):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    with batch.use_backend("jnp"):
        fs = [F.adapt(x, corner_cb, recursive=True) for x in fs]
        batch.reset_dispatch_counts()
        out = F.balance(fs, comm)
        bal_counts = batch.dispatch_counts()
        batch.reset_dispatch_counts()
        F.ghost(out, comm)
        gh_counts = batch.dispatch_counts()
        batch.reset_dispatch_counts()
    assert bal_counts.get("face_neighbor", 0) == 0, bal_counts
    assert gh_counts.get("face_neighbor", 0) == 0, gh_counts
    assert gh_counts["face_sweep"] == sum(1 for x in out if x.num_local)
    report["balance_dispatches"] = bal_counts
    report["ghost_dispatches"] = gh_counts
    row("face_sweep_balance_dispatches", 0.0,
        f"face_sweep={bal_counts.get('face_sweep', 0)}"
        f":per_face_ops={bal_counts.get('face_neighbor', 0)}")

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["face_sweep"] = report
    out_path.write_text(json.dumps(data, indent=2))
    row("face_sweep_json", 0.0, str(out_path))


def device_eval(tiny: bool = False):
    """Device-resident fused Balance eval vs the PR-4 host-eval baseline.

    Times the jnp-backend balance at the acceptance mesh (d=3, 2 trees,
    level 4 -> 8k elements, corner refinement, SimComm(4)) against the
    pinned PR-4 baseline, where the same mesh ran the 2:1 eval host-side
    after materializing every sweep field to numpy.  A no-op round over the
    balanced forest then pins the budget that makes the fusion a speedup:
    one face_sweep + one eval_route + one eval_2to1 dispatch per non-empty
    rank, exactly two host materializations per rank per round (compacted
    routing rows + fused need/boundary masks), zero per-face fallback
    dispatches, and ZERO jit retraces once the padding buckets are warm.
    Tiny runs shrink to level 2 and skip the wall-time gate (CI machines
    vary) but enforce every counter invariant.  Merges a "device_eval"
    section into BENCH_forest.json."""
    from repro.core import batch
    from repro.core import forest as F

    d = 3
    level = 2 if tiny else 4
    baseline_us = 94897.0  # PR-4 jnp balance_us at this mesh (BENCH history)
    comm = F.SimComm(4)
    base = F.new_uniform(d, 2, level, comm)

    def corner_cb(tree, elems, cap=level + 2):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    report = {"d": d, "level": level, "tiny": tiny,
              "baseline_pr4_jnp_us": baseline_us}
    with batch.use_backend("jnp"):
        fs = [F.adapt(f, corner_cb, recursive=True) for f in base]
        us_bal = _time(lambda: F.balance(fs, comm), n=5)
        out = F.balance(fs, comm)
        nonempty = sum(1 for f in out if f.num_local)
        # counters over one already-balanced (single) round, buckets warm
        batch.reset_dispatch_counts()
        batch.reset_host_fetch_counts()
        batch.reset_trace_counts()
        F.balance(out, comm)
        disp = batch.dispatch_counts()
        fetch = batch.host_fetch_counts()
        traces = batch.trace_counts()
        batch.reset_dispatch_counts()
        batch.reset_host_fetch_counts()
        batch.reset_trace_counts()
    assert disp.get("face_sweep", 0) == nonempty, disp
    assert disp.get("eval_2to1", 0) == nonempty, disp
    assert disp.get("eval_route", 0) == nonempty, disp
    for banned in ("face_neighbor", "is_inside_root", "owner_rank"):
        assert disp.get(banned, 0) == 0, disp
    assert fetch.get("eval_2to1", 0) == nonempty, fetch
    assert fetch.get("eval_route", 0) == nonempty, fetch
    assert fetch.get("eval_cache", 0) == 0, fetch
    assert all(v == 0 for v in traces.values()), traces  # jit-retrace guard
    fetches_per_rank = sum(fetch.values()) // max(nonempty, 1)
    assert fetches_per_rank <= 2, fetch
    report.update(
        elements=F.count_global(out), balance_us=us_bal,
        speedup_vs_pr4=baseline_us / us_bal,
        noop_round_dispatches=disp, noop_round_host_fetches=fetch,
        host_fetches_per_rank_per_round=fetches_per_rank,
        retraces_after_warm=sum(traces.values()),
    )
    row("device_eval_jnp_balance", us_bal,
        f"{baseline_us / us_bal:.2f}x_vs_pr4_host_eval"
        f":fetches_per_round={fetches_per_rank}:retraces=0")
    if not tiny:
        assert us_bal <= baseline_us / 2, (
            f"device-resident balance {us_bal:.0f}us did not reach 2x vs "
            f"the PR-4 host-eval baseline {baseline_us:.0f}us")

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["device_eval"] = report
    out_path.write_text(json.dumps(data, indent=2))
    row("device_eval_json", 0.0, str(out_path))


def multitree(tiny: bool = False):
    """Cross-tree Balance/Ghost wall time on connected cube domains.

    2 simulated ranks, corner refinement in tree 0 rippling across the glued
    tree faces; asserts bit-identical forests and ghost layers between the
    reference and jnp backends and reports the cross-tree ghost fraction."""
    from repro.core import batch
    from repro.core import cmesh as C
    from repro.core import forest as F

    cases = [(2, 2, 4)] if tiny else [(2, 3, 5), (3, 2, 4)]
    for d, base, deep in cases:
        cm = C.cmesh_unit_cube(d)
        comm = F.SimComm(2)
        base_fs = F.new_uniform(d, cm.num_trees, base, comm, cmesh=cm)

        def corner(tree, elems, cap=deep):
            a = np.asarray(elems.anchor)
            l = np.asarray(elems.level)
            return ((np.asarray(tree) == 0) & (a.sum(1) == 0) & (l < cap)).astype(np.int32)

        sigs = {}
        for be in ("reference", "jnp"):
            with batch.use_backend(be):
                fs = [F.adapt(f, corner, recursive=True) for f in base_fs]
                us_bal = _time(lambda: F.balance(fs, comm), n=2)
                out = F.balance(fs, comm)
                us_gh = _time(lambda: F.ghost(out, comm), n=2)
                gh = F.ghost(out, comm)
                sigs[be] = (
                    np.concatenate([f.keys for f in out]),
                    np.concatenate([f.tree for f in out]),
                    [tuple(map(tuple, g["anchor"])) for g in gh],
                    [tuple(int(v) for k in ("level", "stype", "tree", "owner")
                           for v in g[k]) for g in gh],
                )
                n = F.count_global(out)
                n_gh = sum(len(g["level"]) for g in gh)
                cross = 0
                for p, g in enumerate(gh):
                    local_trees = set(out[p].tree.tolist())
                    cross += sum(1 for t in g["tree"].tolist() if t not in local_trees)
                row(f"multitree_{be}_balance_d{d}", us_bal, f"n={n}")
                row(f"multitree_{be}_ghost_d{d}", us_gh,
                    f"ghosts={n_gh}:crosstree={cross / max(n_gh, 1):.2f}")
        for a, b in zip(sigs["reference"], sigs["jnp"]):
            assert a == b if isinstance(a, list) else np.array_equal(a, b), \
                f"jnp diverged from reference on multitree d={d}"
    row("multitree_identical", 0.0, "reference==jnp")


def hybrid(tiny: bool = False):
    """Element-class seam costs: hex vs simplex, and the mixed fixture.

    Three parts, merged into BENCH_forest.json under "hybrid":

      ops      per-class batched-op latencies (jnp backend, same batch
               size): morton_key / decode / children / fused face_sweep
               for ECLASS_SIMPLEX vs ECLASS_HEX at d=3.  The hex rows
               lower through the same padded jit pipeline keyed
               (d, eclass), so the ratio measures algorithmic cost (no
               type LUTs, 2d faces vs d+1), not dispatch overhead.

      balance  hex brick vs simplex 2-tree mesh at MATCHED element count
               (same d, level, tree count, corner refinement, SimComm(4)):
               adapt + balance wall time per class, message wire bytes,
               and element-for-element parity with the generalized
               balance_oracle for both classes.

      mixed    the cmesh_hybrid_pair fixture through the full pipeline at
               P=2 with per-class oracle parity — the acceptance smoke CI
               runs with --tiny.
    """
    import jax
    from repro.core import batch, u64
    from repro.core import cmesh as Cm
    from repro.core import forest as F
    from repro.core.types import ECLASS_HEX, ECLASS_SIMPLEX

    d = 3
    report = {"d": d, "tiny": tiny, "ops": {}, "balance": {}, "mixed": {}}

    # ---- part 1: per-class batched-op latencies -------------------------
    from repro.core import get_ops
    n = 1024 if tiny else 16384
    rng = np.random.default_rng(0)
    per_class = {}
    for ec, tag in ((ECLASS_SIMPLEX, "simplex"), (ECLASS_HEX, "hex")):
        o = get_ops(d, ec)
        lv = rng.integers(1, o.L, size=n)
        ids = u64.from_int(rng.integers(0, 2 ** 40, size=n).astype(np.uint64))
        import jax.numpy as jnp
        s = o.from_linear_id(ids, jnp.asarray(lv, jnp.int32))
        bops = batch.get_batch_ops(d, "jnp", eclass=ec)
        fns = {
            "morton_key": lambda: bops.morton_key(s),
            "decode": lambda: bops.decode(bops.morton_key(s), s.level),
            "children": lambda: bops.children(s),
            "face_sweep": lambda: bops.face_sweep(s),
        }
        per_class[tag] = {}
        for name, fn in fns.items():
            us = _time(lambda: jax.block_until_ready(fn()), n=3)
            per_class[tag][name] = us
            row(f"hybrid_op_{tag}_{name}", us, f"{us * 1000 / n:.1f}ns/elem")
    report["ops"] = {"batch_size": n, **per_class}
    for name in per_class["simplex"]:
        ratio = per_class["hex"][name] / per_class["simplex"][name]
        report["ops"].setdefault("hex_over_simplex", {})[name] = ratio
    row("hybrid_op_ratio_face_sweep", 0.0,
        f"{report['ops']['hex_over_simplex']['face_sweep']:.2f}x_hex_vs_simplex")

    # ---- part 2: hex vs simplex balance at matched element count --------
    level = 1 if tiny else 3
    P = 4
    meshes = {
        "simplex": (Cm.cmesh_unit_cube(2), 2),   # d=2 Kuhn square: 2 trees
        "hex": (Cm.cmesh_hex_brick(2, (2, 1)), 2),
    }

    def corner_cb(tree, elems, cap=level + 2):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    with batch.use_backend("jnp"):
        for tag, (cm, trees) in meshes.items():
            comm = F.SimComm(P)
            base = F.new_uniform(2, trees, level, comm, cmesh=cm)
            fs = [F.adapt(f, corner_cb, recursive=True) for f in base]
            us_adapt = _time(
                lambda: [F.adapt(f, corner_cb, recursive=True) for f in base], n=2)
            cmm = F.SimComm(P)
            us_bal = _time(lambda: F.balance(fs, cmm), n=2)
            cm_msg = F.SimComm(P)
            out = F.balance(fs, cm_msg)
            orc = F.balance_oracle(fs, F.SimComm(P))
            identical = all(
                np.array_equal(a.keys, b.keys) and np.array_equal(a.tree, b.tree)
                for a, b in zip(out, orc))
            assert identical, f"{tag} balance diverged from its oracle"
            report["balance"][tag] = {
                "elements": F.count_global(out),
                "adapt_us": us_adapt, "balance_us": us_bal,
                "balance_bytes": cm_msg.bytes_for("balance"),
                "oracle_identical": identical,
            }
            row(f"hybrid_balance_{tag}", us_bal,
                f"n={F.count_global(out)}:oracle_identical={int(identical)}")
    rb = report["balance"]
    row("hybrid_balance_ratio", 0.0,
        f"{rb['hex']['balance_us'] / rb['simplex']['balance_us']:.2f}"
        f"x_hex_vs_simplex")

    # ---- part 3: the mixed-class fixture pipeline (CI smoke) ------------
    cm = Cm.cmesh_hybrid_pair(2)
    comm = F.SimComm(2)
    fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
    fs = [F.adapt(f, corner_cb, recursive=True) for f in fs]
    t0 = time.perf_counter()
    out = F.balance(fs, comm)
    gh = F.ghost(out, comm)
    us_mixed = (time.perf_counter() - t0) * 1e6
    assert F.validate(out, gh)
    orc = F.balance_oracle(fs, F.SimComm(2))
    assert all(np.array_equal(a.keys, b.keys) and np.array_equal(a.tree, b.tree)
               for a, b in zip(out, orc)), "mixed balance diverged from oracle"
    gorc = F.ghost_oracle(out, F.SimComm(2))
    assert all(
        all(np.array_equal(a[k], b[k])
            for k in ("anchor", "level", "stype", "tree", "owner"))
        for a, b in zip(gh, gorc)), "mixed ghost diverged from oracle"
    te = cm.tree_eclass
    n_hex = sum(int((te[f.tree] == ECLASS_HEX).sum()) for f in out)
    n_simp = sum(int((te[f.tree] == ECLASS_SIMPLEX).sum()) for f in out)
    report["mixed"] = {
        "domain": "cmesh_hybrid_pair(2)", "ranks": 2,
        "pipeline_us": us_mixed, "hex_elements": n_hex,
        "simplex_elements": n_simp,
        "ghosts": sum(len(g["level"]) for g in gh),
        "oracle_identical": True,
    }
    row("hybrid_mixed_pipeline", us_mixed,
        f"hex={n_hex}:simplex={n_simp}:oracle_identical=1")

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["hybrid"] = report
    out_path.write_text(json.dumps(data, indent=2))
    row("hybrid_json", 0.0, str(out_path))


_SCALE_SCRIPT = r"""
import json, sys, time
import numpy as np
import jax

port, pid, P, level, out_path = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    sys.argv[5])
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.launch.multiproc import WEAK_BRICK_SETUP

comm_ov = DistComm(timeout_s=240, namespace="ov.")
comm_ser = DistComm(timeout_s=240, namespace="ser.")
exec(WEAK_BRICK_SETUP)  # defines corner, cm, fs0 (the weak-scaling domain)

def timed(comm, overlap):
    t0 = time.perf_counter()
    out = F.balance([f for f in fs0], comm, overlap=overlap)
    return out, time.perf_counter() - t0

# first runs warm the jit caches (and the KV path), second runs are timed
F.balance([f for f in fs0], comm_ov, overlap=True)
F.balance([f for f in fs0], comm_ser, overlap=False)
comm_ov.reset_counters()
comm_ser.reset_counters()
out_ov, t_ov = timed(comm_ov, True)
out_ser, t_ser = timed(comm_ser, False)
np.testing.assert_array_equal(out_ov[0].keys, out_ser[0].keys)
np.testing.assert_array_equal(out_ov[0].level, out_ser[0].level)
assert comm_ov.wire_digest() == comm_ser.wire_digest()
gh = F.ghost(out_ov, comm_ov)

rec = {
    "rank": pid,
    "elements_initial": int(fs0[0].num_local),
    "elements_balanced": int(out_ov[0].num_local),
    "ghosts": int(len(gh[0]["level"])),
    "balance_bytes": int(comm_ov.bytes_for("balance")),
    "ghost_bytes": int(comm_ov.bytes_for("ghost")),
    "t_overlap_s": t_ov,
    "t_serialized_s": t_ser,
}
world = comm_ov.allgather([rec])
if pid == 0:
    json.dump({"ranks": P, "level": level, "per_rank": world},
              open(out_path, "w"))
comm_ov.barrier()
print(f"rank {pid}: scale OK", flush=True)
"""


def _run_scale_case(P: int, level: int) -> dict:
    """Spawn P real DistComm processes on a weak-scaling brick; collect the
    per-rank record rank 0 aggregates."""
    import os
    import tempfile

    from repro.launch.multiproc import run_ranks

    fd, tmp_name = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    out_path = Path(tmp_name)
    try:
        outs = run_ranks(_SCALE_SCRIPT, P, extra_args=(P, level, out_path))
        for pid, (out, _err) in enumerate(outs):
            assert f"rank {pid}: scale OK" in out
        return json.loads(out_path.read_text())
    finally:
        out_path.unlink(missing_ok=True)


def scale(tiny: bool = False):
    """Overlapped vs serialized Balance, and weak-scaling wire volume.

    Two parts, merged into BENCH_forest.json:

      "overlap"  in-process `LatencyComm(4)` (SimComm + simulated per-
                 collective round-trip time, KV-RPC scale) on the 8k-element
                 d=3 mesh: the double-buffered round loop vs the serialized
                 one (`overlap=False`).  Results are asserted bit-identical;
                 the full run asserts the acceptance bar of >= 1.3x.

      "scale"    REAL `DistComm` subprocesses over jax.distributed on a
                 weak-scaling domain (2D Kuhn brick, one cube column and
                 hence a constant element load per rank): per-rank
                 balance/ghost wire bytes and overlapped-vs-serialized wall
                 times at P = 1 (in-process LocalComm), 2, and 4 ranks.
    """
    from repro.core import batch
    from repro.core import cmesh as Cm
    from repro.core import forest as F
    from repro.core.comm import LatencyComm

    # ---- part 1: overlap at the 8k-element size -------------------------
    d = 3
    level = 2 if tiny else 4
    latency_s = 0.002 if tiny else 0.01
    P = 4
    base = F.new_uniform(d, 2, level, F.SimComm(P))
    n0 = F.count_global(base)

    def corner_cb(tree, elems, cap=level + 2):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    with batch.use_backend("jnp"):
        fs0 = [F.adapt(f, corner_cb, recursive=True) for f in base]
        # compute-only reference (no latency), also warms the jit caches
        us_zero = _time(lambda: F.balance([f for f in fs0], F.SimComm(P)), n=2)
        us_ser = _time(lambda: F.balance(
            [f for f in fs0], LatencyComm(P, latency_s), overlap=False), n=3)
        us_ovl = _time(lambda: F.balance(
            [f for f in fs0], LatencyComm(P, latency_s), overlap=True), n=3)
        # identity assert on latency-free runs (LatencyComm changes timing
        # only — pinned by tests — so paying the simulated RTT again here
        # would be pure waste)
        out_s = F.balance([f for f in fs0], F.SimComm(P), overlap=False)
        out_o = F.balance([f for f in fs0], F.SimComm(P), overlap=True)
    identical = all(
        np.array_equal(a.keys, b.keys) and np.array_equal(a.level, b.level)
        for a, b in zip(out_s, out_o))
    assert identical, "overlapped balance diverged from serialized"
    speedup = us_ser / us_ovl
    overlap_report = {
        "d": d, "level": level, "elements": n0, "ranks": P,
        "latency_s": latency_s, "zero_latency_us": us_zero,
        "serialized_us": us_ser, "overlapped_us": us_ovl,
        "speedup": speedup, "identical": identical,
    }
    row("overlap_balance_serialized", us_ser, f"latency={latency_s}s")
    row("overlap_balance_overlapped", us_ovl,
        f"{speedup:.2f}x_vs_serialized:identical={int(identical)}")
    if not tiny:
        assert speedup >= 1.3, (
            f"overlap acceptance: {speedup:.2f}x < 1.3x at {n0} elements")

    # ---- part 2: weak-scaling DistComm subprocess runs ------------------
    wlevel = 2 if tiny else 3
    ranks = [2] if tiny else [2, 4]
    cases = []
    # P = 1 baseline in-process: same per-rank load, zero wire.  Executes
    # the SAME scenario fragment as the subprocess ranks, so the
    # weak-scaling rows cannot drift apart (equal caps, equal domains).
    from repro.launch.multiproc import WEAK_BRICK_SETUP

    lc = F.LocalComm()
    ns = {"np": np, "C": Cm, "F": F, "P": 1, "level": wlevel, "comm_ov": lc}
    exec(WEAK_BRICK_SETUP, ns)
    out1 = F.balance(ns["fs0"], lc)
    cases.append({"ranks": 1, "level": wlevel,
                  "elements_per_rank": int(out1[0].num_local),
                  "balance_bytes_per_rank": int(lc.bytes_for("balance")),
                  "ghost_bytes_per_rank": 0})
    for Pw in ranks:
        rec = _run_scale_case(Pw, wlevel)
        per = rec["per_rank"]
        bb = [r["balance_bytes"] for r in per]
        gb = [r["ghost_bytes"] for r in per]
        cases.append({
            "ranks": Pw, "level": wlevel,
            "elements_per_rank": int(np.mean([r["elements_balanced"] for r in per])),
            "balance_bytes_per_rank": int(np.mean(bb)),
            "balance_bytes_per_rank_max": int(np.max(bb)),
            "ghost_bytes_per_rank": int(np.mean(gb)),
            "t_overlap_s_max": max(r["t_overlap_s"] for r in per),
            "t_serialized_s_max": max(r["t_serialized_s"] for r in per),
            "per_rank": per,
        })
        row(f"scale_distcomm_P{Pw}", cases[-1]["t_overlap_s_max"] * 1e6,
            f"bytes_per_rank={cases[-1]['balance_bytes_per_rank']}"
            f":serialized_s={cases[-1]['t_serialized_s_max']:.3f}")
    scale_report = {"d": 2, "domain": "kuhn_brick_Px1",
                    "cells_per_rank": 1, "cases": cases}

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["overlap"] = overlap_report
    data["scale"] = scale_report
    out_path.write_text(json.dumps(data, indent=2))
    row("scale_json", 0.0, str(out_path))


_REPART_SCRIPT = r"""
import json, sys, time
import numpy as np
import jax

port, pid, P, out_path = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), sys.argv[4])
jax.distributed.initialize(
    coordinator_address=f"localhost:{port}", num_processes=P, process_id=pid)

from repro.core import cmesh as C
from repro.core import forest as F
from repro.core.comm import DistComm
from repro.launch.multiproc import SKEW_BRICK_SETUP

comm_ov = DistComm(timeout_s=240, namespace="rp.ov.")
comm_ser = DistComm(timeout_s=240, namespace="rp.ser.")
# housekeeping comm: keeps the ov/ser wire digests strictly migration
# traffic (wire_digest is cumulative — reset_counters does not clear it)
comm_h = DistComm(timeout_s=240, namespace="rp.h.")
exec(SKEW_BRICK_SETUP)  # defines skew, cm, fs0 (the skewed-adapt domain)

imb_before = F.load_imbalance(fs0, comm_h)
# first runs warm the jit caches (and the KV path), second runs are timed
F.repartition([f for f in fs0], comm_ov, overlap=True)
F.repartition([f for f in fs0], comm_ser, overlap=False)
comm_ov.reset_counters()
comm_ser.reset_counters()
t0 = time.perf_counter()
out_ov = F.repartition([f for f in fs0], comm_ov, overlap=True)
t_ov = time.perf_counter() - t0
t0 = time.perf_counter()
out_ser = F.repartition([f for f in fs0], comm_ser, overlap=False)
t_ser = time.perf_counter() - t0
np.testing.assert_array_equal(out_ov[0].keys, out_ser[0].keys)
np.testing.assert_array_equal(out_ov[0].level, out_ser[0].level)
np.testing.assert_array_equal(out_ov[0].tree, out_ser[0].tree)
assert comm_ov.wire_digest() == comm_ser.wire_digest(), \
    "overlap changed the migration bytes"
imb_after = F.load_imbalance(out_ov, comm_ov)
assert imb_after <= 1.1, f"imbalance {imb_after} > 1.1 after repartition"
# the migrated layout keeps working: balance + ghost on fresh derived state
bal = F.balance([f for f in out_ov], comm_ov)
F.ghost(bal, comm_ov)

rec = {
    "rank": pid,
    "elements_before": int(fs0[0].num_local),
    "elements_after": int(out_ov[0].num_local),
    "migrated_bytes": int(comm_ov.bytes_for("repartition")),
    "t_overlap_s": t_ov,
    "t_serialized_s": t_ser,
}
blob = (rec, out_ov[0].tree, out_ov[0].keys, out_ov[0].level,
        out_ov[0].anchor, out_ov[0].stype)
world = comm_ov.allgather([blob])
if pid == 0:
    # single-rank oracle: the same domain and skewed adapt under
    # `LocalComm`, where repartition is the identity on the global leaf
    # sequence — the migrated world must match it element for element
    ns = {"np": np, "C": C, "F": F, "P": P, "comm_ov": F.LocalComm()}
    exec(SKEW_BRICK_SETUP, ns)
    ref = F.repartition(ns["fs0"], ns["comm_ov"])
    for i, name in ((1, "tree"), (2, "keys"), (3, "level"),
                    (4, "anchor"), (5, "stype")):
        np.testing.assert_array_equal(
            np.concatenate([w[i] for w in world]),
            np.concatenate([getattr(f, name) for f in ref]))
    print("rank 0: repartition == single-rank oracle", flush=True)
    json.dump({"ranks": P,
               "imbalance_before": float(imb_before),
               "imbalance_after": float(imb_after),
               "per_rank": [w[0] for w in world]},
              open(out_path, "w"))
comm_ov.barrier()
print(f"rank {pid}: repartition OK", flush=True)
"""


def _run_repart_case(P: int) -> dict:
    """Spawn P real DistComm processes on the skewed-adapt brick; collect
    the per-rank record rank 0 aggregates after its oracle check."""
    import os
    import tempfile

    from repro.launch.multiproc import run_ranks

    fd, tmp_name = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    out_path = Path(tmp_name)
    try:
        outs = run_ranks(_REPART_SCRIPT, P, extra_args=(P, out_path))
        for pid, (out, _err) in enumerate(outs):
            assert f"rank {pid}: repartition OK" in out
        assert "rank 0: repartition == single-rank oracle" in outs[0][0]
        return json.loads(out_path.read_text())
    finally:
        out_path.unlink(missing_ok=True)


def repartition(tiny: bool = False):
    """Dynamic repartition on the skewed-adapt Kuhn brick.

    Two parts, merged into BENCH_forest.json under "repartition":

      in-process  `SimComm(4)` on the skewed brick (only the first cube
                  cell refines, so the initial SFC split is ~P:1
                  imbalanced): element imbalance before/after, migrated
                  wire bytes, overlap == serialized identity, and the
                  overlapped vs serialized wall time under `LatencyComm`
                  (the weight-total allgather and the migration alltoallv
                  each hide local packing/assembly work).

      distcomm    REAL `DistComm` subprocesses over jax.distributed on
                  the same domain — the tentpole's acceptance run: P = 4
                  (2 in tiny), post-repartition imbalance <= 1.1, world
                  element-for-element identical to the single-rank
                  oracle, wire-digest parity between the overlapped and
                  serialized migrations.
    """
    from repro.core import cmesh as Cm
    from repro.core import forest as F
    from repro.core.comm import LatencyComm
    from repro.launch.multiproc import SKEW_BRICK_SETUP

    P = 4
    latency_s = 0.002 if tiny else 0.01
    ns = {"np": np, "C": Cm, "F": F, "P": P, "comm_ov": F.SimComm(P)}
    exec(SKEW_BRICK_SETUP, ns)
    fs0, comm = ns["fs0"], ns["comm_ov"]
    imb_before = F.load_imbalance(fs0, comm)
    out = F.repartition([f for f in fs0], comm)
    imb_after = F.load_imbalance(out, comm)
    migrated = comm.bytes_for("repartition")
    n = F.count_global(out)
    assert imb_after <= 1.1, f"imbalance {imb_after} > 1.1 after repartition"
    out_ser = F.repartition([f for f in fs0], F.SimComm(P), overlap=False)
    identical = all(
        np.array_equal(a.keys, b.keys) and np.array_equal(a.level, b.level)
        and np.array_equal(a.tree, b.tree) for a, b in zip(out, out_ser))
    assert identical, "overlapped repartition diverged from serialized"
    us_ser = _time(lambda: F.repartition(
        [f for f in fs0], LatencyComm(P, latency_s), overlap=False), n=3)
    us_ovl = _time(lambda: F.repartition(
        [f for f in fs0], LatencyComm(P, latency_s), overlap=True), n=3)
    report = {
        "d": 2, "domain": f"kuhn_brick_{P}x1", "ranks": P, "elements": n,
        "imbalance_before": imb_before, "imbalance_after": imb_after,
        "migrated_bytes": migrated, "latency_s": latency_s,
        "serialized_us": us_ser, "overlapped_us": us_ovl,
        "overlap_speedup": us_ser / us_ovl, "identical": identical,
    }
    row("repartition_imbalance", 0.0,
        f"{imb_before:.2f}->{imb_after:.3f}:migrated_bytes={migrated}")
    row("repartition_overlapped", us_ovl,
        f"{us_ser / us_ovl:.2f}x_vs_serialized:identical={int(identical)}")

    Pw = 2 if tiny else 4
    rec = _run_repart_case(Pw)
    assert rec["imbalance_after"] <= 1.1, rec
    mig = sum(r["migrated_bytes"] for r in rec["per_rank"])
    t_ov = max(r["t_overlap_s"] for r in rec["per_rank"])
    t_ser = max(r["t_serialized_s"] for r in rec["per_rank"])
    rec["oracle_identical"] = True  # asserted inside the rank-0 subprocess
    report["distcomm"] = rec
    row(f"repartition_distcomm_P{Pw}", t_ov * 1e6,
        f"imbalance={rec['imbalance_before']:.2f}->"
        f"{rec['imbalance_after']:.3f}:migrated_bytes={mig}"
        f":serialized_s={t_ser:.3f}")

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["repartition"] = report
    out_path.write_text(json.dumps(data, indent=2))
    row("repartition_json", 0.0, str(out_path))


def chaos(tiny: bool = False):
    """Seeded fault injection on the resilience brick (2x1 Kuhn brick,
    corner adapt, balance, `ChaosComm` over `SimComm(4)`).

    The robustness acceptance gates, run as benchmark rows so CI smoke
    exercises them on every push:

      * per fault kind (corrupt / truncate / duplicate / mixed+delay) the
        chaos run must end bit-identical to the clean run — every injected
        fault detected by the production unframe/decode path, retries
        bounded by the per-payload budget — and the row reports the
        injected/detected counts plus the wall-clock overhead vs clean;
      * a stalled rank under a wait deadline must surface as a
        `CommTimeoutError` naming the phase and collective;
      * crash-at-collective + `Autosaver` + `recover` onto a 3-rank world
        must match the fresh 3-rank run element for element.

    Merges a "chaos" section into BENCH_forest.json.
    """
    import tempfile

    from repro.core import cmesh as Cm
    from repro.core import forest as F
    from repro.core.errors import CommTimeoutError, InjectedCrash
    from repro.core.resilience import Autosaver, ChaosComm, recover

    P = 4
    cap = 3 if tiny else 4
    cm = Cm.cmesh_brick(2, (2, 1))

    def corner(tree, elems):
        a = np.asarray(elems.anchor)
        l = np.asarray(elems.level)
        return ((a.sum(1) == 0) & (l < cap)).astype(np.int32)

    def adapted(comm):
        fs = F.new_uniform(2, cm.num_trees, 2, comm, cmesh=cm)
        return [F.adapt(f, corner, recursive=True) for f in fs]

    def pipeline(comm):
        return F.balance(adapted(comm), comm)

    def world(fs):
        return {k: np.concatenate([np.asarray(getattr(f, k)) for f in fs])
                for k in ("tree", "anchor", "level", "stype")}

    t0 = time.perf_counter()
    ref = world(pipeline(F.SimComm(P)))
    us_clean = (time.perf_counter() - t0) * 1e6
    n = len(ref["level"])
    report = {"d": 2, "ranks": P, "elements": n, "seed": 7,
              "clean_us": us_clean, "faults": {}}
    row("chaos_clean_baseline", us_clean, f"n={n}")

    kinds = [
        ("corrupt", dict(p_corrupt=0.3)),
        ("truncate", dict(p_truncate=0.3)),
        ("duplicate", dict(p_duplicate=0.3)),
        ("mixed", dict(p_corrupt=0.15, p_truncate=0.1, p_duplicate=0.05,
                       p_delay=0.05)),
    ]
    for kind, rates in kinds:
        ch = ChaosComm(F.SimComm(P), seed=7, **rates)
        t0 = time.perf_counter()
        got = world(pipeline(ch))
        us = (time.perf_counter() - t0) * 1e6
        identical = all(np.array_equal(got[k], ref[k]) for k in ref)
        inj, det = ch.injected(), ch.fault_counts["detected"]
        assert identical, f"chaos[{kind}] produced a different forest"
        assert inj > 0, f"chaos[{kind}] injected nothing at these rates"
        assert det == inj, (kind, ch.fault_counts)
        assert ch.fault_counts["retries"] <= inj * ch.cfg.max_retries
        report["faults"][kind] = {
            "rates": rates, "injected": inj, "detected": det,
            "retries": ch.fault_counts["retries"], "us": us,
            "overhead_vs_clean": us / us_clean, "identical": identical,
        }
        row(f"chaos_{kind}", us,
            f"identical={int(identical)}:injected={inj}:detected={det}"
            f":retries={ch.fault_counts['retries']}")

    # a stalled rank surfaces as a phase-named timeout, not a hang
    ch = ChaosComm(F.SimComm(P), stall_after=2, phases=("balance",))
    ch.set_deadline(0.05 if tiny else 0.2)
    try:
        pipeline(ch)
        raise AssertionError("stalled collective did not time out")
    except CommTimeoutError as e:
        assert e.phase == "balance", e
        report["stall"] = {"phase": e.phase, "seq": e.seq,
                           "elapsed_s": e.elapsed_s, "polls": e.retries}
        row("chaos_stall_deadline", e.elapsed_s * 1e6,
            f"timeout_phase={e.phase}:seq={e.seq}")

    # crash mid-balance -> Autosaver checkpoint -> elastic recover at P-1
    with tempfile.TemporaryDirectory() as td:
        ckpt = Path(td) / "autosave"
        ch = ChaosComm(F.SimComm(P), crash_at=3, crash_ranks=(3,),
                       phases=("balance",))
        saver = Autosaver(ckpt).install()
        try:
            fs = adapted(ch)
            try:
                F.balance(fs, ch)
                raise AssertionError("injected crash did not fire")
            except InjectedCrash:
                pass
        finally:
            saver.uninstall()
        c3 = F.SimComm(P - 1)
        t0 = time.perf_counter()
        done = F.balance(recover(ckpt, c3, cmesh=cm), c3)
        us_rec = (time.perf_counter() - t0) * 1e6
        got = world(done)
        fresh = world(pipeline(F.SimComm(P - 1)))
        identical = all(np.array_equal(got[k], fresh[k]) for k in fresh)
        assert identical, "recovered P=3 diverged from fresh P=3"
        report["crash_recover"] = {
            "crash_at": 3, "victim_rank": 3, "survivor_ranks": P - 1,
            "recover_and_balance_us": us_rec, "elements": len(got["level"]),
            "identical_to_fresh": identical,
        }
        row("chaos_crash_recover", us_rec,
            f"P={P}->{P - 1}:identical={int(identical)}"
            f":elements={len(got['level'])}")

    name = "BENCH_forest_tiny.json" if tiny else "BENCH_forest.json"
    out_path = Path(__file__).resolve().parents[1] / name
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    data["chaos"] = report
    out_path.write_text(json.dumps(data, indent=2))
    row("chaos_json", 0.0, str(out_path))


def roofline_summary():
    d = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not d.exists():
        row("roofline_summary", 0.0, "missing:run_dryrun_first")
        return
    for p in sorted(d.glob("*__single.json")):
        j = json.loads(p.read_text())
        if j.get("status") != "ok":
            row(f"roofline_{p.stem}", 0.0, j.get("status", "?"))
            continue
        r = j["roofline"]
        row(f"roofline_{p.stem}", 0.0,
            f"frac={r['roofline_fraction']:.3f}:bound={r['bottleneck']}")


SUITES = {
    "fig11_new_scaling": lambda tiny: fig11_new_scaling(),
    "fig11_new_ranks": lambda tiny: fig11_new_ranks(),
    "fig12_adapt_fractal": lambda tiny: fig12_adapt_fractal(),
    "partition_weighted": lambda tiny: partition_weighted(),
    "element_ops": lambda tiny: element_ops(),
    "pallas_kernels": pallas_kernels,
    "moe_placement": lambda tiny: moe_placement(),
    "forest_backends": forest_backends,
    "face_sweep": face_sweep,
    "device_eval": device_eval,
    "multitree": multitree,
    "hybrid": hybrid,
    "scale": scale,
    "repartition": repartition,
    "chaos": chaos,
    "roofline_summary": lambda tiny: roofline_summary(),
}


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--suite", default="all",
        help="comma-separated suite names (default: all); choices: "
             + ",".join(SUITES),
    )
    ap.add_argument(
        "--tiny", action="store_true",
        help="smallest problem sizes only (CI smoke runs)",
    )
    args = ap.parse_args(argv)
    names = list(SUITES) if args.suite == "all" else args.suite.split(",")
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choices: {list(SUITES)}")
    print("name,us_per_call,derived")
    for n in names:
        SUITES[n](args.tiny)


if __name__ == "__main__":
    main()
